"""End-to-end gateway benchmark: Poisson SSE load against a LIVE cell.

Unlike every other bench (which drives ``MultiSpinCell`` directly), this one
measures the full serving path — HTTP parse, SSE streaming, the gateway's
action queue, the step thread — under the open-loop load generator:

    in-process ``MultiSpinGateway`` (port 0) <- ``run_loadgen`` burst

Reported: delivered tokens/s (REAL wall), TTFT p50/p95 (real wall, send ->
first streamed round), end-to-end latency percentiles, and the acceptance
rate scraped back from ``/metrics`` — the scrape doubles as a format check.

``--smoke`` is the CI gate: a small synthetic burst that must stream every
request to completion, then writes ``BENCH_gateway.json`` at the repo root
(tokens/s + TTFT + acceptance) as the tracked artifact.  ``--backend
engine`` runs the same burst against a real paged smoke-scale SpecEngine.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_gateway            # synthetic
    PYTHONPATH=src python -m benchmarks.bench_gateway --smoke    # CI gate
    PYTHONPATH=src python -m benchmarks.bench_gateway --backend engine
"""

from __future__ import annotations

import argparse
import asyncio
import os
import re

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_gateway.json")


def _build_cell(backend: str, max_batch: int, scheme: str, seed: int):
    from repro.api import CellConfig, MultiSpinCell

    cfg = CellConfig(scheme=scheme, max_batch=max_batch, seed=seed,
                     t_ver_fix=0.035, t_ver_lin=0.0177, L_max=8)
    if backend == "synthetic":
        return MultiSpinCell(cfg)
    # real paged smoke-scale engine (same shape as bench_churn --engine)
    import jax

    from repro.api import EngineBackend, SpecEngine
    from repro.configs import get_config

    tcfg = get_config("qwen2.5-3b").smoke()
    dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                        head_dim=16, d_ff=64, name="draft-smoke")
    eng = SpecEngine(tcfg, dcfg, max_len=128, cache_kind="paged",
                     num_pages=max_batch * 2 * (128 // 16))
    eng.init_params(jax.random.PRNGKey(seed))
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (max_batch, 8), 0, tcfg.vocab_size)
    be = EngineBackend(eng, eng.start(prompts), keep_finished_tokens=True)
    return MultiSpinCell(cfg, backend=be)


def _scrape_acceptance(metrics_text: str) -> float:
    m = re.search(r"^multispin_acceptance_rate ([0-9.eE+-]+)$",
                  metrics_text, re.M)
    if m is None:
        raise SystemExit("gateway /metrics scrape FAILED: "
                         "multispin_acceptance_rate missing")
    return float(m.group(1))


async def _run(backend: str, n_requests: int, rate: float, max_batch: int,
               scheme: str, seed: int, max_new: tuple) -> dict:
    from repro.serving.gateway import (
        GatewayConfig,
        LoadGenConfig,
        MultiSpinGateway,
        run_loadgen,
    )

    cell = _build_cell(backend, max_batch, scheme, seed)
    gw = MultiSpinGateway(cell, GatewayConfig(port=0, idle_wait_s=0.02))
    await gw.start()
    try:
        report = await run_loadgen(
            "127.0.0.1", gw.port,
            LoadGenConfig(rate_per_s=rate, n_requests=n_requests,
                          max_new_tokens_choices=max_new, seed=seed))
        metrics_text = await _scrape(gw.port)
        stats = await _stats(gw.port)
    finally:
        await gw.stop()
    report["acceptance"] = _scrape_acceptance(metrics_text)
    report["rounds"] = stats["rounds_total"]
    report["goodput_sim_committed"] = (
        stats["last_round"]["goodput_committed"] if stats["last_round"]
        else 0.0)
    report["goodput_sim_capped"] = (
        stats["last_round"]["goodput_capped"] if stats["last_round"] else 0.0)
    return report


async def _scrape(port: int) -> str:
    from repro.serving.gateway import GatewayClient
    return await GatewayClient(port=port).metrics()


async def _stats(port: int) -> dict:
    from repro.serving.gateway import GatewayClient
    return await GatewayClient(port=port).stats()


def run(fast: bool = True, backend: str = "synthetic", smoke: bool = False,
        n_requests: int | None = None, rate: float = 16.0,
        max_batch: int = 8, scheme: str = "hete", seed: int = 0,
        out_path: str | None = None) -> list[dict]:
    if smoke:
        backend_, n, max_new = backend, 12, (4, 8)
        rate = 32.0
    else:
        backend_ = backend
        n = n_requests if n_requests is not None else (16 if fast else 64)
        max_new = (8, 16, 32)
    if backend_ == "engine":
        max_batch = min(max_batch, 3)
        max_new = (4, 8)
    report = asyncio.run(_run(backend_, n, rate, max_batch, scheme, seed,
                              max_new))
    ok = report["n_error"] == 0 and report["tokens"] > 0
    row = {
        "name": f"gateway/{backend_}/{scheme}",
        "derived": (f"tokens_per_s={report['tokens_per_s']:.1f} "
                    f"ttft_p50={report['ttft_s']['p50'] * 1e3:.1f}ms "
                    f"ttft_p95={report['ttft_s']['p95'] * 1e3:.1f}ms "
                    f"acceptance={report['acceptance']:.3f} "
                    f"ok={ok}"),
        "tokens_per_s": report["tokens_per_s"],
        "tokens": report["tokens"],
        "n_ok": report["n_ok"],
        "n_error": report["n_error"],
        "errors": report["errors"],
        "wall_s": report["wall_s"],
        "rounds": report["rounds"],
        "ttft_s": report["ttft_s"],
        "latency_s": report["latency_s"],
        "acceptance": report["acceptance"],
        "goodput_sim_committed": report["goodput_sim_committed"],
        "goodput_sim_capped": report["goodput_sim_capped"],
    }
    if smoke:
        if not ok:
            raise SystemExit(f"gateway smoke FAILED: {row['derived']} "
                             f"errors={report['errors']}")
        from .common import write_rows_json
        write_rows_json(out_path or BENCH_PATH, [row])
    return [row]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="synthetic",
                    choices=("synthetic", "engine"))
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=16.0,
                    help="Poisson arrivals per REAL second")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--scheme", default="hete")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: small burst, writes BENCH_gateway.json "
                         "at the repo root, exits non-zero on any error")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="dump rows as JSON (CI artifact)")
    ap.add_argument("--out", type=str, default=None, metavar="PATH",
                    help="where --smoke writes its rows (default: the "
                         "committed repo-root BENCH_gateway.json; CI points "
                         "this at artifacts/ so baselines stay untouched)")
    args = ap.parse_args()
    rows = run(fast=not args.full, backend=args.backend, smoke=args.smoke,
               n_requests=args.n_requests, rate=args.rate,
               max_batch=args.max_batch, scheme=args.scheme, seed=args.seed,
               out_path=args.out)
    for r in rows:
        print(r["name"], r["derived"])
    if args.json:
        from .common import write_rows_json
        write_rows_json(args.json, rows)


if __name__ == "__main__":
    main()
