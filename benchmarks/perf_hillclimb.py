"""§Perf hillclimb driver: hypothesis -> change -> measure -> validate.

Three cells (selection criteria per assignment):
  * arctic-480b  train_4k   — most collective-bound (coll/other = 13.7x)
  * deepseek-7b  decode_32k — worst actionable roofline fraction AND the
                              paper-representative cell (llama-arch target,
                              batched verification serve_step)
  * zamba2-2.7b  long_500k  — worst absolute fraction (long-context edge
                              serving, SSM+attn hybrid)

Each iteration: (1) napkin-math hypothesis on the dominant analytic term,
(2) a real config/lowering change, (3) re-lower + compile the cell (fit +
compilability evidence), (4) recompute analytic terms, (5) verdict.

Run:  PYTHONPATH=src python -m benchmarks.perf_hillclimb
Writes experiments/perf_log.json (+ prints the markdown rows).
"""

from __future__ import annotations

import json
import os

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")


def _terms(arch, shape_name, *, flash=False, microbatches=None, fsdp=True,
           draft_window=0, kv_bytes=2, alpha=0.8):
    """Analytic terms + roofline fraction for a cell variant."""
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import ARCH_MICROBATCHES, TRAIN_MICROBATCHES, _cfg_for_dryrun
    from repro.roofline.analysis import count_params, model_flops
    from repro.roofline.analytic import MeshInfo, roofline_terms, summarize

    shape = SHAPES[shape_name]
    cfg = _cfg_for_dryrun(arch, shape.kind == "train")
    mb = microbatches or ARCH_MICROBATCHES.get(arch, TRAIN_MICROBATCHES)
    tb = roofline_terms(cfg, shape, MeshInfo(chips=256, dp=16, mp=16),
                        flash=flash, microbatches=mb, fsdp=fsdp,
                        draft_window=draft_window, kv_bytes=kv_bytes)
    total, active = count_params(get_config(arch))
    mf = model_flops(cfg, shape, total, active)
    if draft_window > 0:
        # useful tokens per serve step = expected accepted + 1 (paper eq. 12)
        e_n = (1 - alpha ** (draft_window + 1)) / (1 - alpha)
        mf = mf * e_n
    return summarize(tb, mf, 256)


def _lower(arch, shape_name, **kw):
    """Real lowering check: compile + per-chip memory."""
    from repro.launch.dryrun import lower_cell
    lowered, *_ = lower_cell(arch, shape_name, False, **kw)
    mem = lowered.compile().memory_analysis()
    return {"temp_gb": round(mem.temp_size_in_bytes / 1e9, 1),
            "args_gb": round(mem.argument_size_in_bytes / 1e9, 1)}


def _entry(cell, it, hypothesis, change, before, after, lowering, verdict):
    dom_b = max(before["compute_s"], before["memory_s"], before["collective_s"])
    dom_a = max(after["compute_s"], after["memory_s"], after["collective_s"])
    return {
        "cell": cell, "iteration": it, "hypothesis": hypothesis,
        "change": change,
        "before": {k: before[k] for k in
                   ("compute_s", "memory_s", "collective_s", "bottleneck",
                    "peak_fraction")},
        "after": {k: after[k] for k in
                  ("compute_s", "memory_s", "collective_s", "bottleneck",
                   "peak_fraction")},
        "dominant_term_delta": f"{dom_b:.3e} -> {dom_a:.3e} "
                               f"({100 * (dom_a / dom_b - 1):+.0f}%)",
        "frac": f"{before['peak_fraction']:.4f} -> {after['peak_fraction']:.4f}",
        "lowering": lowering,
        "verdict": verdict,
    }


def run() -> list[dict]:
    log = []

    # ================= cell 1: arctic-480b train_4k =================
    cell = "arctic-480b/train_4k/pod16x16"
    base = _terms("arctic-480b", "train_4k", microbatches=16)

    # -- iter 1: microbatch knee (collective vs memory tradeoff) --
    # napkin: fsdp gathers scale with mb (1.84e12 B at mb=16); mb=8 halves the
    # regather traffic; compiled memory rises from 36.6 to ~52 GB/chip.
    after = _terms("arctic-480b", "train_4k", microbatches=8)
    lowering = _lower("arctic-480b", "train_4k", microbatches=8)
    log.append(_entry(
        cell, 1,
        "FSDP per-microbatch weight regathers dominate (1.84e12 B/chip at "
        "mb=16); halving microbatches halves gather traffic at ~1.4x temp "
        "memory",
        "microbatches 16 -> 8",
        base, after, lowering,
        "CONFIRMED: collective term -44%; memory fit worsens 36.6->52 GB "
        "(>16 GB either way at 256 chips; see iter 3)"))
    cur = after

    # -- iter 2: drop cross-pod FSDP for experts (ZeRO over data only)? --
    # napkin: expert weights NOT dp-sharded would eliminate the gathers
    # entirely, but per-chip expert bytes become 470e9*2/16 = 58 GB >> HBM.
    log.append(_entry(
        cell, 2,
        "eliminating FSDP on expert weights removes the dominant gather "
        "entirely",
        "fsdp=False for MoE tensors (analysis only)",
        cur, cur,
        {"temp_gb": None, "args_gb": 58.8,
         "note": "params/chip = 470e9*2/16 = 58.8 GB — exceeds HBM"},
        "REFUTED: infeasible at 256 chips; expert weights must stay "
        "2-D sharded. 480B training wants >= 1024 chips"))

    # -- iter 3: int8-compressed gradient reduce-scatter (error feedback) --
    # napkin: rs portion of fsdp term = ag/(2*mb) ~ 6%; int8 halves it -> ~3%
    after = dict(cur)
    rs_saving = cur["collective_s"] * 0.06 * 0.5
    after = {**cur, "collective_s": cur["collective_s"] - rs_saving}
    after["peak_fraction"] = cur["peak_fraction"] * (
        max(cur["compute_s"], cur["memory_s"], cur["collective_s"])
        / max(after["compute_s"], after["memory_s"], after["collective_s"]))
    log.append(_entry(
        cell, 3,
        "int8 gradient reduce-scatter (distributed.collectives, with error "
        "feedback) halves the gradient share of FSDP traffic (~6% of the "
        "term)",
        "int8 reduce-scatter on gradients",
        cur, after, {"note": "collectives.make_compressed_allreduce, "
                             "validated in tests on 8 devices"},
        "CONFIRMED but small: -3% on dominant term -> below the 5% stop "
        "threshold; stopping cell 1"))

    # ================= cell 2: deepseek-7b decode_32k =================
    cell = "deepseek-7b/decode_32k/pod16x16"
    base = _terms("deepseek-7b", "decode_32k")

    # -- iter 1: speculative verification window (the paper's technique) --
    # napkin: KV-cache reads (8.05e9 B/chip) are charged per serve step
    # regardless of how many tokens are scored; a T=9 window (L=8 drafts,
    # alpha=0.8) yields E[N] = (1-0.8^9)/0.2 = 4.33 accepted tokens per read.
    after = _terms("deepseek-7b", "decode_32k", draft_window=8)
    lowering = _lower("deepseek-7b", "decode_32k", draft_window=8)
    log.append(_entry(
        cell, 1,
        "decode is KV-read bound; the paper's own batched verification "
        "window amortizes one cache sweep over E[N]=4.33 accepted tokens",
        "serve_step window T=1 -> 9 (speculative verification, L=8)",
        base, after, lowering,
        "CONFIRMED: useful-work fraction x3.5 (XLA window also materializes "
        "T x Skv scores — see iter 2)"))
    cur = after

    # -- iter 2: flash-decode kernel (no score materialization) --
    # napkin: (B,H,T,Skv) f32 scores = 1.2e10 B/chip r/w at T=9; the Pallas
    # flash-decode kernel keeps tiles in VMEM.
    after = _terms("deepseek-7b", "decode_32k", draft_window=8, flash=True)
    log.append(_entry(
        cell, 2,
        "window decode now re-materializes f32 scores; the flash-decode "
        "kernel (kernels/decode_attention.py, interpret-validated) removes "
        "them",
        "flash-decode kernel path for verification windows",
        cur, after, {"note": "kernel allclose-tested; analytic byte elision"},
        "CONFIRMED: memory term -57%"))
    cur = after

    # -- iter 3: int8 KV cache --
    # napkin: remaining memory term is ~all KV reads; int8 halves them.
    after = _terms("deepseek-7b", "decode_32k", draft_window=8, flash=True,
                   kv_bytes=1)
    lowering = _lower("deepseek-7b", "decode_32k", draft_window=8,
                      cache_dtype="int8")
    log.append(_entry(
        cell, 3,
        "KV reads are the remaining ~90% of the memory term; int8 "
        "quantized KV (per-head scales in the decode kernel) halves them",
        "KV cache bf16 -> int8",
        cur, after, lowering,
        "CONFIRMED: memory term -46%; cumulative frac gain 8.7x over "
        "baseline"))
    cur = after

    # -- iter 4: further window growth --
    after = _terms("deepseek-7b", "decode_32k", draft_window=16, flash=True,
                   kv_bytes=1)
    log.append(_entry(
        cell, 4,
        "L=16 window: E[N] grows to 5.2 but acceptance saturates "
        "(alpha^L tail) while window compute grows linearly",
        "draft window 8 -> 16",
        cur, after, {"note": "analytic only"},
        "MARGINAL: <5% fraction change — Theorem-1's content-latency "
        "tradeoff shows up in the roofline too; stopping cell 2"))

    # ================= cell 3: zamba2-2.7b long_500k =================
    cell = "zamba2-2.7b/long_500k/pod16x16"
    base = _terms("zamba2-2.7b", "long_500k")

    # -- iter 1: speculative window --
    after = _terms("zamba2-2.7b", "long_500k", draft_window=8)
    lowering = _lower("zamba2-2.7b", "long_500k", draft_window=8)
    log.append(_entry(
        cell, 1,
        "B=1 long-context decode reads 9 shared-attn KV caches (1.9e8 B) + "
        "all params (1.9e7 B) per single token; a verification window "
        "amortizes both by E[N]=4.33",
        "serve_step window T=1 -> 9",
        base, after, lowering,
        "CONFIRMED: fraction x3.6 (hybrid SSM state rollback handled via "
        "per-step snapshots, tests/test_spec_engine.py)"))
    cur = after

    # -- iter 2: int8 KV for the shared-attention caches --
    after = _terms("zamba2-2.7b", "long_500k", draft_window=8, kv_bytes=1)
    log.append(_entry(
        cell, 2,
        "shared-attn KV is 90% of memory term at 500k context; int8 halves",
        "KV cache bf16 -> int8 (9 shared-block caches)",
        cur, after, {"note": "analytic + kernel path as in cell 2"},
        "CONFIRMED: memory term -44%"))
    cur = after

    # -- iter 3: flash decode --
    after = _terms("zamba2-2.7b", "long_500k", draft_window=8, kv_bytes=1,
                   flash=True)
    log.append(_entry(
        cell, 3,
        "remaining: T x 500k f32 score rows for the shared blocks",
        "flash-decode kernel for shared attention",
        cur, after, {"note": "analytic byte elision"},
        "CONFIRMED: memory term -36%; next levers (<5%): state-dtype, "
        "conv fusion — stopping cell 3"))

    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(os.path.join(EXPERIMENTS, "perf_log.json"), "w") as f:
        json.dump(log, f, indent=2, default=str)
    return log


def main():
    log = run()
    for e in log:
        print(f"\n### {e['cell']} — iteration {e['iteration']}")
        print(f"hypothesis: {e['hypothesis']}")
        print(f"change:     {e['change']}")
        print(f"dominant:   {e['dominant_term_delta']}   frac: {e['frac']}")
        print(f"lowering:   {e['lowering']}")
        print(f"verdict:    {e['verdict']}")


if __name__ == "__main__":
    main()
