"""Fig. 3: empirical and theoretical sum goodput vs draft length.

Theory: eq. 18 with Lemma-1 bandwidth.  Empirical: the protocol simulator
(Bernoulli acceptance at Table-I alphas over real channel realizations).
Checks: unimodality, theory/empirical agreement, argmax == Theorem-1 L*.
"""

from __future__ import annotations

import numpy as np

from repro.core.bandwidth import solve_equalized_theta
from repro.core.channel import ChannelState
from repro.core.draft_control import optimal_uniform_length
from repro.core.goodput import expected_accepted_tokens

from .common import K_DEFAULT, load_calibration, paper_channel, paper_devices


def run(pair: str = "llama2", fast: bool = True) -> list[dict]:
    calib = load_calibration()[pair]
    cfg = paper_channel(pair)
    rng = np.random.default_rng(0)
    K = K_DEFAULT
    tasks, alphas = paper_devices(pair, K, rng)
    t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
    T_ver = calib["t_fix"] + K * calib["t_lin"]
    ch = ChannelState.sample(cfg, K, rng)
    theta, _ = solve_equalized_theta(t_dev, ch.rates, cfg.q_tok_bits,
                                     cfg.total_bandwidth_hz)
    alpha_mean = float(np.mean(alphas))

    n_rounds = 100 if fast else 600
    rows = []
    curve_theory, curve_emp = [], []
    for L in range(1, 26):
        tau_theory = float(np.sum(expected_accepted_tokens(alphas, L))
                           / (L * theta + T_ver))
        # empirical Monte-Carlo rounds
        tok = 0.0
        for _ in range(n_rounds):
            u = rng.random((K, L))
            acc = np.cumprod(u < alphas[:, None], axis=1).sum(axis=1)
            tok += float(np.sum(acc + 1))
        tau_emp = tok / (n_rounds * (L * float(theta) + T_ver))
        curve_theory.append(tau_theory)
        curve_emp.append(tau_emp)
        rows.append({
            "name": f"goodput_vs_L/{pair}/L={L}",
            "us_per_call": "",
            "derived": f"theory={tau_theory:.2f} empirical={tau_emp:.2f}",
        })

    L_star, _ = optimal_uniform_length(alpha_mean, float(theta), T_ver, L_max=25)
    argmax_L = int(np.argmax(curve_theory)) + 1
    rows.append({
        "name": f"goodput_vs_L/{pair}/summary",
        "us_per_call": "",
        "derived": (f"L_star_thm1={int(L_star)} argmax_grid={argmax_L} "
                    f"peak_theory={max(curve_theory):.2f} "
                    f"peak_emp={max(curve_emp):.2f} "
                    f"max_rel_gap={max(abs(a - b) / a for a, b in zip(curve_theory, curve_emp)):.3f}"),
        "L_star": int(L_star), "argmax": argmax_L,
        "curve_theory": curve_theory, "curve_emp": curve_emp,
    })
    return rows


if __name__ == "__main__":
    for pair in ("llama2", "qwen35"):
        rs = run(pair)
        print(rs[-1]["name"], rs[-1]["derived"])
