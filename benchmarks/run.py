"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--fast`` (default) keeps
CPU runtimes small; ``--full`` uses paper-scale seeds/rounds.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    args = ap.parse_args()
    fast = not args.full

    from . import (
        bench_acceptance,
        bench_bandwidth_sweep,
        bench_beyond,
        bench_churn,
        bench_gateway,
        bench_goodput_vs_L,
        bench_kernels,
        bench_optimal_L,
        bench_protocols,
        bench_scaling_K,
        bench_tver_vs_K,
        roofline,
    )

    benches = {
        "acceptance": lambda: (bench_acceptance.run("llama2", fast)
                               + bench_acceptance.run("qwen35", fast)),
        "tver_vs_K": lambda: bench_tver_vs_K.run(fast),
        "goodput_vs_L": lambda: (bench_goodput_vs_L.run("llama2", fast)
                                 + bench_goodput_vs_L.run("qwen35", fast)),
        "optimal_L": lambda: bench_optimal_L.run(fast),
        "protocols": lambda: bench_protocols.run(fast),
        "bandwidth_sweep": lambda: bench_bandwidth_sweep.run(fast),
        "scaling_K": lambda: bench_scaling_K.run(fast),
        "churn": lambda: bench_churn.run(fast),
        "gateway": lambda: bench_gateway.run(fast),
        "kernels": lambda: bench_kernels.run(fast),
        "beyond": lambda: bench_beyond.run(fast),
        "roofline": lambda: roofline.run(fast),
    }
    selected = (args.only.split(",") if args.only else list(benches))

    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        t0 = time.time()
        try:
            rows = benches[name]()
        except Exception as e:  # noqa: BLE001
            print(f"{name},,FAILED: {type(e).__name__}: {e}")
            failures += 1
            continue
        for r in rows:
            derived = str(r.get("derived", "")).replace(",", ";")
            print(f"{r['name']},{r.get('us_per_call', '')},{derived}")
        print(f"{name}/_wall,{round((time.time() - t0) * 1e6)},done",
              file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
