"""Compiled round path: eager vs jitted vs jitted+donated steady-state cost.

The tentpole claim this bench guards: compiling the three row-subset round
steps (``draft_rows`` / ``verify_rows`` / ``commit_rows``) into jitted step
functions — with the KV pools and stream-state buffers DONATED and the
committed-token emission as the round's single device->host fetch — makes a
round materially faster than op-by-op eager dispatch *without changing a
single committed token*.

Rows:

* **roundpath/eager|jit|jit_donate** — steady-state ``us_per_round`` over
  the same seeded round schedule (identical keys, prompts, params), plus
  the one-time warmup compile seconds for the jitted modes.  Timing is
  host-gated in the regression diff.
* **roundpath/compare** — the structural gate: ``bit_identical`` committed
  tokens across all three modes, ``n_host_syncs == 1`` per round,
  ``retraces == 0`` after ``warmup(buckets)``, ``step_shapes`` bounded at
  3 (draft/verify/commit at the single bucket), and the headline
  ``speedup_donate >= 1.3`` against eager.
* **roundpath/tree_build** — ``build_token_tree`` with the engine's pooled
  ``TreeScratch`` vs fresh per-call allocation (the ``engine.tree_build``
  span's host-side cost).

``--smoke`` writes ``BENCH_roundpath.json`` and exits nonzero when the
structural gate fails.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_roundpath           # print rows
    PYTHONPATH=src python -m benchmarks.bench_roundpath --smoke   # CI gate
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_roundpath.json")

B, L, VHAT, MAX_LEN = 4, 4, 64, 96
SPEEDUP_GATE = 1.3


def _build(mode: str, seed: int):
    import jax

    from repro.configs import get_config
    from repro.serving.spec_engine import SpecEngine

    tcfg = get_config("qwen2.5-3b").smoke()
    dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                        head_dim=16, d_ff=64, name="draft-smoke")
    eng = SpecEngine(tcfg, dcfg, max_len=MAX_LEN, cache_kind="paged",
                     num_pages=B * 2 * (MAX_LEN // 16), compile_mode=mode)
    eng.init_params(jax.random.PRNGKey(seed))
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, 10), 0,
                                 tcfg.vocab_size)
    return eng, eng.start(prompts)


def run_mode(mode: str, seed: int, warm_rounds: int, rounds: int) -> dict:
    """One engine, one seeded round schedule; every mode replays the same
    keys so committed tokens are comparable bit-for-bit."""
    import jax

    eng, st = _build(mode, seed)
    compile_s = 0.0
    if mode != "eager":
        st, info = eng.warmup(st, [(B, L)], vhat=VHAT)
        compile_s = float(sum(info.values()))
    base = jax.random.PRNGKey(seed + 1000)
    lengths = np.full(B, L)
    for r in range(warm_rounds):
        st, _, _ = eng.spin_round(st, lengths, jax.random.fold_in(base, r),
                                  vhat=VHAT)
    retraced: list = []
    eng.on_step_trace = retraced.append
    h0 = eng.host_syncs
    t0 = time.perf_counter()
    for r in range(warm_rounds, warm_rounds + rounds):
        # each round ends in the engine's single device->host emission
        # fetch, so wall time per iteration is device-synchronized
        st, _, _ = eng.spin_round(st, lengths, jax.random.fold_in(base, r),
                                  vhat=VHAT)
    wall = time.perf_counter() - t0
    return {
        "mode": mode,
        "us_per_round": wall / rounds * 1e6,
        "compile_s": compile_s,
        "host_syncs_per_round": (eng.host_syncs - h0) / rounds,
        "retraces": len(retraced),
        "step_shapes": len(eng.step_shapes),
        "committed": [list(map(int, c)) for c in st.committed],
    }


def run_roundpath(seed: int, warm_rounds: int, rounds: int) -> list[dict]:
    res = {m: run_mode(m, seed, warm_rounds, rounds)
           for m in ("eager", "jit", "jit+donate")}
    rows = []
    for m, slug in (("eager", "eager"), ("jit", "jit"),
                    ("jit+donate", "jit_donate")):
        r = res[m]
        rows.append({
            "name": f"roundpath/{slug}",
            "derived": (f"us_per_round={r['us_per_round']:.0f} "
                        f"compile_s={r['compile_s']:.1f} "
                        f"host_syncs/round={r['host_syncs_per_round']:.1f}"),
            "us_per_round": r["us_per_round"],
            "compile_s": r["compile_s"],
            "rounds": rounds,
        })
    eager, jit, don = res["eager"], res["jit"], res["jit+donate"]
    bit_identical = (jit["committed"] == eager["committed"]
                     and don["committed"] == eager["committed"])
    speedup_jit = eager["us_per_round"] / jit["us_per_round"]
    speedup_donate = eager["us_per_round"] / don["us_per_round"]
    ok = (bit_identical and speedup_donate >= SPEEDUP_GATE
          and don["host_syncs_per_round"] == 1.0 and don["retraces"] == 0)
    rows.append({
        "name": "roundpath/compare",
        "derived": (f"speedup_jit={speedup_jit:.2f}x "
                    f"speedup_donate={speedup_donate:.2f}x "
                    f"bit_identical={bit_identical} "
                    f"n_host_syncs={don['host_syncs_per_round']:.0f} "
                    f"retraces={don['retraces']} ok={ok}"),
        "speedup_jit": speedup_jit,
        "speedup_donate": speedup_donate,
        "bit_identical": int(bit_identical),
        "n_host_syncs": don["host_syncs_per_round"],
        "retraces": don["retraces"],
        "step_shapes": don["step_shapes"],
        "gate_ok": int(ok),
    })
    return rows


def run_tree_build(seed: int, iters: int = 200, Bt: int = 8, J: int = 4,
                   Lt: int = 8, Vhat: int = 512) -> dict:
    """Host-side trie packing: pooled TreeScratch vs fresh allocations.

    Benched at a serving-scale shape — the pool's high-water reset touches
    only the node prefix the last round wrote, while fresh allocation
    zero-fills the full (B, J*L, Vhat) q-summary buffers every call."""
    from repro.core.token_tree import TreeScratch, build_token_tree

    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, 1000, (Bt, J, Lt)).astype(np.int32)
    # duplicate draft 0 into draft 1's prefix so the trie actually dedups
    tokens[:, 1, : Lt // 2] = tokens[:, 0, : Lt // 2]
    probs = rng.random((Bt, J, Lt)).astype(np.float32)
    q_idx = rng.integers(0, 1000, (Bt, J, Lt, Vhat)).astype(np.int32)
    q_val = rng.random((Bt, J, Lt, Vhat)).astype(np.float32)
    lengths = np.full(Bt, Lt, np.int64)

    def loop(scratch):
        t0 = time.perf_counter()
        for _ in range(iters):
            build_token_tree(tokens, probs, q_idx, q_val, lengths,
                             scratch=scratch)
        return (time.perf_counter() - t0) / iters * 1e6

    loop(None)  # warm numpy dispatch paths
    fresh_us = loop(None)
    scratch = TreeScratch()
    scratch_us = loop(scratch)
    ratio = fresh_us / scratch_us if scratch_us else 0.0
    return {
        "name": "roundpath/tree_build",
        "derived": (f"us_per_call={scratch_us:.0f} (scratch) "
                    f"fresh={fresh_us:.0f}us ratio={ratio:.2f}x "
                    f"B={Bt} J={J} L={Lt} Vhat={Vhat}"),
        "us_per_call": scratch_us,
        "fresh_us_per_call": fresh_us,
        "iters": iters,
    }


def run(smoke: bool = False, seed: int = 0, warm_rounds: int = 2,
        rounds: int = 8, out_path: str | None = None) -> list[dict]:
    rows = run_roundpath(seed, warm_rounds, rounds)
    rows.append(run_tree_build(seed))
    if smoke:
        gate_ok = bool(rows[-2]["gate_ok"])
        if not gate_ok:
            raise SystemExit("roundpath smoke FAILED: "
                             + rows[-2]["derived"])
        from .common import write_rows_json
        write_rows_json(out_path or BENCH_PATH, rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=8,
                    help="measured steady-state rounds per mode")
    ap.add_argument("--warm-rounds", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: bit-identity, 1 host sync/round, zero "
                         "retraces after warmup, >=1.3x donated speedup; "
                         "writes BENCH_roundpath.json")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="dump rows as JSON (CI artifact)")
    ap.add_argument("--out", type=str, default=None, metavar="PATH",
                    help="where --smoke writes its rows (default: the "
                         "committed repo-root BENCH_roundpath.json)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke, seed=args.seed,
               warm_rounds=args.warm_rounds, rounds=args.rounds,
               out_path=args.out)
    for r in rows:
        print(r["name"], r["derived"])
    if args.json:
        from .common import write_rows_json
        write_rows_json(args.json, rows)


if __name__ == "__main__":
    main()
