"""Table I: per-task empirical acceptance rates.

Construction (DESIGN.md §7): per-task SLM misalignment is induced by a
draft-temperature perturbation of the target model; the temperature is
calibrated per task so the measured acceptance E[min(1, p/q)] matches the
paper's Table-I mean.  The benchmark then verifies the calibration holds
under ACTUAL speculative verification on a real smoke-scale model (measured
accept fraction vs the analytic alpha).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.training.data import TABLE_I, TASK_TYPES


def _alpha_of_temperature(logits: jax.Array, tau: float) -> float:
    """alpha = E_x[ sum_v min(p(v), q_tau(v)) ] over context rows."""
    p = jax.nn.softmax(logits, axis=-1)
    q = jax.nn.softmax(logits / tau, axis=-1)
    return float(jnp.mean(jnp.sum(jnp.minimum(p, q), axis=-1)))


def calibrate_temperature(logits, alpha_target: float) -> float:
    lo, hi = 1.0, 8.0
    for _ in range(40):
        mid = 0.5 * (lo + hi)
        if _alpha_of_temperature(logits, mid) > alpha_target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def run(pair: str = "llama2", fast: bool = True) -> list[dict]:
    cfg = get_config("tinyllama-1.1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = (8, 32) if fast else (32, 64)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    t0 = time.perf_counter()
    logits, _ = model.apply(params, tokens)
    logits = logits.reshape(-1, cfg.vocab_size)
    rows = []
    for task in TASK_TYPES:
        target = TABLE_I[pair][task]
        tau = calibrate_temperature(logits, target)
        achieved = _alpha_of_temperature(logits, tau)
        # cross-check under actual Bernoulli accept/reject
        p = jax.nn.softmax(logits, axis=-1)
        q = jax.nn.softmax(logits / tau, axis=-1)
        key = jax.random.PRNGKey(hash(task) % 2**31)
        draft = jax.random.categorical(key, jnp.log(q), axis=-1)
        p_tok = jnp.take_along_axis(p, draft[:, None], 1)[:, 0]
        q_tok = jnp.take_along_axis(q, draft[:, None], 1)[:, 0]
        u = jax.random.uniform(jax.random.fold_in(key, 1), p_tok.shape)
        measured = float(jnp.mean(u < jnp.minimum(1.0, p_tok / q_tok)))
        rows.append({
            "name": f"acceptance/{pair}/{task}",
            "us_per_call": round((time.perf_counter() - t0) * 1e6 / B, 1),
            "derived": (f"target={target:.4f} analytic={achieved:.4f} "
                        f"measured={measured:.4f} tau={tau:.3f}"),
            "target": target, "analytic": achieved, "measured": measured,
        })
    return rows


if __name__ == "__main__":
    for pair in ("llama2", "qwen35"):
        for r in run(pair):
            print(r["name"], r["derived"])
