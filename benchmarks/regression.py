"""Perf-trajectory regression gate over the committed BENCH baselines.

Compares freshly generated BENCH rows (``bench_kernels --smoke --out``,
``bench_churn --smoke --out``, ``bench_gateway --smoke --out``) against the
committed repo-root baselines, metric by metric, with direction-aware
tolerance bands:

  * **quality / structural** metrics (goodput, acceptance, completed,
    n_error, ...) are deterministic at fixed seed or hard invariants —
    they gate ALWAYS;
  * **timing** metrics (us_per_call, GB/s, tokens/s, real-wall TTFT, ...)
    are host-dependent — they gate only when the fresh and baseline
    envelopes report the SAME host (``--strict-timing`` forces gating,
    cross-host they are reported informationally).  Rows that record a
    kernel ``backend`` (bench_kernels) additionally require the SAME
    backend on both sides: a ref-mode baseline is never timing-compared
    against an interpret/pallas fresh run, even under ``--strict-timing``.

Exit status is the number of failed comparisons (0 = pass), so CI can run::

    python -m benchmarks.bench_kernels --smoke --out artifacts/BENCH_kernels.json
    python -m benchmarks.bench_churn   --smoke --out artifacts/BENCH_churn.json
    python -m benchmarks.bench_gateway --smoke --out artifacts/BENCH_gateway.json
    python -m benchmarks.regression --fresh artifacts

With no ``--fresh`` the baselines are compared against themselves — a
schema/selftest pass that fails only if a BENCH file is missing or
malformed.  To accept an intentional perf change, regenerate the baseline
with the bench's ``--smoke`` (no ``--out``) and commit the diff.
"""

from __future__ import annotations

import argparse
import os
import sys

from .common import read_rows_json

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_FILES = ("BENCH_kernels.json", "BENCH_churn.json",
               "BENCH_gateway.json", "BENCH_continuous.json",
               "BENCH_roundpath.json")

# metric -> (better, rel_tol, kind); ``better`` is the GOOD direction, a
# relative move beyond rel_tol in the other direction is a regression.
# kind "timing" gates same-host only; "quality"/"structural" always gate.
METRICS = {
    "us_per_call": ("lower", 0.60, "timing"),
    "ref_us_per_call": ("lower", 0.60, "timing"),
    "compile_ms": ("lower", 1.50, "timing"),
    "ref_compile_ms": ("lower", 1.50, "timing"),
    "gbps": ("higher", 0.40, "timing"),
    "tokens_per_s": ("higher", 0.40, "timing"),
    "wall_s": ("lower", 0.60, "timing"),
    "ttft_s.p50": ("lower", 0.60, "timing"),
    "ttft_s.p95": ("lower", 0.60, "timing"),
    "latency_s.p50": ("lower", 0.60, "timing"),
    "latency_s.p95": ("lower", 0.60, "timing"),
    "goodput_sim_committed": ("higher", 0.40, "timing"),
    "goodput_sim_capped": ("higher", 0.40, "timing"),
    "goodput": ("higher", 0.15, "quality"),
    "acceptance": ("higher", 0.20, "quality"),
    "tokens": ("higher", 0.25, "quality"),
    "ttft_sim_s.p50": ("lower", 0.25, "quality"),
    "ttft_sim_s.p95": ("lower", 0.25, "quality"),
    "ttft_sim_s.p99": ("lower", 0.30, "quality"),
    # longest a servable request sat blocked at the FIFO head (sim seconds)
    "hol_block_max_s": ("lower", 0.50, "quality"),
    # continuous-vs-lockstep comparison (bench_continuous): the whole point
    # of the subsystem — a shrinking gain or growing TTFT ratio regresses it
    "goodput_gain": ("higher", 0.10, "quality"),
    "ttft_p95_ratio": ("lower", 0.15, "quality"),
    # compiled round path (bench_roundpath): steady-state round time and
    # the one-time warmup compile are host-dependent; the speedups are
    # ratios on the same host so they ride the same gate
    "us_per_round": ("lower", 0.60, "timing"),
    "compile_s": ("lower", 1.50, "timing"),
    "speedup_jit": ("higher", 0.50, "timing"),
    "speedup_donate": ("higher", 0.50, "timing"),
    "completed": ("higher", 0.0, "structural"),
    "n_error": ("lower", 0.0, "structural"),
    # compiled-path invariants: ONE host transfer per committed round, zero
    # retraces after warmup, and a bounded traced-shape set — any movement
    # is a structural regression, whatever the host
    "n_host_syncs": ("lower", 0.0, "structural"),
    "retraces": ("lower", 0.0, "structural"),
    "step_shapes": ("lower", 0.0, "structural"),
    # forced-barrier bit-identity and the assembler's retrace bound are
    # hard invariants: any movement fails
    "bit_identical": ("higher", 0.0, "structural"),
    "assembler_shapes": ("lower", 0.0, "structural"),
    "gate_ok": ("higher", 0.0, "structural"),
}


def _flatten(row: dict, prefix: str = "") -> dict:
    out = {}
    for k, v in row.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, prefix=f"{key}."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[key] = float(v)
    return out


def _compare_rows(fname: str, base_row: dict, fresh_row: dict,
                  gate_timing: bool, report: list) -> int:
    """Append comparison lines to ``report``; return failure count."""
    failures = 0
    # rows timed on different kernel backends (e.g. a committed ref-mode
    # baseline vs a fresh interpret/pallas run) are never timing-comparable,
    # whatever the host and even under --strict-timing
    if base_row.get("backend") != fresh_row.get("backend"):
        gate_timing = False
    base = _flatten(base_row)
    fresh = _flatten(fresh_row)
    name = base_row.get("name", "?")
    for metric, (better, tol, kind) in METRICS.items():
        if metric not in base:
            continue
        if metric not in fresh:
            report.append(("FAIL", fname, name, metric,
                           f"metric vanished (baseline {base[metric]:g})"))
            failures += 1
            continue
        b, f = base[metric], fresh[metric]
        if b == 0.0:
            # no relative band at a zero baseline: any move in the bad
            # direction is a regression (covers n_error 0 -> k)
            bad = f > 0 if better == "lower" else f < 0
            delta = f
        else:
            rel = (f - b) / abs(b)
            bad = rel > tol if better == "lower" else rel < -tol
            delta = rel
        gated = kind != "timing" or gate_timing
        status = ("FAIL" if bad and gated
                  else "WARN" if bad else "ok")
        if status != "ok" or abs(delta) > tol / 2:
            report.append((status, fname, name, metric,
                           f"{b:g} -> {f:g} ({delta:+.1%} vs "
                           f"{'+' if better == 'lower' else '-'}{tol:.0%} "
                           f"band{'' if gated else ', cross-host info'})"))
        if status == "FAIL":
            failures += 1
    return failures


def compare_file(fname: str, baseline_dir: str, fresh_dir: str,
                 strict_timing: bool, report: list) -> int:
    base_path = os.path.join(baseline_dir, fname)
    fresh_path = os.path.join(fresh_dir, fname)
    if not os.path.exists(base_path):
        report.append(("skip", fname, "-", "-", "no committed baseline"))
        return 0
    if not os.path.exists(fresh_path):
        report.append(("FAIL", fname, "-", "-",
                       f"fresh rows missing at {fresh_path}"))
        return 1
    base_env, base_rows = read_rows_json(base_path)
    fresh_env, fresh_rows = read_rows_json(fresh_path)
    same_host = (base_env.get("host") is not None
                 and base_env.get("host") == fresh_env.get("host"))
    gate_timing = strict_timing or same_host
    fresh_by_name = {r.get("name"): r for r in fresh_rows}
    failures = 0
    for base_row in base_rows:
        name = base_row.get("name")
        if name not in fresh_by_name:
            report.append(("FAIL", fname, name, "-",
                           "row missing from fresh run"))
            failures += 1
            continue
        failures += _compare_rows(fname, base_row, fresh_by_name[name],
                                  gate_timing, report)
    return failures


def run(baseline_dir: str = REPO_ROOT, fresh_dir: str | None = None,
        strict_timing: bool = False, files=BENCH_FILES) -> int:
    """Total failure count across all BENCH files (0 = gate passes)."""
    fresh_dir = fresh_dir or baseline_dir
    report: list = []
    failures = 0
    for fname in files:
        failures += compare_file(fname, baseline_dir, fresh_dir,
                                 strict_timing, report)
    width = max((len(r[2]) for r in report), default=0)
    for status, fname, name, metric, detail in report:
        print(f"[{status:>4}] {fname}: {name:<{width}} {metric}: {detail}")
    checked = sum(1 for r in report if r[0] != "skip")
    print(f"regression gate: {failures} failure(s) "
          f"({checked} notable comparisons reported)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=REPO_ROOT, metavar="DIR",
                    help="directory of committed BENCH baselines "
                         "(default: repo root)")
    ap.add_argument("--fresh", default=None, metavar="DIR",
                    help="directory of freshly generated BENCH files "
                         "(default: the baseline dir — a schema selftest)")
    ap.add_argument("--strict-timing", action="store_true",
                    help="gate timing metrics even across hosts")
    args = ap.parse_args()
    sys.exit(min(run(args.baseline, args.fresh, args.strict_timing), 125))


if __name__ == "__main__":
    main()
