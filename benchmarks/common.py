"""Shared benchmark infrastructure.

Latency-constant calibration (DESIGN.md §7): the paper measures T_k^S on an
Apple M4 Pro and T_ver on an A100; neither exists here.  We calibrate
(T_S, T_fix, T_lin) per model pair so the analytic Fig.-6 operating point
matches the paper's reported goodputs, then reuse the constants everywhere.
Trends and gains are structural — constants only set the scale.
"""

from __future__ import annotations

import dataclasses
import datetime
import json
import os
import platform
import subprocess

import numpy as np

from repro.api import CellConfig, MultiSpinCell, Request
from repro.core.channel import ChannelConfig, ChannelState
from repro.training.data import TABLE_I

EXPERIMENTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")
CALIB_PATH = os.path.join(EXPERIMENTS_DIR, "calibration.json")

# Paper Fig. 6 targets [tokens/s]
FIG6_TARGETS = {
    "llama2": {"multi": 145.0, "cen": 145.0 / 2.5, "p2p": 145.0 / 4.6},
    "qwen35": {"multi": 153.0, "cen": 153.0 / 3.0, "p2p": 153.0 / 4.0},
}

K_DEFAULT = 20


def paper_channel(pair: str) -> ChannelConfig:
    vocab = 32000 if pair == "llama2" else 151936
    return ChannelConfig(vocab_size=vocab)


def paper_devices(pair: str, K: int, rng: np.random.Generator):
    """Heterogeneous device profiles per paper Sec. VI-A: task mixture ->
    Table-I alphas; T_S scaled by U[0.85, 1.15]."""
    alphas_by_task = TABLE_I[pair]
    tasks = rng.choice(list(alphas_by_task), K)
    alphas = np.array([alphas_by_task[t] for t in tasks])
    return tasks, alphas


def cell_plan(scheme: str, channel: ChannelConfig, t_fix: float, t_lin: float,
              alphas: np.ndarray, t_dev: np.ndarray, ch: ChannelState,
              scheme_params: dict | None = None, L_max: int = 25,
              pipelined: bool = False, **cfg_kw):
    """Plan one round through a ``MultiSpinCell`` at a RECORDED channel
    realization — the registry-backed replacement for calling a solver
    directly.  Devices are (alpha, T_S) rows; ``ch`` is replayed via
    ``load_channel`` so the plan sees bit-identical rates to a direct
    solve.  Returns the ``RoundPlan`` (or the pipelined plan dict)."""
    K = len(alphas)
    cfg = CellConfig(scheme=scheme, scheme_params=scheme_params or {},
                     channel=channel, t_ver_fix=t_fix, t_ver_lin=t_lin,
                     L_max=L_max, max_batch=K, **cfg_kw)
    cell = MultiSpinCell(cfg)
    for i in range(K):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=10 ** 9,
                            alpha=float(alphas[i]), T_S=float(t_dev[i])))
    cell.load_channel(ch)
    if pipelined:
        return cell.plan_pipelined(refade=False)
    return cell.plan(refade=False)


def channel_slice(ch: ChannelState, idx) -> ChannelState:
    """Device-subset view of a recorded fading block (e.g. the P2P user)."""
    return ChannelState(cfg=ch.cfg, avg_gains=np.asarray(ch.avg_gains)[idx],
                        gains=np.asarray(ch.gains)[idx],
                        rates=np.asarray(ch.rates)[idx])


def planned_cell_goodput(scheme: str, pair: str, K: int, seed: int,
                         calib: dict, B_hz: float | None = None) -> float:
    """Analytic goodput of one planned round for a freshly sampled
    ``MultiSpinCell`` at the paper's device mixture — the shared recipe of
    the Fig.-7/8 sweeps (``B_hz`` overrides the channel's total budget)."""
    rng = np.random.default_rng(seed)
    tasks, alphas = paper_devices(pair, K, rng)
    t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
    channel = paper_channel(pair)
    if B_hz is not None:
        channel = dataclasses.replace(channel, total_bandwidth_hz=B_hz)
    cfg = CellConfig(scheme=scheme, channel=channel,
                     t_ver_fix=calib["t_fix"], t_ver_lin=calib["t_lin"],
                     L_max=25, max_batch=K, seed=seed)
    cell = MultiSpinCell(cfg)
    for i in range(K):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=10 ** 9,
                            alpha=float(alphas[i]), T_S=float(t_dev[i]),
                            task=str(tasks[i])))
    return cell.plan().goodput


def _fig6_predict(pair: str, T_S: float, t_fix: float, t_lin: float,
                  K: int = K_DEFAULT, n_seeds: int = 4) -> dict:
    """Analytic goodput of the three protocols at the paper's settings,
    every one planned through the registered schemes + ``MultiSpinCell``
    (the recorded channel is replayed, so the numbers are bit-identical to
    the direct solver calls this replaced)."""
    cfg = paper_channel(pair)
    out = {"multi": [], "cen": [], "p2p": []}
    for seed in range(n_seeds):
        rng = np.random.default_rng(seed)
        tasks, alphas = paper_devices(pair, K, rng)
        ch = ChannelState.sample(cfg, K, rng)
        t_dev = rng.uniform(0.85, 1.15, K) * T_S
        out["multi"].append(
            cell_plan("hete", cfg, t_fix, t_lin, alphas, t_dev, ch).goodput)
        # Cen-SPIN: server drafts with batched SLM (A100-class, affine in K;
        # CellConfig's default t_draft model is exactly this convention)
        out["cen"].append(
            cell_plan("cen", cfg, t_fix, t_lin, alphas, t_dev, ch).goodput)
        # P2P: one device, full bandwidth
        out["p2p"].append(
            cell_plan("p2p", cfg, t_fix, t_lin, alphas[:1], t_dev[:1],
                      channel_slice(ch, slice(0, 1))).goodput)
    return {k: float(np.mean(v)) for k, v in out.items()}


def calibrate_pair(pair: str, n_iter: int = 400, seed: int = 0) -> dict:
    """Random search over (T_S, T_fix, T_lin) minimizing relative error to
    the Fig.-6 targets."""
    rng = np.random.default_rng(seed)
    targets = FIG6_TARGETS[pair]

    def score(T_S, t_fix, t_lin, n_seeds=2):
        pred = _fig6_predict(pair, T_S, t_fix, t_lin, n_seeds=n_seeds)
        return sum((pred[k] / targets[k] - 1.0) ** 2 for k in targets), pred

    best = None
    for _ in range(n_iter):
        T_S = rng.uniform(0.01, 0.08)
        t_fix = rng.uniform(0.02, 0.5)
        t_lin = rng.uniform(0.001, 0.02)
        err, pred = score(T_S, t_fix, t_lin)
        if best is None or err < best["err"]:
            best = {"T_S": T_S, "t_fix": t_fix, "t_lin": t_lin, "err": err,
                    "pred": pred}
    # local refinement around the incumbent
    for _ in range(n_iter // 2):
        T_S = best["T_S"] * rng.uniform(0.8, 1.25)
        t_fix = best["t_fix"] * rng.uniform(0.8, 1.25)
        t_lin = best["t_lin"] * rng.uniform(0.8, 1.25)
        err, pred = score(T_S, t_fix, t_lin)
        if err < best["err"]:
            best = {"T_S": T_S, "t_fix": t_fix, "t_lin": t_lin, "err": err,
                    "pred": pred}
    best["err"], best["pred"] = score(best["T_S"], best["t_fix"],
                                      best["t_lin"], n_seeds=6)
    best["targets"] = targets
    return best


def load_calibration(force: bool = False) -> dict:
    if os.path.exists(CALIB_PATH) and not force:
        with open(CALIB_PATH) as f:
            return json.load(f)
    os.makedirs(EXPERIMENTS_DIR, exist_ok=True)
    calib = {pair: calibrate_pair(pair) for pair in ("llama2", "qwen35")}
    with open(CALIB_PATH, "w") as f:
        json.dump(calib, f, indent=2)
    return calib


def fmt_rows(rows: list[dict]) -> str:
    """CSV lines: name,us_per_call,derived"""
    out = []
    for r in rows:
        out.append(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
    return "\n".join(out)


def _jsonable(obj):
    """Recursive numpy -> python conversion for benchmark row dumps."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    return obj


def _git_sha() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


def write_rows_json(path: str, rows: list[dict]) -> None:
    """Dump benchmark rows as JSON (CI uploads these as workflow artifacts;
    ``benchmarks/regression.py`` diffs them against committed baselines).

    Schema v2: a uniform envelope — ``schema_version`` / ``generated_utc`` /
    ``git_sha`` / ``host`` / ``rows`` — stamped on every BENCH file so the
    regression gate can tell which rows are comparable (timing metrics only
    gate against same-host baselines).  Empty-string and ``None`` fields are
    dropped from rows: the old ``"us_per_call": ""`` placeholders carried no
    information and broke uniformity between timing and structural benches.
    """
    rows = [{k: v for k, v in _jsonable(r).items() if v not in ("", None)}
            for r in rows]
    doc = {
        "schema_version": 2,
        "generated_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
        "host": platform.node(),
        "rows": rows,
    }
    doc = {k: v for k, v in doc.items() if v not in ("", None)}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"wrote {path}")


def read_rows_json(path: str) -> tuple[dict, list[dict]]:
    """(envelope, rows) for a BENCH file of either schema: v2 envelopes
    come back verbatim; legacy v1 bare-list files get a synthetic
    ``{"schema_version": 1}`` envelope (no host/sha — the regression gate
    treats their timing rows as cross-host)."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):
        return {"schema_version": 1}, doc
    return doc, list(doc.get("rows", []))
