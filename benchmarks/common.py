"""Shared benchmark infrastructure.

Latency-constant calibration (DESIGN.md §7): the paper measures T_k^S on an
Apple M4 Pro and T_ver on an A100; neither exists here.  We calibrate
(T_S, T_fix, T_lin) per model pair so the analytic Fig.-6 operating point
matches the paper's reported goodputs, then reuse the constants everywhere.
Trends and gains are structural — constants only set the scale.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.api import CellConfig, MultiSpinCell, Request
from repro.core.channel import ChannelConfig, ChannelState
from repro.core.draft_control import (
    solve_centralized,
    solve_heterogeneous,
    solve_p2p,
)
from repro.training.data import TABLE_I

EXPERIMENTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")
CALIB_PATH = os.path.join(EXPERIMENTS_DIR, "calibration.json")

# Paper Fig. 6 targets [tokens/s]
FIG6_TARGETS = {
    "llama2": {"multi": 145.0, "cen": 145.0 / 2.5, "p2p": 145.0 / 4.6},
    "qwen35": {"multi": 153.0, "cen": 153.0 / 3.0, "p2p": 153.0 / 4.0},
}

K_DEFAULT = 20


def paper_channel(pair: str) -> ChannelConfig:
    vocab = 32000 if pair == "llama2" else 151936
    return ChannelConfig(vocab_size=vocab)


def paper_devices(pair: str, K: int, rng: np.random.Generator):
    """Heterogeneous device profiles per paper Sec. VI-A: task mixture ->
    Table-I alphas; T_S scaled by U[0.85, 1.15]."""
    alphas_by_task = TABLE_I[pair]
    tasks = rng.choice(list(alphas_by_task), K)
    alphas = np.array([alphas_by_task[t] for t in tasks])
    return tasks, alphas


def planned_cell_goodput(scheme: str, pair: str, K: int, seed: int,
                         calib: dict, B_hz: float | None = None) -> float:
    """Analytic goodput of one planned round for a freshly sampled
    ``MultiSpinCell`` at the paper's device mixture — the shared recipe of
    the Fig.-7/8 sweeps (``B_hz`` overrides the channel's total budget)."""
    rng = np.random.default_rng(seed)
    tasks, alphas = paper_devices(pair, K, rng)
    t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
    channel = paper_channel(pair)
    if B_hz is not None:
        channel = dataclasses.replace(channel, total_bandwidth_hz=B_hz)
    cfg = CellConfig(scheme=scheme, channel=channel,
                     t_ver_fix=calib["t_fix"], t_ver_lin=calib["t_lin"],
                     L_max=25, max_batch=K, seed=seed)
    cell = MultiSpinCell(cfg)
    for i in range(K):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=10 ** 9,
                            alpha=float(alphas[i]), T_S=float(t_dev[i]),
                            task=str(tasks[i])))
    return cell.plan().goodput


def _fig6_predict(pair: str, T_S: float, t_fix: float, t_lin: float,
                  K: int = K_DEFAULT, n_seeds: int = 4) -> dict:
    """Analytic goodput of the three protocols at the paper's settings."""
    cfg = paper_channel(pair)
    Q = cfg.q_tok_bits
    B = cfg.total_bandwidth_hz
    out = {"multi": [], "cen": [], "p2p": []}
    for seed in range(n_seeds):
        rng = np.random.default_rng(seed)
        tasks, alphas = paper_devices(pair, K, rng)
        ch = ChannelState.sample(cfg, K, rng)
        t_dev = rng.uniform(0.85, 1.15, K) * T_S
        T_ver = t_fix + K * t_lin
        hete = solve_heterogeneous(alphas, t_dev, ch.rates, Q, B, T_ver, L_max=25)
        out["multi"].append(hete.goodput)
        # Cen-SPIN: server drafts with batched SLM (A100-class, affine in K)
        cen = solve_centralized(alphas, T_ver, t_fix * 0.15, t_lin * 0.6,
                                L_max=25)
        out["cen"].append(cen.goodput)
        # P2P: one device, full bandwidth
        p2p = solve_p2p(alphas[0], t_dev[0], ch.rates[0], Q, B,
                        t_fix + t_lin, L_max=25)
        out["p2p"].append(p2p.goodput)
    return {k: float(np.mean(v)) for k, v in out.items()}


def calibrate_pair(pair: str, n_iter: int = 400, seed: int = 0) -> dict:
    """Random search over (T_S, T_fix, T_lin) minimizing relative error to
    the Fig.-6 targets."""
    rng = np.random.default_rng(seed)
    targets = FIG6_TARGETS[pair]

    def score(T_S, t_fix, t_lin, n_seeds=2):
        pred = _fig6_predict(pair, T_S, t_fix, t_lin, n_seeds=n_seeds)
        return sum((pred[k] / targets[k] - 1.0) ** 2 for k in targets), pred

    best = None
    for _ in range(n_iter):
        T_S = rng.uniform(0.01, 0.08)
        t_fix = rng.uniform(0.02, 0.5)
        t_lin = rng.uniform(0.001, 0.02)
        err, pred = score(T_S, t_fix, t_lin)
        if best is None or err < best["err"]:
            best = {"T_S": T_S, "t_fix": t_fix, "t_lin": t_lin, "err": err,
                    "pred": pred}
    # local refinement around the incumbent
    for _ in range(n_iter // 2):
        T_S = best["T_S"] * rng.uniform(0.8, 1.25)
        t_fix = best["t_fix"] * rng.uniform(0.8, 1.25)
        t_lin = best["t_lin"] * rng.uniform(0.8, 1.25)
        err, pred = score(T_S, t_fix, t_lin)
        if err < best["err"]:
            best = {"T_S": T_S, "t_fix": t_fix, "t_lin": t_lin, "err": err,
                    "pred": pred}
    best["err"], best["pred"] = score(best["T_S"], best["t_fix"],
                                      best["t_lin"], n_seeds=6)
    best["targets"] = targets
    return best


def load_calibration(force: bool = False) -> dict:
    if os.path.exists(CALIB_PATH) and not force:
        with open(CALIB_PATH) as f:
            return json.load(f)
    os.makedirs(EXPERIMENTS_DIR, exist_ok=True)
    calib = {pair: calibrate_pair(pair) for pair in ("llama2", "qwen35")}
    with open(CALIB_PATH, "w") as f:
        json.dump(calib, f, indent=2)
    return calib


def fmt_rows(rows: list[dict]) -> str:
    """CSV lines: name,us_per_call,derived"""
    out = []
    for r in rows:
        out.append(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
    return "\n".join(out)
