"""Fig. 8: sum goodput vs number of devices.

Claims checked: Hete-Multi-SPIN scales favourably while Fixed BW&L saturates;
the Hete-over-Fixed gain WIDENS with K (paper: 21%->67% llama2, 29%->80%+
qwen at K=24).

Each (pair, K, scheme, seed) point is one ``MultiSpinCell`` built from a
``CellConfig``; the cell samples its own channel and the registry resolves
the scheme solver — no hand-wired controller/solver glue.
"""

from __future__ import annotations

import numpy as np

from .common import load_calibration, planned_cell_goodput

K_RANGE = [4, 8, 12, 16, 20, 24]
SCHEMES = ("hete", "fixed")


def run(fast: bool = True) -> list[dict]:
    rows = []
    # the cell samples its own channel stream, so the fast mode needs a few
    # more seeds than the legacy solver-wired version for stable gain trends
    n_seeds = 10 if fast else 20
    for pair in ("llama2", "qwen35"):
        calib = load_calibration()[pair]
        gains = {}
        for K in K_RANGE:
            m = {s: float(np.mean(
                    [planned_cell_goodput(s, pair, K, seed, calib)
                     for seed in range(n_seeds)]))
                 for s in SCHEMES}
            gains[K] = m["hete"] / m["fixed"] - 1.0
            rows.append({
                "name": f"scaling_K/{pair}/K={K}",
                "us_per_call": "",
                "derived": (f"hete={m['hete']:.1f} fixed={m['fixed']:.1f} "
                            f"gain={100 * gains[K]:.0f}%"),
                **m,
            })
        rows.append({
            "name": f"scaling_K/{pair}/summary",
            "us_per_call": "",
            "derived": (f"gain_K={K_RANGE[0]}: {100 * gains[K_RANGE[0]]:.0f}% -> "
                        f"gain_K={K_RANGE[-1]}: {100 * gains[K_RANGE[-1]]:.0f}% "
                        f"widens={gains[K_RANGE[-1]] > gains[K_RANGE[0]]}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
