"""Fig. 8: sum goodput vs number of devices.

Claims checked: Hete-Multi-SPIN scales favourably while Fixed BW&L saturates;
the Hete-over-Fixed gain WIDENS with K (paper: 21%->67% llama2, 29%->80%+
qwen at K=24).
"""

from __future__ import annotations

import numpy as np

from repro.core.channel import ChannelState
from repro.core.draft_control import solve_fixed, solve_heterogeneous

from .common import load_calibration, paper_channel, paper_devices

K_RANGE = [4, 8, 12, 16, 20, 24]


def run(fast: bool = True) -> list[dict]:
    rows = []
    n_seeds = 3 if fast else 10
    for pair in ("llama2", "qwen35"):
        calib = load_calibration()[pair]
        cfg = paper_channel(pair)
        Q, B = cfg.q_tok_bits, cfg.total_bandwidth_hz
        gains = {}
        for K in K_RANGE:
            acc = {"hete": [], "fixed": []}
            T_ver = calib["t_fix"] + K * calib["t_lin"]
            for seed in range(n_seeds):
                rng = np.random.default_rng(seed)
                tasks, alphas = paper_devices(pair, K, rng)
                ch = ChannelState.sample(cfg, K, rng)
                t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
                kw = dict(T_S=t_dev, r=ch.rates, Q_tok=Q, B=B, T_ver=T_ver)
                acc["hete"].append(
                    solve_heterogeneous(alphas, L_max=25, **kw).goodput)
                acc["fixed"].append(solve_fixed(alphas, **kw).goodput)
            m = {s: float(np.mean(v)) for s, v in acc.items()}
            gains[K] = m["hete"] / m["fixed"] - 1.0
            rows.append({
                "name": f"scaling_K/{pair}/K={K}",
                "us_per_call": "",
                "derived": (f"hete={m['hete']:.1f} fixed={m['fixed']:.1f} "
                            f"gain={100 * gains[K]:.0f}%"),
                **m,
            })
        rows.append({
            "name": f"scaling_K/{pair}/summary",
            "us_per_call": "",
            "derived": (f"gain_K={K_RANGE[0]}: {100 * gains[K_RANGE[0]]:.0f}% -> "
                        f"gain_K={K_RANGE[-1]}: {100 * gains[K_RANGE[-1]]:.0f}% "
                        f"widens={gains[K_RANGE[-1]] > gains[K_RANGE[0]]}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
