"""Fig. 5: batched verification latency vs batch size K, with affine fit.

Measured by wall-clock on THIS backend (CPU stand-in for the A100): one
batched forward_window of the smoke-scale target model at K = 1..K_max, then
a least-squares fit of T_ver(K) = T_fix + K*T_lin.  The claim under test is
the affine structure (R^2), not the absolute milliseconds.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def run(fast: bool = True) -> list[dict]:
    rows = []
    for pair, target_arch in (("llama2", "llama2-7b"), ("qwen35", "qwen3.5-27b")):
        cfg = get_config(target_arch).smoke().replace(num_layers=4, d_model=128,
                                                      num_heads=4, num_kv_heads=2,
                                                      head_dim=32, d_ff=256)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        L = 8
        Ks = [1, 2, 4, 8, 12, 16] if fast else [1, 2, 4, 8, 12, 16, 20, 24]
        lat = []
        for K in Ks:
            cache = model.init_cache(K, 64, jnp.float32)
            tokens = jax.random.randint(jax.random.PRNGKey(1), (K, 16), 0,
                                        cfg.vocab_size)
            _, cache, _ = model.prefill(params, tokens, cache)
            window = jax.random.randint(jax.random.PRNGKey(2), (K, L + 1), 0,
                                        cfg.vocab_size)
            pos = jnp.full((K,), 16, jnp.int32)
            step = jax.jit(lambda p, w, c, q: model.forward_window(p, w, c, q)[0])
            step(params, window, cache, pos).block_until_ready()  # compile
            n_rep = 5
            t0 = time.perf_counter()
            for _ in range(n_rep):
                step(params, window, cache, pos).block_until_ready()
            lat.append((time.perf_counter() - t0) / n_rep)
        Ks_np = np.array(Ks, float)
        lat_np = np.array(lat)
        A = np.stack([np.ones_like(Ks_np), Ks_np], axis=1)
        (t_fix, t_lin), res, *_ = np.linalg.lstsq(A, lat_np, rcond=None)
        ss_tot = np.sum((lat_np - lat_np.mean()) ** 2)
        r2 = 1 - (res[0] / ss_tot if len(res) and ss_tot > 0 else 0.0)
        for K, l in zip(Ks, lat):
            rows.append({"name": f"tver_vs_K/{pair}/K={K}",
                         "us_per_call": round(l * 1e6, 1),
                         "derived": f"latency={l * 1e3:.2f}ms"})
        rows.append({
            "name": f"tver_vs_K/{pair}/fit",
            "us_per_call": "",
            "derived": (f"T_fix={t_fix * 1e3:.2f}ms T_lin={t_lin * 1e3:.3f}ms "
                        f"R2={r2:.4f} affine_ok={r2 > 0.9}"),
            "r2": float(r2),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
