"""Per-kernel microbenchmarks: every Pallas op vs its jnp oracle.

For each kernel in ``repro.kernels.ops`` this times the jit'd public op
(which dispatches Pallas / interpreter / oracle per ``REPRO_KERNELS``) and
the jit'd ``ref.py`` oracle on identical inputs, with JAX-correct timing:
the first call is measured separately (compile + run), the steady-state
loop only calls ``block_until_ready`` once at the end so async dispatch
pipelines, and us/call comes from the loop.  Each row also estimates moved
bytes (inputs + outputs) and reports GB/s — dispatch-level numbers on CPU,
kernel-level on a real accelerator.

``--smoke`` uses tiny interpret-safe shapes and writes the tracked
``BENCH_kernels.json`` baseline at the repo root (``--out`` redirects it,
which is how CI writes fresh rows into ``artifacts/`` without clobbering
the committed baseline that ``benchmarks/regression.py`` diffs against).

Usage::

    PYTHONPATH=src python -m benchmarks.bench_kernels              # fast
    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke      # CI rows
    REPRO_KERNELS=interpret PYTHONPATH=src \
        python -m benchmarks.bench_kernels --smoke                 # Pallas path
"""

from __future__ import annotations

import argparse
import os
import time

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kernels.json")


def _tree_bytes(tree) -> int:
    import jax
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(tree)
               if hasattr(leaf, "nbytes"))


def _time(fn, args, iters: int):
    """(first_call_s, steady_s_per_call, out) with async-dispatch-correct
    boundaries: one sync after the first call, one after the whole loop."""
    import jax

    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args))
    first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return first_s, (time.perf_counter() - t0) / iters, out


def _cases(smoke: bool):
    """[(name, op_fn, oracle_fn, args)] — op and oracle share signatures."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    if smoke:
        B, S, H, KV, D = 2, 64, 4, 2, 16
        T, N, V = 4, 32, 512
        b, s, h, p, g, n, chunk = 1, 64, 4, 16, 1, 16, 32
    else:
        B, S, H, KV, D = 4, 512, 8, 4, 64
        T, N, V = 8, 256, 32000
        b, s, h, p, g, n, chunk = 2, 512, 8, 64, 2, 64, 64
    ps = 16
    n_slots = S // ps
    keys = iter(jax.random.split(jax.random.PRNGKey(0), 32))

    def rnd(*shape):
        return jax.random.normal(next(keys), shape, jnp.float32)

    q_pre = rnd(B, S, H, D)
    k_pre, v_pre = rnd(B, S, KV, D), rnd(B, S, KV, D)
    q_dec = rnd(B, H, D)
    lengths = jnp.full((B,), S // 2, jnp.int32)
    kq, ks, vq, vs = ref.quantize_kv(k_pre, v_pre)
    # paged view: row i of the pool pair holds page i of stream i // n_slots
    P = B * n_slots
    k_pool = k_pre.reshape(P, ps, KV, D)
    v_pool = v_pre.reshape(P, ps, KV, D)
    page_table = jnp.arange(P, dtype=jnp.int32).reshape(B, n_slots)
    q_win = rnd(B, T, H, D)
    win_lengths = jnp.full((B,), S // 2 - T, jnp.int32)
    win_mask = jnp.broadcast_to(jnp.tril(jnp.ones((T, T), bool)), (B, T, T))
    logits = rnd(N, V)
    token_ids = jax.random.randint(next(keys), (N,), 0, V)
    p_rows = jax.nn.softmax(rnd(N, V), axis=-1)
    q_rows = jax.nn.softmax(rnd(N, V), axis=-1)
    u = jax.random.uniform(next(keys), (N,))
    x_ssd = rnd(b, s, h, p)
    dt = jax.nn.softplus(rnd(b, s, h))
    A = -jnp.exp(rnd(h))
    B_ssd, C_ssd = rnd(b, s, g, n), rnd(b, s, g, n)
    # fused verify+sample: drafts really drawn from the uploaded truncated
    # distribution so the accept test is exercised at realistic rates
    from repro.core.verification import truncate_renormalize
    vhat = 16
    f_q = jax.nn.softmax(rnd(B, T, V), axis=-1)
    fq_idx, fq_val = truncate_renormalize(f_q.reshape(B * T, V), vhat)
    fq_idx = fq_idx.reshape(B, T, vhat)
    fq_val = fq_val.reshape(B, T, vhat)
    f_j = jax.random.categorical(next(keys),
                                 jnp.log(jnp.maximum(fq_val, 1e-30)))
    f_toks = jnp.take_along_axis(fq_idx, f_j[..., None], -1)[..., 0]
    f_probs = jnp.take_along_axis(fq_val, f_j[..., None], -1)[..., 0]
    f_logits = rnd(B, T + 1, V)
    f_uacc = jax.random.uniform(next(keys), (B, T))
    f_ures = jax.random.uniform(next(keys), (B,))
    f_dlen = jnp.full((B,), T, jnp.int32)

    return [
        ("flash_attention", ops.flash_attention, ref.flash_attention_ref,
         (q_pre, k_pre, v_pre)),
        ("decode_attention", ops.decode_attention, ref.decode_attention_ref,
         (q_dec, k_pre, v_pre, lengths)),
        ("decode_attention_q8", ops.decode_attention_q8,
         ref.decode_attention_quantized_ref,
         (q_dec, kq, vq, ks, vs, lengths)),
        ("paged_attention", ops.paged_attention, ref.paged_attention_ref,
         (q_win, k_pool, v_pool, page_table, win_lengths)),
        ("tree_attention", ops.tree_attention, ref.tree_attention_ref,
         (q_win, k_pre, v_pre, win_lengths, win_mask)),
        ("paged_tree_attention", ops.paged_tree_attention,
         ref.paged_tree_attention_ref,
         (q_win, k_pool, v_pool, page_table, win_lengths, win_mask)),
        ("gather_softmax_prob", ops.gather_softmax_prob,
         ref.gather_softmax_prob_ref, (logits, token_ids)),
        ("residual_sample", ops.residual_sample, ref.residual_sample_ref,
         (p_rows, q_rows, u)),
        ("fused_verify_sample", ops.fused_verify_sample,
         ref.fused_verify_sample_ref,
         (f_logits, f_toks, f_probs, fq_idx, fq_val, f_uacc, f_ures,
          f_dlen)),
        ("ssd_scan",
         lambda x_, dt_, A_, B_, C_: ops.ssd_scan(x_, dt_, A_, B_, C_,
                                                  chunk=chunk),
         lambda x_, dt_, A_, B_, C_: ref.ssd_scan_ref(x_, dt_, A_, B_, C_,
                                                      chunk=chunk),
         (x_ssd, dt, A, B_ssd, C_ssd)),
    ]


def run(fast: bool = True, smoke: bool = False, mode: str | None = None,
        iters: int | None = None, out_path: str | None = None) -> list[dict]:
    if mode is not None:
        os.environ["REPRO_KERNELS"] = mode
    import jax

    from repro.kernels.ops import kernel_mode

    backend = kernel_mode()
    if iters is None:
        iters = 10 if (smoke or fast) else 50
        if backend == "interpret":
            iters = min(iters, 3)   # the Pallas interpreter is slow
    rows = []
    for name, op_fn, ref_fn, args in _cases(smoke or fast):
        jop = jax.jit(op_fn)
        jref = jax.jit(ref_fn)
        first_s, steady_s, out = _time(jop, args, iters)
        ref_first_s, ref_steady_s, _ = _time(jref, args, iters)
        moved = _tree_bytes(args) + _tree_bytes(out)
        gbps = moved / steady_s / 1e9 if steady_s > 0 else 0.0
        rows.append({
            "name": f"kernels/{name}",
            "backend": backend,
            "us_per_call": steady_s * 1e6,
            "compile_ms": max(first_s - steady_s, 0.0) * 1e3,
            "ref_us_per_call": ref_steady_s * 1e6,
            "ref_compile_ms": max(ref_first_s - ref_steady_s, 0.0) * 1e3,
            "gbps": gbps,
            "bytes_moved": int(moved),
            "iters": iters,
            "lead_shape": list(args[0].shape),
            "derived": (f"us={steady_s * 1e6:.1f} "
                        f"ref_us={ref_steady_s * 1e6:.1f} "
                        f"compile_ms={max(first_s - steady_s, 0.0) * 1e3:.1f} "
                        f"gbps={gbps:.2f} backend={backend}"),
        })
    if smoke:
        from .common import write_rows_json
        write_rows_json(out_path or BENCH_PATH, rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--mode", default=None,
                    choices=("auto", "pallas", "ref", "interpret"),
                    help="force the kernel dispatch path (sets "
                         "REPRO_KERNELS for this process)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny interpret-safe shapes; writes the tracked "
                         "BENCH_kernels.json rows")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", type=str, default=None, metavar="PATH",
                    help="where --smoke writes its rows (default: the "
                         "committed repo-root BENCH_kernels.json; CI points "
                         "this at artifacts/ so baselines stay untouched)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="dump rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = run(fast=not args.full, smoke=args.smoke, mode=args.mode,
               iters=args.iters, out_path=args.out)
    for r in rows:
        print(r["name"], r["derived"])
    if args.json:
        from .common import write_rows_json
        write_rows_json(args.json, rows)


if __name__ == "__main__":
    main()
