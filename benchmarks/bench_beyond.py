"""Beyond-paper optimizations: packed ragged verification + pipelined rounds.

Compares, at the paper's Fig-6 operating point and across K:
  baseline   — paper-faithful Hete-Multi-SPIN (constant T_ver(K))
  packed     — token-budget T_ver + ragged packing (no zero-pad compute)
  pipelined  — two half-batches overlapping draft/upload with verification
  packed+pipe — both
  multidraft — joint (L, J) optimum (J drafts per device, keep the longest)

Every variant is a registered scheme planned through ``MultiSpinCell``
(``cell_plan`` replays the recorded fading block; ``pipelined=True`` uses
the cell's two-half-batch planner) — no solver is constructed directly.
The baseline/packed comparison uses the SAME token-budget verifier with
padded vs packed accounting, so the packing gain is not an artifact of the
verifier refinement.

``--engine`` additionally RUNS the ``multidraft`` scheme on a real paged
``SpecEngine`` (token-tree verification, J > 1 drafts per device committed
by longest accepted root-to-leaf path) — the analytic J dimension served
end to end.  ``--smoke`` is the CI gate for that path; ``--json PATH``
dumps the rows as a workflow artifact.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_beyond            # analytic
    PYTHONPATH=src python -m benchmarks.bench_beyond --engine
    PYTHONPATH=src python -m benchmarks.bench_beyond --smoke    # CI gate
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.channel import ChannelState

from .common import (
    cell_plan,
    load_calibration,
    paper_channel,
    paper_devices,
    write_rows_json,
)


def run(fast: bool = True) -> list[dict]:
    rows = []
    n_seeds = 3 if fast else 8
    for pair in ("llama2", "qwen35"):
        calib = load_calibration()[pair]
        cfg = paper_channel(pair)
        t_fix, t_lin = calib["t_fix"], calib["t_lin"]
        for K in (8, 20):
            acc = {"paper": [], "padded_tb": [], "packed": [], "pipelined": [],
                   "packed_pipe": []}
            for seed in range(n_seeds):
                rng = np.random.default_rng(seed)
                _, alphas = paper_devices(pair, K, rng)
                ch = ChannelState.sample(cfg, K, rng)
                t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]

                def plan(scheme, pipelined=False):
                    return cell_plan(scheme, cfg, t_fix, t_lin, alphas,
                                     t_dev, ch, pipelined=pipelined)

                acc["paper"].append(plan("hete").goodput)
                acc["padded_tb"].append(
                    plan("hete-padded-tokenbudget").goodput)
                acc["packed"].append(plan("hete-packed").goodput)
                acc["pipelined"].append(
                    plan("hete", pipelined=True)["goodput"])
                acc["packed_pipe"].append(
                    plan("hete-packed", pipelined=True)["goodput"])
            m = {k: float(np.mean(v)) for k, v in acc.items()}
            # multi-draft (L, J) joint optimum in the uniform regime
            rng = np.random.default_rng(0)
            _, alphas = paper_devices(pair, K, rng)
            t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
            ch = ChannelState.sample(cfg, K, rng)
            md = cell_plan("multidraft", cfg, t_fix, t_lin, alphas, t_dev, ch)
            m["multidraft"] = md.goodput
            m["multidraft_J"] = md.draft_width
            rows.append({
                "name": f"beyond/{pair}/K={K}",
                "us_per_call": "",
                "derived": (f"paper={m['paper']:.1f} "
                            f"padded_tb={m['padded_tb']:.1f} "
                            f"packed={m['packed']:.1f} "
                            f"(+{100 * (m['packed'] / m['padded_tb'] - 1):.0f}% "
                            f"vs padded) pipelined={m['pipelined']:.1f} "
                            f"(+{100 * (m['pipelined'] / m['paper'] - 1):.0f}%) "
                            f"both={m['packed_pipe']:.1f} "
                            f"(+{100 * (m['packed_pipe'] / m['paper'] - 1):.0f}%) "
                            f"multidraft_LJ={m['multidraft']:.1f} "
                            f"(J*={m['multidraft_J']})"),
                **m,
            })
    return rows


def run_engine(rounds: int = 10, K: int = 3, J_min: int = 2, J_max: int = 3,
               L_max: int = 6, seed: int = 0) -> list[dict]:
    """Serve the ``multidraft`` scheme on a REAL paged ``SpecEngine``:
    every round drafts J sequences per device, packs them into a token
    tree, verifies the whole tree in one ancestor-masked target pass, and
    commits the longest accepted root-to-leaf path.  ``J_min=2`` pins the
    plan to true multi-draft widths so the tree path cannot silently
    degenerate to sequential verification.

    The workload runs twice — once with the default scatter-commit, once
    with ``tree_commit="repair"`` — under a span tracer: the scatter run
    must emit NO ``engine.cache_repair`` spans (the repair forward is
    eliminated from the hot path) while committing bit-identical tokens."""
    import jax

    from repro.api import CellConfig, MultiSpinCell, Request
    from repro.configs import get_config
    from repro.obs import trace
    from repro.serving import SpecEngine
    from repro.serving.backends import EngineBackend

    def serve(tree_commit: str):
        tcfg = get_config("qwen2.5-3b").smoke()
        dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2,
                            num_kv_heads=1, head_dim=16, d_ff=64,
                            name="draft-smoke")
        eng = SpecEngine(tcfg, dcfg, max_len=160, cache_kind="paged",
                         num_pages=2 * K * (160 // 16),
                         tree_commit=tree_commit)
        eng.init_params(jax.random.PRNGKey(seed))
        prompts = jax.random.randint(jax.random.PRNGKey(seed + 1), (K, 8), 0,
                                     tcfg.vocab_size)
        backend = EngineBackend(eng, eng.start(prompts))
        cfg = CellConfig(scheme="multidraft",
                         scheme_params={"J_min": J_min, "J_max": J_max},
                         max_batch=K, L_max=L_max, seed=seed)
        cell = MultiSpinCell(cfg, backend=backend)
        rng = np.random.default_rng(seed)
        for i in range(K):
            cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=10 ** 9,
                                alpha=float(rng.choice([0.71, 0.74, 0.86])),
                                T_S=0.009 * float(rng.uniform(0.85, 1.15))))
        tracer = trace.install()
        try:
            out = cell.run(rounds)
            spans = [sp.name for sp in tracer.snapshot()]
        finally:
            trace.uninstall()
        committed = [list(c) for c in backend.state.committed]
        return eng, cell, out, spans, committed

    eng, cell, out, spans, committed = serve("scatter")
    _, _, _, repair_spans, repair_committed = serve("repair")
    # hard invariants: dead-branch pages all returned, no allocator leak
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()
    J_used = [r.draft_width for r in cell.history]
    tokens_per_round = float(np.mean(
        [np.sum(r.accepted) for r in cell.history]))
    row = {
        "name": "beyond/engine/multidraft",
        "us_per_call": "",
        "rounds": len(cell.history),
        "goodput": out["goodput"],
        "tokens": out["tokens"],
        "J_min": min(J_used),
        "J_max_used": max(J_used),
        "free_pages": eng.pool_stats()["free_pages"],
        "repair_spans": spans.count("engine.cache_repair"),
        "kv_commit_spans": spans.count("engine.kv_commit"),
        "repair_mode_spans": repair_spans.count("engine.cache_repair"),
        "commit_parity": int(committed == repair_committed),
        "derived": (f"goodput={out['goodput']:.1f} "
                    f"tokens/round={tokens_per_round:.1f} "
                    f"J_used={sorted(set(J_used))} "
                    f"rounds={len(cell.history)} "
                    f"repair_spans={spans.count('engine.cache_repair')} "
                    f"commit_parity={int(committed == repair_committed)}"),
    }
    return [row]


def smoke(rows: list[dict]) -> None:
    """CI gate: the engine-served multidraft path must commit tokens with
    true multi-draft widths every round; raises SystemExit otherwise."""
    failures = []
    for r in rows:
        if r["name"] != "beyond/engine/multidraft":
            continue
        if not r["tokens"] > 0:
            failures.append(f"{r['name']}: no tokens committed")
        if not r["goodput"] > 0:
            failures.append(f"{r['name']}: non-positive goodput")
        if r["J_min"] < 2:
            failures.append(f"{r['name']}: a round planned J={r['J_min']} "
                            "< 2 — the tree path was not exercised")
        if r["rounds"] == 0:
            failures.append(f"{r['name']}: no rounds executed")
        if r.get("repair_spans", 0) != 0:
            failures.append(f"{r['name']}: {r['repair_spans']} "
                            "engine.cache_repair span(s) in the default "
                            "scatter-commit run — the repair forward is "
                            "back in the hot path")
        if r.get("kv_commit_spans", 1) == 0:
            failures.append(f"{r['name']}: no engine.kv_commit spans — "
                            "scatter-commit never ran despite J >= 2")
        if r.get("commit_parity", 1) != 1:
            failures.append(f"{r['name']}: scatter-commit and repair-forward "
                            "committed different tokens at the same seed")
        if r.get("repair_mode_spans", 1) == 0:
            failures.append(f"{r['name']}: the repair-mode control run "
                            "emitted no engine.cache_repair spans — the "
                            "span check is vacuous (span renamed?)")
    if failures:
        raise SystemExit("beyond smoke FAILED:\n  " + "\n  ".join(failures))
    print("beyond smoke OK")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine", action="store_true",
                    help="also SERVE multidraft on a real paged SpecEngine "
                    "(token-tree verification, J > 1)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast engine-only CI gate (exits non-zero when the "
                    "tree path is dead)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="dump rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = []
    if args.smoke:
        rows += run_engine(rounds=args.rounds or 6, seed=args.seed)
    else:
        rows += run(fast=not args.full)
        if args.engine:
            rows += run_engine(rounds=args.rounds or 10, seed=args.seed)
    for r in rows:
        print(r["name"], r["derived"])
    if args.json:
        write_rows_json(args.json, rows)
    if args.smoke:
        smoke(rows)


if __name__ == "__main__":
    main()
