"""Beyond-paper optimizations: packed ragged verification + pipelined rounds.

Compares, at the paper's Fig-6 operating point and across K:
  baseline   — paper-faithful Hete-Multi-SPIN (constant T_ver(K))
  packed     — token-budget T_ver + ragged packing (no zero-pad compute)
  pipelined  — two half-batches overlapping draft/upload with verification
  packed+pipe — both
  multidraft — joint (L, J) optimum (J drafts per device, keep the longest)

Every variant is a registered scheme planned through ``MultiSpinCell``
(``cell_plan`` replays the recorded fading block; ``pipelined=True`` uses
the cell's two-half-batch planner) — no solver is constructed directly.
The baseline/packed comparison uses the SAME token-budget verifier with
padded vs packed accounting, so the packing gain is not an artifact of the
verifier refinement.
"""

from __future__ import annotations

import numpy as np

from repro.core.channel import ChannelState

from .common import cell_plan, load_calibration, paper_channel, paper_devices


def run(fast: bool = True) -> list[dict]:
    rows = []
    n_seeds = 3 if fast else 8
    for pair in ("llama2", "qwen35"):
        calib = load_calibration()[pair]
        cfg = paper_channel(pair)
        t_fix, t_lin = calib["t_fix"], calib["t_lin"]
        for K in (8, 20):
            acc = {"paper": [], "padded_tb": [], "packed": [], "pipelined": [],
                   "packed_pipe": []}
            for seed in range(n_seeds):
                rng = np.random.default_rng(seed)
                _, alphas = paper_devices(pair, K, rng)
                ch = ChannelState.sample(cfg, K, rng)
                t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]

                def plan(scheme, pipelined=False):
                    return cell_plan(scheme, cfg, t_fix, t_lin, alphas,
                                     t_dev, ch, pipelined=pipelined)

                acc["paper"].append(plan("hete").goodput)
                acc["padded_tb"].append(
                    plan("hete-padded-tokenbudget").goodput)
                acc["packed"].append(plan("hete-packed").goodput)
                acc["pipelined"].append(
                    plan("hete", pipelined=True)["goodput"])
                acc["packed_pipe"].append(
                    plan("hete-packed", pipelined=True)["goodput"])
            m = {k: float(np.mean(v)) for k, v in acc.items()}
            # multi-draft (L, J) joint optimum in the uniform regime
            rng = np.random.default_rng(0)
            _, alphas = paper_devices(pair, K, rng)
            t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
            ch = ChannelState.sample(cfg, K, rng)
            md = cell_plan("multidraft", cfg, t_fix, t_lin, alphas, t_dev, ch)
            m["multidraft"] = md.goodput
            m["multidraft_J"] = md.draft_width
            rows.append({
                "name": f"beyond/{pair}/K={K}",
                "us_per_call": "",
                "derived": (f"paper={m['paper']:.1f} "
                            f"padded_tb={m['padded_tb']:.1f} "
                            f"packed={m['packed']:.1f} "
                            f"(+{100 * (m['packed'] / m['padded_tb'] - 1):.0f}% "
                            f"vs padded) pipelined={m['pipelined']:.1f} "
                            f"(+{100 * (m['pipelined'] / m['paper'] - 1):.0f}%) "
                            f"both={m['packed_pipe']:.1f} "
                            f"(+{100 * (m['packed_pipe'] / m['paper'] - 1):.0f}%) "
                            f"multidraft_LJ={m['multidraft']:.1f} "
                            f"(J*={m['multidraft_J']})"),
                **m,
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
