"""Beyond-paper optimizations: packed ragged verification + pipelined rounds.

Compares, at the paper's Fig-6 operating point and across K:
  baseline   — paper-faithful Hete-Multi-SPIN (constant T_ver(K))
  packed     — token-budget T_ver + ragged packing (no zero-pad compute)
  pipelined  — two half-batches overlapping draft/upload with verification
  packed+pipe — both

The baseline/packed comparison uses the SAME token-budget verifier with
padded vs packed accounting, so the packing gain is not an artifact of the
verifier refinement.
"""

from __future__ import annotations

import numpy as np

from repro.core.beyond import (
    TokenBudgetVerifier,
    pipelined_goodput,
    solve_heterogeneous_packed,
    solve_heterogeneous_padded_tokenbudget,
    solve_uniform_multidraft,
)
from repro.core.channel import ChannelState
from repro.core.draft_control import solve_heterogeneous

from .common import load_calibration, paper_channel, paper_devices


def run(fast: bool = True) -> list[dict]:
    rows = []
    n_seeds = 3 if fast else 8
    for pair in ("llama2", "qwen35"):
        calib = load_calibration()[pair]
        cfg = paper_channel(pair)
        Q, B = cfg.q_tok_bits, cfg.total_bandwidth_hz
        verifier = TokenBudgetVerifier.from_affine(calib["t_fix"],
                                                   calib["t_lin"], L_ref=8)
        for K in (8, 20):
            acc = {"paper": [], "padded_tb": [], "packed": [], "pipelined": [],
                   "packed_pipe": []}
            for seed in range(n_seeds):
                rng = np.random.default_rng(seed)
                _, alphas = paper_devices(pair, K, rng)
                ch = ChannelState.sample(cfg, K, rng)
                t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
                T_ver = calib["t_fix"] + K * calib["t_lin"]

                acc["paper"].append(
                    solve_heterogeneous(alphas, t_dev, ch.rates, Q, B, T_ver,
                                        L_max=25).goodput)
                acc["padded_tb"].append(
                    solve_heterogeneous_padded_tokenbudget(
                        alphas, t_dev, ch.rates, Q, B, verifier,
                        L_max=25).goodput)
                acc["packed"].append(
                    solve_heterogeneous_packed(alphas, t_dev, ch.rates, Q, B,
                                               verifier, L_max=25).goodput)
                t_ver_of_K = lambda k: calib["t_fix"] + k * calib["t_lin"]  # noqa: E731
                acc["pipelined"].append(
                    pipelined_goodput(alphas, t_dev, ch.rates, Q, B,
                                      t_ver_of_K, L_max=25)["goodput"])

                def packed_solver(a, t, r, q, b, tv, L_max=25):
                    return solve_heterogeneous_packed(a, t, r, q, b, verifier,
                                                      L_max=L_max)
                acc["packed_pipe"].append(
                    pipelined_goodput(alphas, t_dev, ch.rates, Q, B,
                                      t_ver_of_K, L_max=25,
                                      solver=packed_solver)["goodput"])
            m = {k: float(np.mean(v)) for k, v in acc.items()}
            # multi-draft (L, J) joint optimum in the uniform regime
            rng = np.random.default_rng(0)
            _, alphas = paper_devices(pair, K, rng)
            t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
            ch = ChannelState.sample(cfg, K, rng)
            md = solve_uniform_multidraft(float(np.mean(alphas)), t_dev,
                                          ch.rates, Q, B, verifier, K)
            m["multidraft"] = md["best"]["goodput"]
            m["multidraft_J"] = md["best"]["J"]
            rows.append({
                "name": f"beyond/{pair}/K={K}",
                "us_per_call": "",
                "derived": (f"paper={m['paper']:.1f} "
                            f"padded_tb={m['padded_tb']:.1f} "
                            f"packed={m['packed']:.1f} "
                            f"(+{100 * (m['packed'] / m['padded_tb'] - 1):.0f}% "
                            f"vs padded) pipelined={m['pipelined']:.1f} "
                            f"(+{100 * (m['pipelined'] / m['paper'] - 1):.0f}%) "
                            f"both={m['packed_pipe']:.1f} "
                            f"(+{100 * (m['packed_pipe'] / m['paper'] - 1):.0f}%) "
                            f"multidraft_LJ={m['multidraft']:.1f} "
                            f"(J*={m['multidraft_J']})"),
                **m,
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
