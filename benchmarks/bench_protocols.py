"""Fig. 6: P2P-SPIN vs Cen-SPIN vs Multi-SPIN maximum sum goodput.

Every protocol runs through the scheme registry + ``MultiSpinCell``
(``CellConfig(scheme=...)`` — no solver is constructed directly); the
recorded fading block is replayed into the cell, so the reported numbers
are bit-identical to the direct-solver values of the pre-registry driver.

``--smoke`` is the CI gate: it checks the Fig.-6 ordering (Multi > Cen >
P2P) and that the goodput ratios stay inside a loose band around the
paper's, exiting non-zero otherwise.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.channel import ChannelState

from .common import (
    FIG6_TARGETS,
    K_DEFAULT,
    cell_plan,
    channel_slice,
    load_calibration,
    paper_channel,
    paper_devices,
)

# loose structural bands for the CI smoke gate (paper: 2.5-3.0x over Cen,
# 4.0-4.6x over P2P) — wide enough to never flake, tight enough to catch a
# scheme wired to the wrong latency model
SMOKE_RATIO_BANDS = {"cen": (1.3, 6.0), "p2p": (2.0, 10.0)}


def run(fast: bool = True) -> list[dict]:
    rows = []
    n_seeds = 3 if fast else 10
    for pair in ("llama2", "qwen35"):
        calib = load_calibration()[pair]
        cfg = paper_channel(pair)
        K = K_DEFAULT
        t_fix, t_lin = calib["t_fix"], calib["t_lin"]
        acc = {"multi": [], "cen": [], "p2p": []}
        for seed in range(n_seeds):
            rng = np.random.default_rng(seed)
            tasks, alphas = paper_devices(pair, K, rng)
            ch = ChannelState.sample(cfg, K, rng)
            t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
            acc["multi"].append(
                cell_plan("hete", cfg, t_fix, t_lin, alphas, t_dev,
                          ch).goodput)
            acc["cen"].append(
                cell_plan("cen", cfg, t_fix, t_lin, alphas, t_dev,
                          ch).goodput)
            acc["p2p"].append(
                cell_plan("p2p", cfg, t_fix, t_lin, alphas[:1], t_dev[:1],
                          channel_slice(ch, slice(0, 1))).goodput)
        means = {k: float(np.mean(v)) for k, v in acc.items()}
        for proto in ("multi", "cen", "p2p"):
            rows.append({
                "name": f"protocols/{pair}/{proto}",
                "us_per_call": "",
                "derived": (f"goodput={means[proto]:.1f} "
                            f"paper={FIG6_TARGETS[pair][proto]:.1f}"),
                "goodput": means[proto],
            })
        rows.append({
            "name": f"protocols/{pair}/ratios",
            "us_per_call": "",
            "derived": (f"multi/cen={means['multi'] / means['cen']:.2f} "
                        f"(paper {'2.5' if pair == 'llama2' else '3.0'}) "
                        f"multi/p2p={means['multi'] / means['p2p']:.2f}"),
            "ratios": {p: means["multi"] / means[p] for p in ("cen", "p2p")},
            "means": means,
        })
    return rows


def smoke(rows: list[dict]) -> None:
    """CI gate over the Fig.-6 structure; raises SystemExit on violation."""
    failures = []
    for r in rows:
        if "goodput" in r and not r["goodput"] > 0:
            failures.append(f"{r['name']}: non-positive goodput")
        means = r.get("means")
        if means is not None and not (means["multi"] > means["cen"]
                                      > means["p2p"] > 0):
            failures.append(f"{r['name']}: Fig.-6 ordering violated "
                            f"(multi={means['multi']:.1f} "
                            f"cen={means['cen']:.1f} p2p={means['p2p']:.1f})")
        for proto, (lo, hi) in SMOKE_RATIO_BANDS.items():
            ratio = r.get("ratios", {}).get(proto)
            if ratio is not None and not lo <= ratio <= hi:
                failures.append(f"{r['name']}: multi/{proto}={ratio:.2f} "
                                f"outside [{lo}, {hi}]")
    if failures:
        raise SystemExit("protocols smoke FAILED:\n  " + "\n  ".join(failures))
    print("protocols smoke OK")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: Fig.-6 ordering + ratio bands")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="dump rows as JSON (CI artifact)")
    args = ap.parse_args()
    rows = run(fast=not args.full)
    for r in rows:
        print(r["name"], r["derived"])
    if args.json:
        from .common import write_rows_json
        write_rows_json(args.json, rows)
    if args.smoke:
        smoke(rows)


if __name__ == "__main__":
    main()
