"""Fig. 6: P2P-SPIN vs Cen-SPIN vs Multi-SPIN maximum sum goodput."""

from __future__ import annotations

import numpy as np

from repro.core.channel import ChannelState
from repro.core.draft_control import (
    solve_centralized,
    solve_heterogeneous,
    solve_p2p,
)

from .common import (
    FIG6_TARGETS,
    K_DEFAULT,
    load_calibration,
    paper_channel,
    paper_devices,
)


def run(fast: bool = True) -> list[dict]:
    rows = []
    n_seeds = 3 if fast else 10
    for pair in ("llama2", "qwen35"):
        calib = load_calibration()[pair]
        cfg = paper_channel(pair)
        Q, B = cfg.q_tok_bits, cfg.total_bandwidth_hz
        K = K_DEFAULT
        acc = {"multi": [], "cen": [], "p2p": []}
        for seed in range(n_seeds):
            rng = np.random.default_rng(seed)
            tasks, alphas = paper_devices(pair, K, rng)
            ch = ChannelState.sample(cfg, K, rng)
            t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
            T_ver = calib["t_fix"] + K * calib["t_lin"]
            acc["multi"].append(
                solve_heterogeneous(alphas, t_dev, ch.rates, Q, B, T_ver,
                                    L_max=25).goodput)
            acc["cen"].append(
                solve_centralized(alphas, T_ver, calib["t_fix"] * 0.15,
                                  calib["t_lin"] * 0.6, L_max=25).goodput)
            acc["p2p"].append(
                solve_p2p(alphas[0], t_dev[0], ch.rates[0], Q, B,
                          calib["t_fix"] + calib["t_lin"], L_max=25).goodput)
        means = {k: float(np.mean(v)) for k, v in acc.items()}
        for proto in ("multi", "cen", "p2p"):
            rows.append({
                "name": f"protocols/{pair}/{proto}",
                "us_per_call": "",
                "derived": (f"goodput={means[proto]:.1f} "
                            f"paper={FIG6_TARGETS[pair][proto]:.1f}"),
                "goodput": means[proto],
            })
        rows.append({
            "name": f"protocols/{pair}/ratios",
            "us_per_call": "",
            "derived": (f"multi/cen={means['multi'] / means['cen']:.2f} "
                        f"(paper {'2.5' if pair == 'llama2' else '3.0'}) "
                        f"multi/p2p={means['multi'] / means['p2p']:.2f}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
