"""Fig. 7: sum goodput vs total bandwidth budget, all four control schemes.

Claims checked: Hete >= Uni-BW >= / Homo >= Fixed everywhere; ~88% gain of
Hete over Fixed at the smallest budget; the gain narrows as B grows
(communication-limited -> computation-limited transition).

Each (pair, B, scheme, seed) point is one ``MultiSpinCell`` built from a
``CellConfig``; scheme solvers resolve through the registry.
"""

from __future__ import annotations

import numpy as np

from .common import K_DEFAULT, load_calibration, planned_cell_goodput

BUDGETS_MHZ = [1.0, 2.0, 5.0, 10.0, 20.0, 40.0]
SCHEMES = ("hete", "homo", "uni-bw", "fixed")


def run(fast: bool = True) -> list[dict]:
    rows = []
    # the cell samples its own channel stream, so the fast mode needs a few
    # more seeds than the legacy solver-wired version for stable gain trends
    n_seeds = 10 if fast else 20
    for pair in ("llama2", "qwen35"):
        calib = load_calibration()[pair]
        gains = {}
        for B_mhz in BUDGETS_MHZ:
            m = {s: float(np.mean(
                    [planned_cell_goodput(s, pair, K_DEFAULT, seed, calib,
                                          B_hz=B_mhz * 1e6)
                     for seed in range(n_seeds)]))
                 for s in SCHEMES}
            gains[B_mhz] = m["hete"] / m["fixed"] - 1.0
            rows.append({
                "name": f"bandwidth_sweep/{pair}/B={B_mhz}MHz",
                "us_per_call": "",
                "derived": (f"hete={m['hete']:.1f} uni-bw={m['uni-bw']:.1f} "
                            f"homo={m['homo']:.1f} fixed={m['fixed']:.1f} "
                            f"gain_vs_fixed={100 * gains[B_mhz]:.0f}%"),
                **m,
            })
        rows.append({
            "name": f"bandwidth_sweep/{pair}/summary",
            "us_per_call": "",
            "derived": (f"gain at {BUDGETS_MHZ[0]}MHz: "
                        f"{100 * gains[BUDGETS_MHZ[0]]:.0f}% -> "
                        f"{BUDGETS_MHZ[-1]}MHz: "
                        f"{100 * gains[BUDGETS_MHZ[-1]]:.0f}% "
                        f"narrows={gains[BUDGETS_MHZ[-1]] < gains[BUDGETS_MHZ[0]]}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
