"""Fig. 7: sum goodput vs total bandwidth budget, all four control schemes.

Claims checked: Hete >= Uni-BW >= / Homo >= Fixed everywhere; ~88% gain of
Hete over Fixed at the smallest budget; the gain narrows as B grows
(communication-limited -> computation-limited transition).
"""

from __future__ import annotations

import numpy as np

from repro.core.channel import ChannelState
from repro.core.draft_control import (
    solve_fixed,
    solve_heterogeneous,
    solve_homogeneous_exhaustive,
    solve_uniform_bandwidth,
)

from .common import K_DEFAULT, load_calibration, paper_channel, paper_devices

BUDGETS_MHZ = [1.0, 2.0, 5.0, 10.0, 20.0, 40.0]


def run(fast: bool = True) -> list[dict]:
    rows = []
    n_seeds = 3 if fast else 10
    for pair in ("llama2", "qwen35"):
        calib = load_calibration()[pair]
        cfg = paper_channel(pair)
        Q = cfg.q_tok_bits
        K = K_DEFAULT
        T_ver = calib["t_fix"] + K * calib["t_lin"]
        gains = {}
        for B_mhz in BUDGETS_MHZ:
            B = B_mhz * 1e6
            acc = {s: [] for s in ("hete", "homo", "uni-bw", "fixed")}
            for seed in range(n_seeds):
                rng = np.random.default_rng(seed)
                tasks, alphas = paper_devices(pair, K, rng)
                ch = ChannelState.sample(cfg, K, rng)
                t_dev = rng.uniform(0.85, 1.15, K) * calib["T_S"]
                kw = dict(T_S=t_dev, r=ch.rates, Q_tok=Q, B=B, T_ver=T_ver)
                acc["hete"].append(
                    solve_heterogeneous(alphas, L_max=25, **kw).goodput)
                acc["homo"].append(
                    solve_homogeneous_exhaustive(alphas, L_max=25, **kw).goodput)
                acc["uni-bw"].append(
                    solve_uniform_bandwidth(alphas, L_max=25, **kw).goodput)
                acc["fixed"].append(solve_fixed(alphas, **kw).goodput)
            m = {s: float(np.mean(v)) for s, v in acc.items()}
            gains[B_mhz] = m["hete"] / m["fixed"] - 1.0
            rows.append({
                "name": f"bandwidth_sweep/{pair}/B={B_mhz}MHz",
                "us_per_call": "",
                "derived": (f"hete={m['hete']:.1f} uni-bw={m['uni-bw']:.1f} "
                            f"homo={m['homo']:.1f} fixed={m['fixed']:.1f} "
                            f"gain_vs_fixed={100 * gains[B_mhz]:.0f}%"),
                **m,
            })
        rows.append({
            "name": f"bandwidth_sweep/{pair}/summary",
            "us_per_call": "",
            "derived": (f"gain_at_min_B={100 * gains[BUDGETS_MHZ[0]]:.0f}% "
                        f"(paper ~88%) gain_at_max_B="
                        f"{100 * gains[BUDGETS_MHZ[-1]]:.0f}% "
                        f"narrows={gains[BUDGETS_MHZ[0]] > gains[BUDGETS_MHZ[-1]]}"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
