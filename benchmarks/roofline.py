"""Roofline table: reads experiments/dryrun/*.json into the §Roofline table."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells() -> list[dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def run(fast: bool = True) -> list[dict]:
    rows = []
    ok = err = 0
    for c in load_cells():
        if c.get("status") != "ok":
            err += 1
            rows.append({"name": f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
                         "us_per_call": "", "derived": f"ERROR {c.get('error')}"})
            continue
        ok += 1
        r = c["roofline"]
        mem = c.get("memory_analysis", {})
        tot_gb = (mem.get("temp_size_in_bytes", 0)
                  + mem.get("argument_size_in_bytes", 0)) / 1e9
        rows.append({
            "name": f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            "us_per_call": "",
            "derived": (f"bottleneck={r['bottleneck']} "
                        f"frac={r['peak_fraction']:.3f} "
                        f"c/m/n={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
                        f"{r['collective_s']:.2e} "
                        f"flops_ratio={r['flops_ratio']:.2f} mem={tot_gb:.1f}GB"),
            "roofline": r,
        })
    rows.append({"name": "roofline/summary", "us_per_call": "",
                 "derived": f"cells_ok={ok} cells_err={err}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r["name"], r["derived"])
