"""Generate PNG analogues of the paper's figures into experiments/figures/.

  PYTHONPATH=src python -m benchmarks.make_figures
"""

from __future__ import annotations

import os

import matplotlib
matplotlib.use("Agg")
import matplotlib.pyplot as plt
import numpy as np

FIG_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "figures")


def fig3_goodput_vs_L():
    from .bench_goodput_vs_L import run
    fig, axes = plt.subplots(1, 2, figsize=(10, 4))
    for ax, pair in zip(axes, ("llama2", "qwen35")):
        rows = run(pair, fast=True)
        summary = rows[-1]
        Ls = np.arange(1, 26)
        ax.plot(Ls, summary["curve_theory"], "-", label="theory (eq. 18)")
        ax.plot(Ls, summary["curve_emp"], "o", ms=3, label="empirical")
        ax.axvline(summary["L_star"], ls="--", c="gray",
                   label=f"L* (Thm 1) = {summary['L_star']}")
        ax.set_xlabel("draft length L")
        ax.set_ylabel("sum goodput [tok/s]")
        ax.set_title(f"Fig. 3 analogue — {pair}")
        ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(FIG_DIR, "fig3_goodput_vs_L.png"), dpi=120)


def fig7_bandwidth_sweep():
    from .bench_bandwidth_sweep import BUDGETS_MHZ, run
    rows = run(fast=True)
    fig, axes = plt.subplots(1, 2, figsize=(10, 4))
    for ax, pair in zip(axes, ("llama2", "qwen35")):
        data = {s: [] for s in ("hete", "uni-bw", "homo", "fixed")}
        for r in rows:
            if f"/{pair}/" in r["name"] and "B=" in r["name"]:
                for s in data:
                    data[s].append(r[s])
        for s, vals in data.items():
            ax.plot(BUDGETS_MHZ, vals, "o-", label=s)
        ax.set_xscale("log")
        ax.set_xlabel("bandwidth budget [MHz]")
        ax.set_ylabel("sum goodput [tok/s]")
        ax.set_title(f"Fig. 7 analogue — {pair}")
        ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(FIG_DIR, "fig7_bandwidth_sweep.png"), dpi=120)


def fig8_scaling_K():
    from .bench_scaling_K import K_RANGE, run
    rows = run(fast=True)
    fig, axes = plt.subplots(1, 2, figsize=(10, 4))
    for ax, pair in zip(axes, ("llama2", "qwen35")):
        hete, fixed = [], []
        for r in rows:
            if f"/{pair}/" in r["name"] and "K=" in r["name"]:
                hete.append(r["hete"])
                fixed.append(r["fixed"])
        ax.plot(K_RANGE, hete, "o-", label="Hete-Multi-SPIN")
        ax.plot(K_RANGE, fixed, "s-", label="Fixed BW&L")
        ax.set_xlabel("devices K")
        ax.set_ylabel("sum goodput [tok/s]")
        ax.set_title(f"Fig. 8 analogue — {pair}")
        ax.legend()
    fig.tight_layout()
    fig.savefig(os.path.join(FIG_DIR, "fig8_scaling_K.png"), dpi=120)


def main():
    os.makedirs(FIG_DIR, exist_ok=True)
    fig3_goodput_vs_L()
    fig7_bandwidth_sweep()
    fig8_scaling_K()
    print("figures written to", FIG_DIR)


if __name__ == "__main__":
    main()
