"""Lockstep vs continuous batching: goodput and TTFT under Poisson churn.

The continuous engine (ROADMAP item 2, DiP-SD/WISP direction) removes the
cell's round barrier: per-stream state machines, verification batches packed
from whichever streams are READY, drafting overlapped with in-flight
verification.  This bench quantifies the trade and guards its correctness
anchor:

* **sim rows** — the SAME Poisson arrival trace (identical simulated-time
  schedule, seeds, and device profiles with heterogeneous draft speeds)
  driven through ``schedule="sync"`` and ``schedule="continuous"`` cells.
  The smoke gate requires continuous >= lockstep sum goodput AND strictly
  lower p95 TTFT: slow drafters no longer stall the cohort, at the price of
  extra fixed verification cost per (smaller) batch.
* **engine row** — forced-barrier bit-identity: ``max_inflight=1`` +
  exact shapes must reproduce the lockstep ``SpecEngine.spin_round``
  committed tokens bit-for-bit at the same seed, and the shape-bucketed
  assembler must bound distinct dispatch shapes (XLA retraces) under a
  churny ready-set.
* **gateway row** — the closed-loop concurrent-client load generator
  (``LoadGenConfig(mode="closed")``: N persistent SSE clients, per-client
  think time) against a live continuous-schedule gateway; real-wall
  timings, host-gated in the regression diff.

``--smoke`` writes ``BENCH_continuous.json`` (the ``continuous-smoke`` CI
gate; ``bench-regression`` diffs it against the committed baseline).

Usage::

    PYTHONPATH=src python -m benchmarks.bench_continuous           # sim only
    PYTHONPATH=src python -m benchmarks.bench_continuous --smoke   # CI gate
"""

from __future__ import annotations

import argparse
import os
from collections import deque

import numpy as np

from repro.api import CellConfig, MultiSpinCell, Request

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_continuous.json")

ALPHAS = (0.71, 0.74, 0.86, 0.93)
# heterogeneous device compute: a fast majority and a 7x straggler tail —
# the regime where lockstep rounds pay max(T_draft) every round
T_S_CHOICES = (0.004, 0.006, 0.028)


def _arrival_trace(n: int, rate_per_s: float, seed: int,
                   mean_tokens: int) -> list[dict]:
    """One Poisson arrival schedule in SIMULATED seconds, as request specs
    (plain dicts: each schedule run builds its own Request objects)."""
    rng = np.random.default_rng(seed)
    t, specs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate_per_s))
        specs.append(dict(
            t=t, rid=100 + i, prompt_len=8,
            max_new_tokens=int(rng.integers(mean_tokens // 2,
                                            2 * mean_tokens)),
            alpha=float(rng.choice(ALPHAS)),
            T_S=float(rng.choice(T_S_CHOICES))))
    return specs


def _drive(schedule: str, specs: list[dict], max_batch: int, seed: int,
           max_inflight: int = 2, max_steps: int = 100_000) -> dict:
    """Run one cell over the arrival trace until every request retires."""
    cfg = CellConfig(scheme="hete", max_batch=max_batch, schedule=schedule,
                     max_inflight=max_inflight, seed=seed)
    cell = MultiSpinCell(cfg)
    pending = deque(dict(s) for s in specs)
    for _ in range(max_steps):
        while pending and pending[0]["t"] <= cell.scheduler.clock:
            s = pending.popleft()
            cell.submit(Request(rid=s["rid"], prompt_len=s["prompt_len"],
                                max_new_tokens=s["max_new_tokens"],
                                alpha=s["alpha"], T_S=s["T_S"]))
        if cell.step() is None:
            if not pending:
                break
            # idle gap before the next arrival: advance the sim clock
            # without billing busy time
            cell.scheduler.clock = max(cell.scheduler.clock,
                                       pending[0]["t"])
    else:
        raise SystemExit(f"bench_continuous: {schedule} did not drain")
    stats = cell.scheduler.stats
    from repro.serving.gateway.loadgen import percentile
    occ = [r.batch_occupancy for r in cell.history
           if r.batch_occupancy is not None]
    out = {
        "schedule": schedule,
        "rounds": len(cell.history),
        "completed": stats.completed,
        "tokens": stats.total_tokens,
        "goodput": stats.goodput,
        "hol_block_max_s": stats.hol_wait_max,
        "batch_occupancy_mean": float(np.mean(occ)) if occ else 0.0,
        "ttft_sim_s": {"p50": percentile(stats.ttft_s, 50),
                       "p95": percentile(stats.ttft_s, 95),
                       "p99": percentile(stats.ttft_s, 99),
                       "n": len(stats.ttft_s)},
    }
    if schedule == "continuous":
        ready = [r.ready_depth for r in cell.history
                 if r.ready_depth is not None]
        out["ready_depth_mean"] = float(np.mean(ready)) if ready else 0.0
    return out


def run_sim(n_requests: int, rate_per_s: float, max_batch: int, seed: int,
            mean_tokens: int, max_inflight: int = 2) -> list[dict]:
    specs = _arrival_trace(n_requests, rate_per_s, seed, mean_tokens)
    lock = _drive("sync", specs, max_batch, seed)
    cont = _drive("continuous", specs, max_batch, seed,
                  max_inflight=max_inflight)
    gain = cont["goodput"] / lock["goodput"] if lock["goodput"] else 0.0
    p95_ratio = (cont["ttft_sim_s"]["p95"] / lock["ttft_sim_s"]["p95"]
                 if lock["ttft_sim_s"]["p95"] else 0.0)
    ok = gain >= 1.0 and p95_ratio < 1.0
    rows = [
        {"name": "continuous/sim/lockstep",
         "derived": (f"goodput={lock['goodput']:.1f} "
                     f"ttft_p95={lock['ttft_sim_s']['p95']:.2f}s "
                     f"ttft_p99={lock['ttft_sim_s']['p99']:.2f}s "
                     f"hol_max={lock['hol_block_max_s']:.2f}s "
                     f"completed={lock['completed']}/{n_requests}"),
         **lock},
        {"name": "continuous/sim/continuous",
         "derived": (f"goodput={cont['goodput']:.1f} "
                     f"ttft_p95={cont['ttft_sim_s']['p95']:.2f}s "
                     f"ttft_p99={cont['ttft_sim_s']['p99']:.2f}s "
                     f"hol_max={cont['hol_block_max_s']:.2f}s "
                     f"occupancy={cont['batch_occupancy_mean']:.2f} "
                     f"completed={cont['completed']}/{n_requests}"),
         **cont},
        {"name": "continuous/sim/compare",
         "derived": (f"goodput_gain={gain:.3f}x "
                     f"ttft_p95_ratio={p95_ratio:.3f} ok={ok}"),
         "goodput_gain": gain, "ttft_p95_ratio": p95_ratio,
         "gate_ok": int(ok)},
    ]
    return rows


def run_engine_identity(seed: int = 42, rounds: int = 5) -> dict:
    """Forced-barrier bit-identity + assembler retrace bound on a real
    smoke-scale paged SpecEngine (the tentpole's correctness anchor)."""
    import jax

    from repro.configs import get_config
    from repro.serving.continuous import BatchAssembler, ContinuousEngine
    from repro.serving.spec_engine import SpecEngine

    def build():
        tcfg = get_config("qwen2.5-3b").smoke()
        dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2,
                            num_kv_heads=1, head_dim=16, d_ff=64,
                            name="draft-smoke")
        eng = SpecEngine(tcfg, dcfg, max_len=96, cache_kind="paged",
                         num_pages=3 * 2 * (96 // 16))
        eng.init_params(jax.random.PRNGKey(0))
        return eng, tcfg

    B, M, L = 3, 10, 4
    base = jax.random.PRNGKey(seed)
    eng1, tcfg = build()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, M), 0,
                                 tcfg.vocab_size)
    st1 = eng1.start(prompts)
    for r in range(rounds):
        st1, _, _ = eng1.spin_round(st1, np.full(B, L),
                                    jax.random.fold_in(base, r))

    eng2, _ = build()
    cont = ContinuousEngine(eng2, eng2.start(prompts), base,
                            max_inflight=1, exact_shapes=True)
    for b in range(B):
        cont.add_stream(b, length=L)
    for _ in range(rounds):
        cont.step()
    identical = all(st1.committed[b] == cont.state.committed[b]
                    for b in range(B))

    # assembler retrace bound: 12 distinct churny (K, L) ready-set shapes
    # must collapse to at most a handful of pow2 buckets
    asm = BatchAssembler(max_batch=8)
    ready_sets = [(k, ln) for k in (1, 2, 3, 5) for ln in (3, 4, 6)]
    for k, ln in ready_sets:
        for g in asm.assemble([(object(), ln)] * k):
            pass
    return {
        "name": "continuous/engine/bit_identity",
        "derived": (f"bit_identical={identical} rounds={rounds} "
                    f"assembler_shapes={len(asm.shapes)}"
                    f"/{len(ready_sets)} ready-set shapes"),
        "bit_identical": int(identical),
        "rounds": rounds,
        "assembler_shapes": len(asm.shapes),
        "ready_set_shapes": len(ready_sets),
    }


async def _run_gateway_closed(n_requests: int, n_clients: int,
                              seed: int) -> dict:
    from repro.serving.gateway import (
        GatewayConfig,
        LoadGenConfig,
        MultiSpinGateway,
        run_loadgen,
    )

    cfg = CellConfig(scheme="hete", max_batch=8, schedule="continuous",
                     seed=seed, L_max=8)
    gw = MultiSpinGateway(MultiSpinCell(cfg),
                          GatewayConfig(port=0, idle_wait_s=0.02))
    await gw.start()
    try:
        report = await run_loadgen(
            "127.0.0.1", gw.port,
            LoadGenConfig(mode="closed", n_clients=n_clients,
                          think_time_s=0.01, n_requests=n_requests,
                          max_new_tokens_choices=(4, 8), seed=seed))
    finally:
        await gw.stop()
    return report


def run_gateway(n_requests: int, n_clients: int, seed: int) -> dict:
    import asyncio

    report = asyncio.run(_run_gateway_closed(n_requests, n_clients, seed))
    ok = report["n_error"] == 0 and report["tokens"] > 0
    return {
        "name": "continuous/gateway/closed_loop",
        "derived": (f"tokens_per_s={report['tokens_per_s']:.1f} "
                    f"ttft_p95={report['ttft_s']['p95'] * 1e3:.1f}ms "
                    f"clients={n_clients} ok={ok}"),
        "tokens_per_s": report["tokens_per_s"],
        "tokens": report["tokens"],
        "n_ok": report["n_ok"],
        "n_error": report["n_error"],
        "errors": report["errors"],
        "wall_s": report["wall_s"],
        "ttft_s": report["ttft_s"],
        "latency_s": report["latency_s"],
    }


def run(smoke: bool = False, engine: bool | None = None,
        n_requests: int | None = None, rate: float = 6.0,
        max_batch: int = 8, seed: int = 0, mean_tokens: int = 16,
        out_path: str | None = None) -> list[dict]:
    if smoke:
        # the sim is synthetic-backend cheap: use the full trace so the p95
        # gate is judged on a stable sample
        n = 48
        engine = True if engine is None else engine
    else:
        n = n_requests if n_requests is not None else 48
        engine = False if engine is None else engine
    rows = run_sim(n, rate, max_batch, seed, mean_tokens)
    gate_ok = bool(rows[-1]["gate_ok"])
    if engine:
        ident = run_engine_identity()
        rows.append(ident)
        gate_ok = gate_ok and bool(ident["bit_identical"])
        rows.append(run_gateway(n_requests=8, n_clients=3, seed=seed))
        gate_ok = gate_ok and rows[-1]["n_error"] == 0
    if smoke:
        if not gate_ok:
            raise SystemExit("continuous smoke FAILED: "
                             + "; ".join(r["derived"] for r in rows))
        from .common import write_rows_json
        write_rows_json(out_path or BENCH_PATH, rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=6.0,
                    help="Poisson arrivals per SIMULATED second")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mean-tokens", type=int, default=16)
    ap.add_argument("--engine", action="store_true",
                    help="also run the engine bit-identity and gateway "
                         "closed-loop rows (always on under --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: requires continuous >= lockstep goodput, "
                         "strictly lower p95 TTFT, and forced-barrier "
                         "bit-identity; writes BENCH_continuous.json")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="dump rows as JSON (CI artifact)")
    ap.add_argument("--out", type=str, default=None, metavar="PATH",
                    help="where --smoke writes its rows (default: the "
                         "committed repo-root BENCH_continuous.json; CI "
                         "points this at artifacts/)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke, engine=args.engine or None,
               n_requests=args.n_requests, rate=args.rate,
               max_batch=args.max_batch, seed=args.seed,
               mean_tokens=args.mean_tokens, out_path=args.out)
    for r in rows:
        print(r["name"], r["derived"])
    if args.json:
        from .common import write_rows_json
        write_rows_json(args.json, rows)


if __name__ == "__main__":
    main()
