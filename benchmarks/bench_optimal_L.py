"""Fig. 4: optimal uniform draft length vs system parameters.

Sweeps T_ver, theta*, alpha; verifies the closed form against grid argmax and
the Remark-1 monotonicity directions.
"""

from __future__ import annotations

import numpy as np

from repro.core.draft_control import optimal_uniform_length
from repro.core.goodput import goodput_homogeneous


def _grid_argmax(alpha, theta, T_ver, L_max=200):
    Ls = np.arange(1, L_max + 1)
    taus = goodput_homogeneous(alpha, Ls, theta, T_ver, K=1)
    return int(Ls[int(np.argmax(taus))])


def run(fast: bool = True) -> list[dict]:
    rows = []
    base = dict(alpha=0.74, theta=0.03, T_ver=0.2)

    sweeps = {
        "T_ver": np.linspace(0.05, 1.0, 12),
        "theta": np.linspace(0.01, 0.12, 12),
        "alpha": np.linspace(0.4, 0.98, 12),
    }
    for pname, values in sweeps.items():
        seq = []
        for v in values:
            kw = dict(base)
            kw[pname] = float(v)
            L_star, L_tilde = optimal_uniform_length(kw["alpha"], kw["theta"],
                                                     kw["T_ver"])
            grid = _grid_argmax(kw["alpha"], kw["theta"], kw["T_ver"])
            assert int(L_star) == grid, (pname, v, int(L_star), grid)
            seq.append(int(L_star))
            rows.append({
                "name": f"optimal_L/{pname}={v:.3f}",
                "us_per_call": "",
                "derived": f"L_star={int(L_star)} L_tilde={float(L_tilde):.2f}",
            })
        # Remark-1 monotone directions
        mono_up = all(a <= b for a, b in zip(seq, seq[1:]))
        mono_dn = all(a >= b for a, b in zip(seq, seq[1:]))
        expect = {"T_ver": mono_up, "theta": mono_dn, "alpha": mono_up}[pname]
        rows.append({
            "name": f"optimal_L/{pname}/monotonicity",
            "us_per_call": "",
            "derived": f"ok={expect} seq={seq}",
            "ok": expect,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        if "monotonicity" in r["name"]:
            print(r["name"], r["derived"])
