"""Goodput under churn: Poisson join/leave against a live Multi-SPIN cell.

The paper's Sec.-V scenario — devices joining and leaving mid-session with
re-planning every round — measured end to end: arrivals are Poisson(rate)
per round, each admitted device runs a finite request, and every active
device independently departs early with probability ``p_leave`` per round
(exponential lifetimes).  Reported per scheme: goodput, completion count,
mean queue wait (admission delay), and mean sojourn time.

Two backends:

  * synthetic (default)  — analytic acceptance draws, scales to hundreds of
    rounds; measures the PROTOCOL cost of churn (re-planning, refilling).
  * ``--engine``         — a real paged ``SpecEngine`` at smoke scale; churn
    exercises dynamic admission (page-pool gated), stream retirement, and
    page recycling on real model weights.  This is the path CI smokes so
    `engine batch exhausted`-style regressions cannot land silently.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_churn              # synthetic
    PYTHONPATH=src python -m benchmarks.bench_churn --engine
    PYTHONPATH=src python -m benchmarks.bench_churn --smoke      # CI gate
"""

from __future__ import annotations

import argparse
import os

import numpy as np

from repro.api import CellConfig, MultiSpinCell, Request

ALPHAS = [0.71, 0.74, 0.86, 0.93]
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_churn.json")


def _poisson_churn_cell(cell: MultiSpinCell, rounds: int, rate: float,
                        p_leave: float, rng: np.random.Generator,
                        mean_tokens: int = 48) -> dict:
    """Drive ``cell`` for ``rounds`` rounds of Poisson join/leave; returns
    churn-level accounting on top of the cell's own summary."""
    next_rid = 10_000
    submitted = left_early = idle_rounds = 0
    for _ in range(rounds):
        for _ in range(rng.poisson(rate)):
            cell.submit(Request(
                rid=next_rid, prompt_len=8,
                max_new_tokens=int(rng.integers(mean_tokens // 2,
                                                2 * mean_tokens)),
                alpha=float(rng.choice(ALPHAS)),
                T_S=0.009 * float(rng.uniform(0.85, 1.15))))
            next_rid += 1
            submitted += 1
        if cell.step() is None:
            idle_rounds += 1
            continue
        # early departures (device failure / user abort), paper Sec. V
        for req in list(cell.scheduler.active):
            if rng.random() < p_leave:
                cell.leave(req.rid)
                left_early += 1
    stats = cell.scheduler.stats
    drafted = sum(int(r.lengths[r.active].sum()) for r in cell.history)
    positions = sum(int(np.maximum(r.accepted - 1, 0)[r.active].sum())
                    for r in cell.history)
    out = {
        "submitted": submitted,
        "completed": stats.completed,
        "left_early": left_early,
        "idle_rounds": idle_rounds,
        "tokens": stats.total_tokens,
        "goodput": stats.goodput,
        "acceptance": positions / drafted if drafted else 0.0,
        "queued_at_end": len(cell.scheduler.queue),
    }
    # head-of-line blocking: the longest a SERVABLE request sat at the FIFO
    # head (batch slots or page pool full) — the queueing tail the
    # continuous engine's per-stream rounds attack
    out["hol_block_max_s"] = stats.hol_wait_max
    if stats.ttft_s:
        from repro.serving.gateway.loadgen import percentile
        out["ttft_sim_s"] = {"p50": percentile(stats.ttft_s, 50),
                             "p95": percentile(stats.ttft_s, 95),
                             "p99": percentile(stats.ttft_s, 99),
                             "n": len(stats.ttft_s)}
    return out


def run_synthetic(rounds: int, rate: float, p_leave: float, max_batch: int,
                  scheme: str, seed: int, mean_tokens: int = 48) -> dict:
    cfg = CellConfig(scheme=scheme, max_batch=max_batch, seed=seed)
    cell = MultiSpinCell(cfg)
    return _poisson_churn_cell(cell, rounds, rate, p_leave,
                               np.random.default_rng(seed),
                               mean_tokens=mean_tokens)


def run_engine(rounds: int, rate: float, p_leave: float, max_batch: int,
               scheme: str, seed: int, mean_tokens: int = 8) -> dict:
    """Same churn trace against a real paged SpecEngine at smoke scale."""
    import jax

    from repro.configs import get_config
    from repro.serving import SpecEngine
    from repro.serving.backends import EngineBackend

    tcfg = get_config("qwen2.5-3b").smoke()
    dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                        head_dim=16, d_ff=64, name="draft-smoke")
    eng = SpecEngine(tcfg, dcfg, max_len=128, cache_kind="paged",
                     num_pages=max_batch * 2 * (128 // 16))
    eng.init_params(jax.random.PRNGKey(seed))
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (max_batch, 8), 0, tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts))
    cfg = CellConfig(scheme=scheme, max_batch=max_batch, L_max=6, seed=seed)
    cell = MultiSpinCell(cfg, backend=backend)
    out = _poisson_churn_cell(cell, rounds, rate, p_leave,
                              np.random.default_rng(seed),
                              mean_tokens=mean_tokens)
    # hard churn invariants: the allocator never leaks under join/leave
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()
    out["free_pages"] = eng.pool_stats()["free_pages"]
    return out


def run(fast: bool = True, engine: bool = False, smoke: bool = False,
        rounds: int | None = None, rate: float = 0.8, p_leave: float = 0.02,
        max_batch: int = 8, seed: int = 0,
        out_path: str | None = None) -> list[dict]:
    rows = []
    mean_tokens = None
    if smoke:
        schemes, rounds, engine = ("fixed",), 8, True
        rate, max_batch, mean_tokens = 1.0, 3, 4
    else:
        schemes = ("hete", "fixed")
        rounds = rounds if rounds is not None else (60 if fast else 400)
    for scheme in schemes:
        fn = run_engine if engine else run_synthetic
        kw = {} if mean_tokens is None else {"mean_tokens": mean_tokens}
        out = fn(rounds, rate, p_leave, max_batch, scheme, seed, **kw)
        ok = out["completed"] > 0 and out["tokens"] > 0
        ttft = out.get("ttft_sim_s")
        rows.append({
            "name": f"churn/{'engine' if engine else 'synthetic'}/{scheme}",
            "derived": (f"goodput={out['goodput']:.1f} "
                        f"acceptance={out['acceptance']:.3f} "
                        + (f"ttft_p50={ttft['p50']:.2f}s "
                           f"ttft_p95={ttft['p95']:.2f}s "
                           f"ttft_p99={ttft['p99']:.2f}s " if ttft else "")
                        + f"hol_max={out['hol_block_max_s']:.2f}s "
                        f"completed={out['completed']}/{out['submitted']} "
                        f"left_early={out['left_early']} "
                        f"queued={out['queued_at_end']} ok={ok}"),
            **out,
        })
        if smoke and not ok:
            raise SystemExit(f"churn smoke FAILED: {out}")
    if smoke:
        from .common import write_rows_json
        write_rows_json(out_path or BENCH_PATH, rows)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.8,
                    help="Poisson arrivals per round")
    ap.add_argument("--p-leave", type=float, default=0.02,
                    help="per-round early-departure probability")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", action="store_true",
                    help="real paged SpecEngine instead of synthetic draws")
    ap.add_argument("--smoke", action="store_true",
                    help="fast engine-backed CI gate (exits non-zero on "
                    "a dead churn path)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="dump rows as JSON (CI artifact)")
    ap.add_argument("--out", type=str, default=None, metavar="PATH",
                    help="where --smoke writes its rows (default: the "
                         "committed repo-root BENCH_churn.json; CI points "
                         "this at artifacts/ so baselines stay untouched)")
    args = ap.parse_args()
    rows = run(fast=not args.full, engine=args.engine, smoke=args.smoke,
               rounds=args.rounds, rate=args.rate, p_leave=args.p_leave,
               max_batch=args.max_batch, seed=args.seed, out_path=args.out)
    for r in rows:
        print(r["name"], r["derived"])
    if args.json:
        from .common import write_rows_json
        write_rows_json(args.json, rows)


if __name__ == "__main__":
    main()
