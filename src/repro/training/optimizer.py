"""AdamW with cosine schedule, global-norm clipping, decoupled weight decay.

Written against plain pytrees (no optax dependency).  Optimizer-state dtype
is configurable: fp32 moments by default; bf16 moments for memory-dominated
giants (arctic-480b training dry-run uses bf16, DESIGN.md §5).  All updates
are elementwise, so any pjit sharding of the parameters transfers one-to-one
to the optimizer state (ZeRO-style when params are FSDP-sharded).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    state_dtype: str = "float32"


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay -> floor at min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def init_optimizer(cfg: OptimizerConfig, params: Params) -> Params:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.state_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_gradients(cfg: OptimizerConfig, params: Params, grads: Params,
                    state: Params):
    """One AdamW step.  Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
