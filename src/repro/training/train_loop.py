"""Training step factory: loss, microbatched gradient accumulation, AdamW.

``make_train_step`` builds the jit-able step used both by the real CPU
training example (examples/train_100m.py) and by the multi-pod dry-run
(launch/dryrun.py), where it is lowered with ShapeDtypeStructs under the
production mesh.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .optimizer import OptimizerConfig, apply_gradients

Params = Any


def lm_loss(model, params, tokens, prefix_embeds=None, aux_weight: float = 0.01):
    """Next-token cross entropy (+ MoE aux loss).  Loss over text positions."""
    logits, aux = model.apply(params, tokens, prefix_embeds=prefix_embeds)
    S = tokens.shape[1]
    txt = logits[:, -S:]                      # drop VLM/audio prefix positions
    logp = jax.nn.log_softmax(txt[:, :-1].astype(jnp.float32), axis=-1)
    # one-hot contraction instead of take_along_axis: a vocab-dim gather on a
    # model-sharded logits tensor forces SPMD to replicate the full (B, S, V)
    # array; the elementwise one-hot product keeps the vocab shards local and
    # reduces with one small psum.
    onehot = jax.nn.one_hot(tokens[:, 1:], txt.shape[-1], dtype=logp.dtype)
    nll = -jnp.sum(logp * onehot, axis=-1)
    return jnp.mean(nll) + aux_weight * aux, (jnp.mean(nll), aux)


def make_train_step(model, opt_cfg: OptimizerConfig, microbatches: int = 1,
                    has_prefix: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``batch`` = {"tokens": (B, S)[, "prefix_embeds": ...]}.

    With microbatches > 1 the global batch is split on the leading axis and
    gradients are accumulated with a lax.scan — peak activation memory drops
    by the microbatch factor while keeping one optimizer step per call.
    """

    def grad_fn(params, tokens, prefix):
        (loss, (nll, aux)), grads = jax.value_and_grad(
            lambda p: lm_loss(model, p, tokens, prefix_embeds=prefix),
            has_aux=True)(params)
        return grads, loss, nll

    def train_step(params, opt_state, batch):
        tokens = batch["tokens"]
        prefix = batch.get("prefix_embeds") if has_prefix else None
        if microbatches == 1:
            grads, loss, nll = grad_fn(params, tokens, prefix)
        else:
            B = tokens.shape[0]
            mb = B // microbatches
            tok_mb = tokens.reshape(microbatches, mb, *tokens.shape[1:])
            pre_mb = (prefix.reshape(microbatches, mb, *prefix.shape[1:])
                      if prefix is not None else None)

            def body(carry, xs):
                acc, loss_acc, nll_acc = carry
                tok = xs[0]
                pre = xs[1] if pre_mb is not None else None
                g, l, n = grad_fn(params, tok, pre)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l, nll_acc + n), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (tok_mb, pre_mb) if pre_mb is not None else (tok_mb,)
            (grads, loss, nll), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(())), xs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss, nll = loss / microbatches, nll / microbatches

        params, opt_state, om = apply_gradients(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, "nll": nll, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model, has_prefix: bool = False):
    def eval_step(params, batch):
        tokens = batch["tokens"]
        prefix = batch.get("prefix_embeds") if has_prefix else None
        loss, (nll, aux) = lm_loss(model, params, tokens, prefix_embeds=prefix)
        return {"loss": loss, "nll": nll}
    return eval_step
