"""Synthetic data pipeline.

Two roles:

1. LM training batches — an order-2 Markov token source with Zipfian
   marginals: enough structure that a ~100M model demonstrably learns
   (loss decreases) within a few hundred CPU steps, fully deterministic
   per (seed, step) so data-parallel workers never need coordination and
   restarts resume bit-exactly.

2. Multi-SPIN task mixtures — prompt streams labeled with the paper's four
   task types (Table I); each task induces a characteristic SLM/LLM
   acceptance rate via per-task draft-temperature perturbation
   (benchmarks/bench_acceptance.py calibrates these to Table I means).
"""

from __future__ import annotations

import dataclasses

import numpy as np

TASK_TYPES = ("mbpp", "gsm8k", "mtbench", "squad")

# Paper Table I means (Llama-2 pair / Qwen3.5 pair)
TABLE_I = {
    "llama2": {"mbpp": 0.8582, "gsm8k": 0.7390, "mtbench": 0.7393, "squad": 0.7126},
    "qwen35": {"mbpp": 0.8100, "gsm8k": 0.9340, "mtbench": 0.9318, "squad": 0.9650},
}


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticLMDataset:
    """Deterministic order-2 Markov stream with Zipfian unigram marginals."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # Zipfian unigram distribution
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.unigram = (ranks ** -cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        # hidden low-rank bigram structure: token t -> shift pattern
        self.n_states = 16
        self.state_shift = rng.integers(0, V, self.n_states)
        self.state_of = rng.integers(0, self.n_states, V)

    def batch(self, step: int) -> dict:
        """Batch for a global step — pure function of (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        base = rng.choice(V, size=(B, S), p=self.unigram)
        out = np.empty((B, S), dtype=np.int64)
        out[:, 0] = base[:, 0]
        for t in range(1, S):
            # half the tokens follow the deterministic state pattern
            follow = rng.random(B) < 0.5
            pattern = (self.state_shift[self.state_of[out[:, t - 1]]]
                       + out[:, t - 1]) % V
            out[:, t] = np.where(follow, pattern, base[:, t])
        return {"tokens": out.astype(np.int32)}

    def shard(self, batch: dict, worker: int, num_workers: int) -> dict:
        B = batch["tokens"].shape[0]
        per = B // num_workers
        return {k: v[worker * per:(worker + 1) * per] for k, v in batch.items()}


@dataclasses.dataclass(frozen=True)
class TaskProfile:
    """Per-task drafting characteristics for the Multi-SPIN simulator."""

    name: str
    alpha_llama2: float
    alpha_qwen35: float
    draft_temperature: float  # SLM perturbation inducing the acceptance gap


def task_profiles() -> list[TaskProfile]:
    return [
        TaskProfile("mbpp", TABLE_I["llama2"]["mbpp"], TABLE_I["qwen35"]["mbpp"], 1.10),
        TaskProfile("gsm8k", TABLE_I["llama2"]["gsm8k"], TABLE_I["qwen35"]["gsm8k"], 1.25),
        TaskProfile("mtbench", TABLE_I["llama2"]["mtbench"], TABLE_I["qwen35"]["mtbench"], 1.25),
        TaskProfile("squad", TABLE_I["llama2"]["squad"], TABLE_I["qwen35"]["squad"], 1.30),
    ]


def sample_device_tasks(K: int, rng: np.random.Generator) -> list[TaskProfile]:
    """i.i.d. task assignment across devices (paper Sec. VI-A1)."""
    profiles = task_profiles()
    return [profiles[i] for i in rng.integers(0, len(profiles), K)]


def sample_prompts(vocab: int, K: int, length: int,
                   rng: np.random.Generator) -> np.ndarray:
    return rng.integers(0, vocab, (K, length)).astype(np.int32)
