"""Training substrate: optimizer, data pipeline, train loop."""

from .data import DataConfig, SyntheticLMDataset, sample_device_tasks, task_profiles  # noqa: F401
from .optimizer import OptimizerConfig, apply_gradients, init_optimizer  # noqa: F401
from .train_loop import lm_loss, make_eval_step, make_train_step  # noqa: F401
