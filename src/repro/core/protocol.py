"""Legacy entry point for the Multi-SPIN round protocol.

DEPRECATED — ``MultiSpinProtocol`` is now a thin compatibility shim over
``repro.serving.cell.MultiSpinCell``, kept for one PR so downstream code
can migrate.  New code should construct the system through
``repro.api``::

    from repro.api import CellConfig, MultiSpinCell, Request

The cell owns the controller, channel, estimator, and round scheduler and
re-plans on device join/leave; the verification compute (synthetic
Bernoulli vs real JAX engine) is a pluggable backend
(``repro.serving.backends``) instead of an ``if self.engine`` fork.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.backends import EngineBackend, SyntheticBackend
from repro.serving.cell import CellConfig, MultiSpinCell, RoundRecord  # noqa: F401 (re-export)
from repro.serving.scheduler import Request

from .channel import ChannelConfig
from .controller import MultiSpinController

_NEVER_RETIRE = 10 ** 12   # shim devices are persistent, not finite requests


@dataclasses.dataclass
class DeviceProfile:
    """Static per-device characteristics (paper Sec. VI-A)."""

    T_S: float            # per-token SLM latency [s]
    alpha: float          # task-level acceptance rate (Table I)
    task: str = ""


class MultiSpinProtocol:
    """Compatibility shim: a fixed-device view of ``MultiSpinCell``.

    Construction submits one never-retiring request per device, so round
    semantics (including rng draw order in the synthetic regime) are
    identical to the pre-cell implementation.
    """

    def __init__(self, controller: MultiSpinController,
                 channel_cfg: ChannelConfig,
                 devices: list[DeviceProfile],
                 rng: np.random.Generator,
                 engine=None,
                 engine_state=None,
                 use_estimator: bool = False,
                 deadline_factor: float | None = None):
        self.controller = controller
        self.channel_cfg = channel_cfg
        self.devices = devices
        self.rng = rng
        cfg = CellConfig(
            scheme=controller.scheme, channel=channel_cfg,
            t_ver_fix=controller.t_ver_model.t_fix,
            t_ver_lin=controller.t_ver_model.t_lin,
            L_max=controller.L_max, L_fixed=controller.L_fixed,
            n_phi=controller.n_phi, n_lam=controller.n_lam,
            max_batch=len(devices), use_estimator=use_estimator,
            deadline_factor=deadline_factor)
        backend = (EngineBackend(engine, engine_state)
                   if engine is not None else SyntheticBackend())
        self.cell = MultiSpinCell(cfg, backend=backend, rng=rng)
        # honor the caller's controller instance verbatim (it may carry
        # custom hyper-parameters the config round-trip would rebuild)
        self.cell.controller = controller
        for i, d in enumerate(devices):
            self.cell.submit(Request(rid=i, prompt_len=0,
                                     max_new_tokens=_NEVER_RETIRE,
                                     alpha=d.alpha, T_S=d.T_S, task=d.task))
        self.cell.admit()

    # ------------------------------------------------------------------

    @property
    def engine(self):
        b = self.cell.backend
        return b.engine if isinstance(b, EngineBackend) else None

    @property
    def engine_state(self):
        b = self.cell.backend
        return b.state if isinstance(b, EngineBackend) else None

    @property
    def estimator(self):
        return self.cell.estimator

    @property
    def channel(self):
        return self.cell.channel

    @property
    def history(self) -> list[RoundRecord]:
        return self.cell.history

    @property
    def _round_idx(self) -> int:
        return self.cell._round_idx

    @property
    def alphas(self) -> np.ndarray:
        return self.cell.planning_alphas(self.cell.scheduler.active)

    @property
    def t_slm(self) -> np.ndarray:
        return np.array([r.T_S for r in self.cell.scheduler.active])

    # ------------------------------------------------------------------

    def run_round(self, key=None) -> RoundRecord:
        return self.cell.step(key=key)

    def run(self, n_rounds: int) -> dict:
        for _ in range(n_rounds):
            self.run_round()
        return self.summary()

    def run_pipelined(self, n_rounds: int) -> dict:
        """Pipelined half-batch schedule (see ``MultiSpinCell`` docs); now a
        schedule option of the cell rather than a synthetic-only fork.  As in
        the legacy implementation the call is fully self-contained: it starts
        with an empty pipe and halves parity 0, returns accounting for only
        this call's rounds (plus the trailing drain), and leaves ``history``
        / ``summary()`` / ``state_dict()`` untouched."""
        prev = self.cell.config.schedule
        mark = len(self.cell.history)
        est = self.cell.estimator
        sched = self.cell.scheduler
        # legacy planned every half-round with the alpha_hat frozen at call
        # entry and never fed outcomes back; silence updates for the call
        if est is not None:
            _est_update, est.update = est.update, lambda *a, **k: None
        snap = (sched.clock, dataclasses.replace(sched.stats),
                [(r, r.generated, r.rounds) for r in sched.active])
        self.cell._pipe_parity = 0
        self.cell.config.schedule = "pipelined"
        try:
            for _ in range(n_rounds):
                self.cell.step()
            recs = list(self.cell.history[mark:])
            tokens = float(sum(np.sum(r.accepted) for r in recs))
            seconds = (float(sum(r.t_round for r in recs))
                       + self.cell._pending_ver)
        finally:
            # legacy kept local accounting only — even on a mid-run failure,
            # drop this call's records and scheduler bookkeeping so sync-round
            # summary()/round_idx/state_dict semantics are preserved, and
            # clear the pipe (its drain is billed here, not in the summary)
            self.cell.config.schedule = prev
            if est is not None:
                est.update = _est_update
            n_piped = len(self.cell.history) - mark
            del self.cell.history[mark:]
            self.cell._round_idx -= n_piped
            self.cell._pending_ver = 0.0
            self.cell._pending_rids = set()
            sched.clock, sched.stats = snap[0], snap[1]
            for r, generated, rounds in snap[2]:
                r.generated, r.rounds = generated, rounds
        return {"rounds": len(recs), "tokens": tokens, "seconds": seconds,
                "goodput": tokens / seconds if seconds else 0.0}

    def summary(self) -> dict:
        return self.cell.summary()

    # ------------------------------------------------------------------

    def state_dict(self) -> dict:
        return self.cell.state_dict()

    def load_state_dict(self, state: dict):
        self.cell.load_state_dict(state)

    def drop_device(self, k: int):
        """Permanent device failure: re-plan for the survivors (elastic)."""
        rid = self.cell.scheduler.active[k].rid
        del self.devices[k]
        self.cell.leave(rid)
        # legacy resampled the survivors' fading block on drop (consuming
        # K-1 exponential draws); replicate for seeded-run reproducibility
        self.cell._refade()
