"""The Multi-SPIN round protocol (paper Sec. III-A, Fig. 2).

``MultiSpinProtocol.run_round`` executes steps 1-5 with full latency
bookkeeping.  Two compute backends:

  * synthetic — acceptance outcomes drawn Bernoulli(alpha_k) (paper's
    analytic regime; used for the large-scale sweeps of Figs. 6-8);
  * engine    — a ``repro.serving.spec_engine.SpecEngine`` running real JAX
    models (used for Fig. 3 empirical curves and integration tests).

Fault-tolerance hooks: device dropout (a device missing its deadline is
skipped this round and its tokens carried over), controller re-planning on
churn, and round-state checkpointing live here as first-class features.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .channel import ChannelConfig, ChannelState
from .controller import AcceptanceEstimator, MultiSpinController
from .goodput import expected_accepted_tokens


@dataclasses.dataclass
class DeviceProfile:
    """Static per-device characteristics (paper Sec. VI-A)."""

    T_S: float            # per-token SLM latency [s]
    alpha: float          # task-level acceptance rate (Table I)
    task: str = ""


@dataclasses.dataclass
class RoundRecord:
    lengths: np.ndarray
    bandwidth: np.ndarray
    accepted: np.ndarray          # realized accepted tokens (incl. bonus)
    t_ma: float
    t_ver: float
    t_round: float
    predicted_goodput: float
    realized_goodput: float
    active: np.ndarray            # device participation mask


class MultiSpinProtocol:
    def __init__(self, controller: MultiSpinController,
                 channel_cfg: ChannelConfig,
                 devices: list[DeviceProfile],
                 rng: np.random.Generator,
                 engine=None,
                 engine_state=None,
                 use_estimator: bool = False,
                 deadline_factor: float | None = None):
        self.controller = controller
        self.channel_cfg = channel_cfg
        self.devices = devices
        self.rng = rng
        self.engine = engine
        self.engine_state = engine_state
        self.estimator = AcceptanceEstimator(len(devices)) if use_estimator else None
        self.deadline_factor = deadline_factor
        self.channel = ChannelState.sample(channel_cfg, len(devices), rng)
        self.history: list[RoundRecord] = []
        self._round_idx = 0

    # ------------------------------------------------------------------

    @property
    def alphas(self) -> np.ndarray:
        if self.estimator is not None:
            return self.estimator.alpha_hat
        return np.array([d.alpha for d in self.devices])

    @property
    def t_slm(self) -> np.ndarray:
        return np.array([d.T_S for d in self.devices])

    def run_round(self, key=None) -> RoundRecord:
        K = len(self.devices)
        # --- step 1: system configuration ---
        self.channel = self.channel.refade(self.rng)       # block fading
        plan = self.controller.plan(self.alphas, self.t_slm, self.channel.rates)
        lengths = np.asarray(plan.lengths, dtype=np.int64)
        bandwidth = np.asarray(plan.bandwidth, dtype=np.float64)

        # --- steps 2-3: drafting + upload latency (straggler-limited) ---
        per_dev_lat = lengths * (self.t_slm + self.controller.q_tok_bits
                                 / np.maximum(bandwidth * self.channel.rates, 1e-9))
        active = np.ones(K, dtype=bool)
        if self.deadline_factor is not None:
            # straggler mitigation: devices missing deadline_factor x median
            # latency are dropped from this round's batch
            deadline = self.deadline_factor * np.median(per_dev_lat)
            active = per_dev_lat <= deadline
            if not active.any():
                active[:] = True
        t_ma = float(np.max(per_dev_lat[active]))

        # --- step 4: batched verification ---
        K_active = int(active.sum())
        t_ver = float(plan.meta.get("t_ver",
                                    self.controller.t_ver_model(K_active)))
        if self.engine is not None:
            import jax
            key = jax.random.PRNGKey(self.rng.integers(2 ** 31)) if key is None else key
            self.engine_state, res, _ = self.engine.spin_round(
                self.engine_state, lengths, key)
            accepted = np.asarray(res.output_len, dtype=np.int64)
            accepted = np.where(active, accepted, 0)
        else:
            # synthetic verification: Bernoulli draws from the TRUE device
            # alphas (the estimator, when enabled, only informs planning)
            true_alpha = np.array([d.alpha for d in self.devices])
            u = self.rng.random((K, int(lengths.max())))
            pos_ok = np.arange(int(lengths.max()))[None, :] < lengths[:, None]
            acc = (u < true_alpha[:, None]) & pos_ok
            n = np.sum(np.cumprod(acc, axis=1), axis=1)
            accepted = np.where(active, n + 1, 0)

        # --- step 5: feedback / estimator update ---
        if self.estimator is not None:
            self.estimator.update(np.maximum(accepted - 1, 0), lengths)

        t_round = t_ma + t_ver
        rec = RoundRecord(
            lengths=lengths, bandwidth=bandwidth, accepted=accepted,
            t_ma=t_ma, t_ver=t_ver, t_round=t_round,
            predicted_goodput=plan.goodput,
            realized_goodput=float(np.sum(accepted) / t_round),
            active=active,
        )
        self.history.append(rec)
        self._round_idx += 1
        return rec

    def run(self, n_rounds: int) -> dict:
        for _ in range(n_rounds):
            self.run_round()
        return self.summary()

    # ------------------------------------------------------------------
    # Beyond-paper: pipelined half-batch schedule (core.beyond). While half
    # A drafts+uploads, the server verifies half B; wall-clock per half-round
    # is max(T_ma(current half), T_ver(other half)).
    # ------------------------------------------------------------------

    def run_pipelined(self, n_rounds: int) -> dict:
        K = len(self.devices)
        idx = np.argsort([d.alpha for d in self.devices])
        halves = [list(idx[0::2]), list(idx[1::2])]
        total_tokens, total_time = 0.0, 0.0
        pending_ver: float | None = None   # T_ver of the half now verifying
        for i in range(n_rounds):
            h = halves[i % 2]
            self.channel = self.channel.refade(self.rng)
            alphas = self.alphas[h]
            t_slm = self.t_slm[h]
            rates = self.channel.rates[h]
            plan = self.controller.plan(alphas, t_slm, rates)
            lengths = np.asarray(plan.lengths, dtype=np.int64)
            per_dev = lengths * (t_slm + self.controller.q_tok_bits
                                 / np.maximum(np.asarray(plan.bandwidth)
                                              * rates, 1e-9))
            t_ma = float(np.max(per_dev))
            # overlap with the other half's verification
            step_time = max(t_ma, pending_ver or 0.0)
            t_ver = float(plan.meta.get(
                "t_ver", self.controller.t_ver_model(len(h))))
            pending_ver = t_ver
            true_alpha = np.array([self.devices[j].alpha for j in h])
            u = self.rng.random((len(h), int(lengths.max())))
            ok = np.arange(int(lengths.max()))[None, :] < lengths[:, None]
            acc = (u < true_alpha[:, None]) & ok
            n = np.sum(np.cumprod(acc, axis=1), axis=1) + 1
            total_tokens += float(np.sum(n))
            total_time += step_time
        total_time += pending_ver or 0.0   # drain the pipe
        return {"rounds": n_rounds, "tokens": total_tokens,
                "seconds": total_time,
                "goodput": total_tokens / total_time if total_time else 0.0}

    def summary(self) -> dict:
        total_tokens = float(sum(np.sum(r.accepted) for r in self.history))
        total_time = float(sum(r.t_round for r in self.history))
        return {
            "rounds": len(self.history),
            "tokens": total_tokens,
            "seconds": total_time,
            "goodput": total_tokens / total_time if total_time else 0.0,
            "mean_predicted_goodput": float(np.mean(
                [r.predicted_goodput for r in self.history])),
        }

    # ------------------------------------------------------------------
    # Fault tolerance: round-state checkpoint/restore (serving pods restart
    # mid-conversation without losing protocol state).
    # ------------------------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "round_idx": self._round_idx,
            "avg_gains": self.channel.avg_gains,
            "alpha_hat": (self.estimator.alpha_hat
                          if self.estimator is not None else None),
        }

    def load_state_dict(self, state: dict):
        self._round_idx = state["round_idx"]
        self.channel = ChannelState.sample(self.channel_cfg, len(self.devices),
                                           self.rng, avg_gains=state["avg_gains"])
        if state.get("alpha_hat") is not None and self.estimator is not None:
            self.estimator.alpha_hat = state["alpha_hat"]

    def drop_device(self, k: int):
        """Permanent device failure: re-plan for the survivors (elastic)."""
        del self.devices[k]
        self.channel = ChannelState.sample(
            self.channel_cfg, len(self.devices), self.rng,
            avg_gains=np.delete(self.channel.avg_gains, k))
        if self.estimator is not None:
            self.estimator.alpha_hat = np.delete(self.estimator.alpha_hat, k)
