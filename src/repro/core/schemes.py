"""Draft-control scheme registry.

Every multi-access draft-control scheme the controller can run is registered
here under a stable name via ``@register_scheme``.  The CLI, benchmarks, and
docs enumerate ``available_schemes()`` instead of hard-coding choice lists,
so adding a scheme is a single decorated function — nothing else can drift.

A solver receives the owning ``MultiSpinController`` (for the latency model
and search hyper-parameters) plus the per-round cell observation
(acceptance estimates, device compute speeds, channel spectrum
efficiencies) and returns a ``DraftControlSolution``.
"""

from __future__ import annotations

from typing import Callable, Protocol

import numpy as np

from .draft_control import (
    DraftControlSolution,
    solve_fixed,
    solve_heterogeneous,
    solve_homogeneous_exhaustive,
    solve_uniform_bandwidth,
)


class SchemeSolver(Protocol):
    def __call__(self, controller, alphas: np.ndarray, T_S: np.ndarray,
                 rates: np.ndarray) -> DraftControlSolution: ...


_REGISTRY: dict[str, SchemeSolver] = {}


def register_scheme(name: str) -> Callable[[SchemeSolver], SchemeSolver]:
    """Register ``fn`` as the solver for scheme ``name``."""

    def deco(fn: SchemeSolver) -> SchemeSolver:
        if name in _REGISTRY:
            raise ValueError(f"scheme {name!r} already registered")
        _REGISTRY[name] = fn
        return fn

    return deco


def get_scheme(name: str) -> SchemeSolver:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scheme {name!r}; available: "
                       f"{', '.join(available_schemes())}") from None


def available_schemes() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Paper schemes (Sec. IV/V) + baselines (Sec. VI-A4)
# ---------------------------------------------------------------------------

def _common_kw(controller, T_S, rates) -> dict:
    return dict(T_S=T_S, r=rates, Q_tok=controller.q_tok_bits,
                B=controller.bandwidth_hz)


@register_scheme("hete")
def _solve_hete(controller, alphas, T_S, rates) -> DraftControlSolution:
    """Algorithm 1: joint heterogeneous lengths + bandwidth."""
    return solve_heterogeneous(
        alphas, T_ver=controller.t_ver_model(len(alphas)),
        L_max=controller.L_max, n_phi=controller.n_phi,
        n_lam=controller.n_lam, **_common_kw(controller, T_S, rates))


@register_scheme("hete-packed")
def _solve_hete_packed(controller, alphas, T_S, rates) -> DraftControlSolution:
    """Beyond-paper: heterogeneous lengths under ragged packed verification."""
    from .beyond import TokenBudgetVerifier, solve_heterogeneous_packed
    verifier = TokenBudgetVerifier.from_affine(
        controller.t_ver_model.t_fix, controller.t_ver_model.t_lin)
    return solve_heterogeneous_packed(
        alphas, verifier=verifier, L_max=controller.L_max,
        n_phi=controller.n_phi, n_lam=controller.n_lam,
        **_common_kw(controller, T_S, rates))


@register_scheme("homo")
def _solve_homo(controller, alphas, T_S, rates) -> DraftControlSolution:
    """Homo-Multi-SPIN: optimal uniform length, Lemma-1 bandwidth."""
    return solve_homogeneous_exhaustive(
        alphas, T_ver=controller.t_ver_model(len(alphas)),
        L_max=controller.L_max, **_common_kw(controller, T_S, rates))


@register_scheme("uni-bw")
def _solve_uni_bw(controller, alphas, T_S, rates) -> DraftControlSolution:
    """Uni-BW Multi-SPIN: heterogeneous lengths under B_k = B/K."""
    return solve_uniform_bandwidth(
        alphas, T_ver=controller.t_ver_model(len(alphas)),
        L_max=controller.L_max, **_common_kw(controller, T_S, rates))


@register_scheme("fixed")
def _solve_fixed(controller, alphas, T_S, rates) -> DraftControlSolution:
    """Fixed BW&L baseline: L_k = L_fixed, B_k = B/K."""
    return solve_fixed(
        alphas, T_ver=controller.t_ver_model(len(alphas)),
        L_fixed=controller.L_fixed, **_common_kw(controller, T_S, rates))
