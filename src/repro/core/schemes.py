"""Structured Observation→RoundPlan draft-control scheme API.

Every multi-access draft-control scheme is a registered ``Scheme`` class:
the cell assembles a ``CellObservation`` each round (acceptance estimates,
device speeds, channel rates, latency models, spectrum budget, deadline
info) and the scheme returns a ``RoundPlan`` (per-device draft lengths,
bandwidth shares, verification mode, multi-draft width, predicted goodput).
The CLI, benchmarks, and docs enumerate ``available_schemes()`` and derive
``--scheme-arg`` parsing, help text, and the README table from each
scheme's declared ``Params`` dataclass and capability flags — nothing can
drift.

Registering a scheme is one decorated class::

    @register_scheme
    class MyScheme(Scheme):
        name = "my-scheme"

        @dataclasses.dataclass(frozen=True)
        class Params:
            boost: float = 1.0

        def plan(self, obs: CellObservation) -> RoundPlan:
            ...

The analytic solvers themselves live in ``draft_control``/``beyond``; the
classes here adapt the observation record onto them and annotate the
solution with the plan-level control surface (verification mode, J,
server-drafting latency) the cell executes.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar

import numpy as np

from .draft_control import (
    DraftControlSolution,
    solve_centralized,
    solve_fixed,
    solve_heterogeneous,
    solve_homogeneous_exhaustive,
    solve_p2p,
    solve_uniform_bandwidth,
)
from .goodput import expected_accepted_tokens

VERIFICATION_MODES = ("padded", "packed")


class SchemeCapabilityError(ValueError):
    """A scheme was asked to plan outside its declared capabilities."""


# ---------------------------------------------------------------------------
# The two structured records of the control API
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CellObservation:
    """Everything the controller knows at the start of a round (paper
    Fig. 2, step 1), as one immutable record.

    Device axis arrays are row-aligned with the cell's active set.  The
    latency models are carried as their affine coefficients so the record
    stays numpy/JSON friendly: verification ``T_ver(K) = t_ver_fix +
    K*t_ver_lin`` (paper eq. 7) and server-side drafting (Cen-SPIN)
    ``t_draft_fix + K*t_draft_lin`` per drafted token.
    """

    alphas: np.ndarray            # per-device acceptance estimates
    T_S: np.ndarray               # per-device SLM per-token latency [s]
    rates: np.ndarray             # uplink spectrum efficiencies [bit/s/Hz]
    q_tok_bits: float             # per-token uplink payload (paper eq. 9)
    bandwidth_hz: float           # total OFDMA bandwidth budget B
    t_ver_fix: float              # verification latency model (eq. 7)
    t_ver_lin: float
    t_draft_fix: float = 0.0      # server drafting model (Cen-SPIN)
    t_draft_lin: float = 0.0
    L_max: int = 25               # admissible draft-length ceiling
    n_phi: int = 40               # Algorithm-1 grid resolution
    n_lam: int = 40
    deadline_factor: float | None = None  # straggler deadline x median T_ma

    @property
    def K(self) -> int:
        return len(self.alphas)

    def t_ver(self, K: int | None = None) -> float:
        """Batched verification latency for ``K`` sequences (eq. 7)."""
        return self.t_ver_fix + (self.K if K is None else K) * self.t_ver_lin

    def t_draft_per_token(self, K: int | None = None) -> float:
        """Server-side per-token draft latency for a K-sequence batch."""
        return self.t_draft_fix + (self.K if K is None else K) * self.t_draft_lin

    def take(self, idx) -> "CellObservation":
        """Sub-observation over a subset of devices (pipelined halves)."""
        return dataclasses.replace(
            self, alphas=np.asarray(self.alphas)[idx],
            T_S=np.asarray(self.T_S)[idx], rates=np.asarray(self.rates)[idx])


@dataclasses.dataclass
class RoundPlan:
    """Controller output for one Multi-SPIN round — the full control
    surface the cell executes, replacing the bare ``DraftControlSolution``
    downstream.
    """

    lengths: np.ndarray                # integer draft lengths L_k*
    bandwidth: np.ndarray              # B_k* [Hz] (zeros: no uplink involved)
    goodput: float                     # predicted sum goodput [tokens/s]
    equalized_latency: float           # phi* / predicted T_ma [s]
    verification_mode: str = "padded"  # "padded" | "packed" server batching
    draft_width: int = 1               # multi-draft J (drafts per device)
    t_ver: float | None = None         # scheme-predicted verification latency
                                       # (None -> cell uses its affine model)
    expected_tokens: float | None = None  # predicted accepted tokens / round
    per_device_latency: np.ndarray | None = None  # draft+upload override
                                       # (server-drafting schemes: no uplink)
    meta: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def from_solution(cls, sol: DraftControlSolution, obs: CellObservation,
                      **kw) -> "RoundPlan":
        kw.setdefault("t_ver", sol.meta.get("t_ver"))
        kw.setdefault("expected_tokens", float(np.sum(
            expected_accepted_tokens(obs.alphas, sol.lengths))))
        return cls(lengths=np.asarray(sol.lengths, dtype=np.int64),
                   bandwidth=np.asarray(sol.bandwidth, dtype=np.float64),
                   goodput=float(sol.goodput),
                   equalized_latency=float(sol.equalized_latency),
                   meta=dict(sol.meta), **kw)


# ---------------------------------------------------------------------------
# Scheme base class + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SchemeCapabilities:
    """Declarative capability flags enforced by the cell/config layer."""

    single_user_only: bool = False    # P2P: exactly one device per cell
    server_drafting: bool = False     # Cen-SPIN: no uplink, server drafts
    packed_verification: bool = False  # ragged token-budget verification
    multi_draft: bool = False         # J > 1 drafts per device

    def flags(self) -> tuple[str, ...]:
        return tuple(f.name for f in dataclasses.fields(self)
                     if getattr(self, f.name))


@dataclasses.dataclass(frozen=True)
class _NoParams:
    pass


class Scheme:
    """Base class for registered draft-control schemes.

    Subclasses declare a ``name``, a ``Params`` dataclass (the schema that
    drives ``CellConfig.scheme_params`` validation and ``--scheme-arg``
    parsing), optional ``capabilities`` flags, and implement
    ``plan(obs) -> RoundPlan``.
    """

    name: ClassVar[str]
    Params: ClassVar[type] = _NoParams
    capabilities: ClassVar[SchemeCapabilities] = SchemeCapabilities()

    def __init__(self, **params):
        try:
            self.params = self.Params(**params)
        except TypeError as e:
            valid = {f.name for f in dataclasses.fields(self.Params)}
            unknown = sorted(set(params) - valid)
            if unknown:
                raise ValueError(
                    f"unknown scheme parameter(s) {unknown} for scheme "
                    f"{self.name!r}; valid parameters: "
                    f"{', '.join(sorted(valid)) or '(none)'}") from None
            # e.g. a Params field without a default left unset
            raise ValueError(
                f"invalid scheme_params for scheme {self.name!r}: {e}") \
                from None

    def plan(self, obs: CellObservation) -> RoundPlan:
        raise NotImplementedError

    # -- shared helpers -------------------------------------------------

    def _check(self, obs: CellObservation):
        if self.capabilities.single_user_only and obs.K != 1:
            raise SchemeCapabilityError(
                f"scheme {self.name!r} is single-user (capability "
                f"'single_user_only'): it plans for exactly one device, "
                f"got K={obs.K}")

    def _verifier(self, obs: CellObservation):
        from .beyond import TokenBudgetVerifier
        return TokenBudgetVerifier.from_affine(
            obs.t_ver_fix, obs.t_ver_lin, L_ref=self.params.L_ref,
            kv_fraction=self.params.kv_fraction)


_REGISTRY: dict[str, type[Scheme]] = {}


def register_scheme(cls: type[Scheme]) -> type[Scheme]:
    """Class decorator: register ``cls`` under its declared ``name``."""
    name = getattr(cls, "name", None)
    if not isinstance(name, str) or not name:
        raise ValueError(f"{cls.__name__} must declare a string 'name'")
    if name in _REGISTRY:
        raise ValueError(f"scheme {name!r} already registered")
    if not dataclasses.is_dataclass(cls.Params):
        raise ValueError(f"{cls.__name__}.Params must be a dataclass")
    _REGISTRY[name] = cls
    return cls


def get_scheme(name: str) -> type[Scheme]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scheme {name!r}; available: "
                       f"{', '.join(available_schemes())}") from None


def build_scheme(name: str, params: dict | None = None) -> Scheme:
    """Instantiate the registered scheme with validated parameters."""
    return get_scheme(name)(**(params or {}))


def available_schemes() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Schema-driven CLI parsing / help / docs
# ---------------------------------------------------------------------------

def scheme_param_fields(name: str) -> tuple[dataclasses.Field, ...]:
    return dataclasses.fields(get_scheme(name).Params)


def _coerce(annotation: str, value: str):
    """Coerce a CLI string to a Params field type (annotations are strings
    under ``from __future__ import annotations``)."""
    ann = str(annotation)
    if value.lower() in ("none", "null") and "None" in ann:
        return None
    if "bool" in ann:
        if value.lower() in ("1", "true", "yes"):
            return True
        if value.lower() in ("0", "false", "no"):
            return False
        raise ValueError(f"expected a boolean, got {value!r}")
    if "int" in ann:
        return int(value)
    if "float" in ann:
        return float(value)
    return value


def parse_scheme_args(name: str, kvs: list[str] | None) -> dict:
    """Parse ``--scheme-arg key=val`` pairs against the scheme's schema."""
    fields = {f.name: f for f in scheme_param_fields(name)}
    out: dict = {}
    for kv in kvs or []:
        key, sep, val = kv.partition("=")
        if not sep:
            raise ValueError(f"--scheme-arg expects key=value, got {kv!r}")
        if key not in fields:
            valid = ", ".join(sorted(fields)) or "(none)"
            raise ValueError(f"scheme {name!r} has no parameter {key!r}; "
                             f"valid parameters: {valid}")
        out[key] = _coerce(fields[key].type, val)
    return out


def _param_summary(name: str) -> str:
    return " ".join(f"{f.name}={f.default!r}" for f in scheme_param_fields(name))


def scheme_help_text() -> str:
    """Per-scheme parameter/capability help for CLI epilogs."""
    lines = ["registered schemes (--scheme-arg key=val per parameter):"]
    for name in available_schemes():
        cls = get_scheme(name)
        caps = ", ".join(cls.capabilities.flags()) or "-"
        params = _param_summary(name) or "-"
        lines.append(f"  {name:26s} params: {params:34s} capabilities: {caps}")
    return "\n".join(lines)


def scheme_table_markdown() -> str:
    """README scheme table, generated from the registry."""
    rows = ["| scheme | parameters | capabilities |", "|---|---|---|"]
    for name in available_schemes():
        cls = get_scheme(name)
        params = ", ".join(f"`{f.name}={f.default!r}`"
                           for f in scheme_param_fields(name)) or "—"
        caps = ", ".join(f"`{c}`" for c in cls.capabilities.flags()) or "—"
        rows.append(f"| `{name}` | {params} | {caps} |")
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# Paper schemes (Sec. IV/V) + baselines (Sec. VI-A4)
# ---------------------------------------------------------------------------

@register_scheme
class HeteScheme(Scheme):
    """Algorithm 1: joint heterogeneous lengths + bandwidth (paper Sec. V)."""

    name = "hete"

    def plan(self, obs: CellObservation) -> RoundPlan:
        self._check(obs)
        sol = solve_heterogeneous(
            obs.alphas, T_S=obs.T_S, r=obs.rates, Q_tok=obs.q_tok_bits,
            B=obs.bandwidth_hz, T_ver=obs.t_ver(), L_max=obs.L_max,
            n_phi=obs.n_phi, n_lam=obs.n_lam)
        return RoundPlan.from_solution(sol, obs)


@register_scheme
class HomoScheme(Scheme):
    """Homo-Multi-SPIN: optimal uniform length, Lemma-1 bandwidth."""

    name = "homo"

    def plan(self, obs: CellObservation) -> RoundPlan:
        self._check(obs)
        sol = solve_homogeneous_exhaustive(
            obs.alphas, T_S=obs.T_S, r=obs.rates, Q_tok=obs.q_tok_bits,
            B=obs.bandwidth_hz, T_ver=obs.t_ver(), L_max=obs.L_max)
        return RoundPlan.from_solution(sol, obs)


@register_scheme
class UniBwScheme(Scheme):
    """Uni-BW Multi-SPIN: heterogeneous lengths under B_k = B/K."""

    name = "uni-bw"

    @dataclasses.dataclass(frozen=True)
    class Params:
        n_phi: int = 200       # 1-D latency sweep resolution

    def plan(self, obs: CellObservation) -> RoundPlan:
        self._check(obs)
        sol = solve_uniform_bandwidth(
            obs.alphas, T_S=obs.T_S, r=obs.rates, Q_tok=obs.q_tok_bits,
            B=obs.bandwidth_hz, T_ver=obs.t_ver(), L_max=obs.L_max,
            n_phi=self.params.n_phi)
        return RoundPlan.from_solution(sol, obs)


@register_scheme
class FixedScheme(Scheme):
    """Fixed BW&L baseline: L_k = L_fixed, B_k = B/K."""

    name = "fixed"

    @dataclasses.dataclass(frozen=True)
    class Params:
        L_fixed: int = 8

    def plan(self, obs: CellObservation) -> RoundPlan:
        self._check(obs)
        sol = solve_fixed(
            obs.alphas, T_S=obs.T_S, r=obs.rates, Q_tok=obs.q_tok_bits,
            B=obs.bandwidth_hz, T_ver=obs.t_ver(),
            L_fixed=self.params.L_fixed)
        return RoundPlan.from_solution(sol, obs)


@register_scheme
class P2PScheme(Scheme):
    """P2P-SPIN baseline: one device, full bandwidth, exhaustive L."""

    name = "p2p"
    capabilities = SchemeCapabilities(single_user_only=True)

    def plan(self, obs: CellObservation) -> RoundPlan:
        self._check(obs)
        sol = solve_p2p(
            float(obs.alphas[0]), float(obs.T_S[0]), float(obs.rates[0]),
            obs.q_tok_bits, obs.bandwidth_hz, T_ver_single=obs.t_ver(1),
            L_max=obs.L_max)
        return RoundPlan.from_solution(sol, obs)


@register_scheme
class CenScheme(Scheme):
    """Cen-SPIN baseline: the server drafts AND verifies for all K prompts
    (no uplink; per drafted token the server spends
    ``t_draft_fix + K*t_draft_lin``)."""

    name = "cen"
    capabilities = SchemeCapabilities(server_drafting=True)

    def plan(self, obs: CellObservation) -> RoundPlan:
        self._check(obs)
        if obs.t_draft_fix <= 0.0 and obs.t_draft_lin <= 0.0:
            raise ValueError(
                "scheme 'cen' needs the server draft-latency model: set "
                "t_draft_fix/t_draft_lin on the CellConfig (or controller)")
        sol = solve_centralized(obs.alphas, obs.t_ver(), obs.t_draft_fix,
                                obs.t_draft_lin, L_max=obs.L_max)
        # server drafting: the "multi-access" phase is the batched SLM
        # forward, identical for every device — no uplink to straggle on
        per_dev = sol.lengths.astype(np.float64) * obs.t_draft_per_token()
        return RoundPlan.from_solution(sol, obs, per_device_latency=per_dev)


# ---------------------------------------------------------------------------
# Beyond-paper schemes (core/beyond.py solvers)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _TokenBudgetParams:
    kv_fraction: float = 0.7   # length-agnostic share of T_lin (KV reads)
    L_ref: int = 8             # affine-model calibration draft length


@register_scheme
class HetePackedScheme(Scheme):
    """Heterogeneous lengths under ragged PACKED token-budget verification
    (no zero-pad compute; see ``core/beyond.py``)."""

    name = "hete-packed"
    Params = _TokenBudgetParams
    capabilities = SchemeCapabilities(packed_verification=True)

    def plan(self, obs: CellObservation) -> RoundPlan:
        self._check(obs)
        from .beyond import solve_heterogeneous_packed
        sol = solve_heterogeneous_packed(
            obs.alphas, T_S=obs.T_S, r=obs.rates, Q_tok=obs.q_tok_bits,
            B=obs.bandwidth_hz, verifier=self._verifier(obs),
            L_max=obs.L_max, n_phi=obs.n_phi, n_lam=obs.n_lam)
        return RoundPlan.from_solution(sol, obs, verification_mode="packed")


@register_scheme
class HetePaddedTokenBudgetScheme(Scheme):
    """Same token-budget verifier but ZERO-PADDED batching (paper layout):
    the honest baseline for measuring the packing gain."""

    name = "hete-padded-tokenbudget"
    Params = _TokenBudgetParams

    def plan(self, obs: CellObservation) -> RoundPlan:
        self._check(obs)
        from .beyond import solve_heterogeneous_padded_tokenbudget
        sol = solve_heterogeneous_padded_tokenbudget(
            obs.alphas, T_S=obs.T_S, r=obs.rates, Q_tok=obs.q_tok_bits,
            B=obs.bandwidth_hz, verifier=self._verifier(obs),
            L_max=obs.L_max, n_phi=obs.n_phi, n_lam=obs.n_lam)
        return RoundPlan.from_solution(sol, obs)


@register_scheme
class MultiDraftScheme(Scheme):
    """Joint (L, J) optimization in the uniform regime: each device uploads
    J i.i.d. drafts and the server keeps the longest-accepted one."""

    name = "multidraft"

    @dataclasses.dataclass(frozen=True)
    class Params:
        J_max: int = 6
        J_min: int = 1         # floor the searched widths (engine gates
                               # pin 2 so the tree path always exercises)
        kv_fraction: float = 0.7
        L_ref: int = 8

    capabilities = SchemeCapabilities(multi_draft=True)

    def plan(self, obs: CellObservation) -> RoundPlan:
        self._check(obs)
        from .beyond import solve_uniform_multidraft
        out = solve_uniform_multidraft(
            float(np.mean(obs.alphas)), obs.T_S, obs.rates, obs.q_tok_bits,
            obs.bandwidth_hz, self._verifier(obs), obs.K, L_max=obs.L_max,
            J_max=self.params.J_max, J_min=self.params.J_min)
        best = out["best"]
        K = obs.K
        lengths = np.full(K, int(best["L"]), dtype=np.int64)
        per_dev = np.full(K, float(best["t_ma"]), dtype=np.float64)
        return RoundPlan(
            lengths=lengths,
            bandwidth=np.asarray(out["bandwidth"], dtype=np.float64),
            goodput=float(best["goodput"]),
            equalized_latency=float(best["t_ma"]),
            draft_width=int(best["J"]),
            t_ver=float(best["t_ver"]),
            expected_tokens=float(K * best["E_N"]),
            per_device_latency=per_dev,
            meta={"scheme": "multidraft", "theta": out["theta"],
                  "single_draft": out["single_draft"], "gain": out["gain"]},
        )


if __name__ == "__main__":
    # the README scheme table is generated from here:
    #   PYTHONPATH=src python -m repro.core.schemes
    print(scheme_table_markdown())
