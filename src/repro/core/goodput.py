"""Analytic goodput model of Multi-SPIN (paper Sec. II-C and III-B).

All formulas are namespace-generic (numpy for the float64 controller path,
jnp inside jit-traced experiment sweeps).

Notation (paper):
    alpha_k  token acceptance rate of device k            (eq. 10)
    L_k      draft length of device k
    T_k^S    per-token SLM inference latency of device k  (eq. 2)
    r_k      uplink spectrum efficiency [bit/s/Hz]         (eq. 8)
    B_k      allocated bandwidth [Hz]
    Q_tok    per-token uplink payload [bits]               (eq. 9)
    T_ver    batched verification latency                  (eq. 7)
"""

from __future__ import annotations

import numpy as np


def expected_accepted_tokens(alpha, L, xp=np):
    """E[N_k | L_k] = (1 - alpha^(L+1)) / (1 - alpha)   (paper eq. 12).

    Includes the bonus token sampled from the LLM when the whole draft is
    accepted.  Handles alpha -> 1 (limit is L + 1) and alpha -> 0 (limit 1).
    """
    alpha = xp.asarray(alpha, dtype=xp.float64 if xp is np else None)
    L = xp.asarray(L)
    near_one = xp.abs(1.0 - alpha) < 1e-12
    safe = xp.where(near_one, 0.5, alpha)
    val = (1.0 - safe ** (L + 1.0)) / (1.0 - safe)
    return xp.where(near_one, L + 1.0, val)


def verification_latency(K, t_fix, t_lin):
    """T_ver(K) = T_fix + K * T_lin   (paper eq. 7)."""
    return t_fix + K * t_lin


def per_token_upload_latency(Q_tok, B_k, r_k):
    """Q_tok / (B_k r_k): uplink seconds per drafted token (from eq. 9)."""
    return Q_tok / (B_k * r_k)


def per_token_ma_latency(T_S, Q_tok, B_k, r_k):
    """T_k^S + Q_tok/(B_k r_k): per-token draft+upload latency of device k."""
    return T_S + per_token_upload_latency(Q_tok, B_k, r_k)


def multi_access_latency(L, T_S, Q_tok, B, r, xp=np):
    """T^ma = max_k L_k (T_k^S + Q_tok/(B_k r_k))   (paper eq. 25).

    With scalar ``L`` this specializes to the homogeneous eq. 15.
    """
    L = xp.asarray(L)
    per_tok = per_token_ma_latency(xp.asarray(T_S), Q_tok, xp.asarray(B), xp.asarray(r))
    return xp.max(L * per_tok, axis=-1)


def goodput_homogeneous(alpha, L, theta, T_ver, K, xp=np):
    """Sum goodput under uniform draft length (paper eq. 17 / 18).

    theta is the per-token multi-access latency of the slowest device
    (theta^* after Lemma-1 equalization).
    """
    n_acc = expected_accepted_tokens(alpha, L, xp=xp)
    return K * n_acc / (xp.asarray(L) * theta + T_ver)


def goodput_heterogeneous(alphas, Ls, T_S, Q_tok, B, r, T_ver, xp=np):
    """Sum goodput with per-device draft lengths (paper eq. 26)."""
    n_acc = expected_accepted_tokens(xp.asarray(alphas), xp.asarray(Ls), xp=xp)
    t_ma = multi_access_latency(Ls, T_S, Q_tok, B, r, xp=xp)
    return xp.sum(n_acc, axis=-1) / (t_ma + T_ver)


def goodput_from_equalized_latency(alphas, Ls, phi, T_ver, xp=np):
    """Sum goodput when Lemma 3 has equalized every device latency to phi
    (paper eq. 29)."""
    n_acc = expected_accepted_tokens(xp.asarray(alphas), xp.asarray(Ls), xp=xp)
    return xp.sum(n_acc, axis=-1) / (phi + T_ver)
