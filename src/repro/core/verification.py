"""Server-side speculative verification (paper Sec. II-A2, eq. 4-5).

Implements exact speculative sampling [Leviathan et al. 2023]: each drafted
token is accepted with probability min(1, p_L/p_S); the first rejected
position is replaced by a sample from the calibrated residual distribution
normalize(max(p_L - p_S, 0)); full acceptance earns one bonus token from the
LLM distribution.  The composition is distributed exactly as LLM sampling —
property-tested in tests/test_verification.py.

Supports both dense SLM distributions (co-located engine path) and the
paper's uplink-compressed sparse form (top-|V^hat| values + indices, Sec.
II-B): the device samples from the truncated+renormalized SLM distribution
and uploads exactly that distribution, so verification remains exact.

``verify_tree`` (multi-draft token trees) intentionally implements the
``multidraft`` scheme's MAX-OF-J acceptance law instead of exact
multi-draft speculative sampling — see its docstring for the
distributional tradeoff at J > 1 (J = 1 stays bit-exact).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


@dataclasses.dataclass
class VerifyResult:
    """Outcome of one batched verification round.

    accept_counts: (B,) int32 — n_k in [0, L_k]: accepted draft tokens.
    output_tokens: (B, L+1) int32 — accepted tokens + calibrated/bonus token
        at position n_k; positions > n_k are padding (0).
    output_len:    (B,) int32 — n_k + 1 (paper: N_k, includes the extra token).
    accept_mask:   (B, L) bool — per-position Bernoulli outcomes A_{k,l}.
    """

    accept_counts: jax.Array
    output_tokens: jax.Array
    output_len: jax.Array
    accept_mask: jax.Array


# pytree registration: jitted round steps (serving/compiled.py) return a
# VerifyResult straight through the jit boundary
jax.tree_util.register_dataclass(
    VerifyResult,
    data_fields=["accept_counts", "output_tokens", "output_len",
                 "accept_mask"],
    meta_fields=[])


@dataclasses.dataclass
class TreeVerifyResult(VerifyResult):
    """Outcome of one batched TREE verification round (multi-draft).

    Same commit surface as ``VerifyResult`` (``accept_counts`` /
    ``output_tokens`` / ``output_len`` refer to the LONGEST accepted
    root-to-leaf path), except ``accept_mask`` is per NODE (B, W) — the
    Bernoulli outcome of every tree node's accept test — and ``winner``
    names the draft whose path was committed.
    """

    winner: jax.Array = None        # (B,) int32 winning draft index
    node_valid: jax.Array = None    # (B, W) bool live-node mask


jax.tree_util.register_dataclass(
    TreeVerifyResult,
    data_fields=["accept_counts", "output_tokens", "output_len",
                 "accept_mask", "winner", "node_valid"],
    meta_fields=[])


def sparse_to_dense(idx: jax.Array, val: jax.Array, vocab: int) -> jax.Array:
    """Scatter top-|V^hat| (idx, val) rows into dense (.., V) distributions."""
    out = jnp.zeros(idx.shape[:-1] + (vocab,), val.dtype)
    return _scatter_last(out, idx, val)


def _scatter_last(out, idx, val):
    grids = jnp.meshgrid(*[jnp.arange(s) for s in idx.shape[:-1]], indexing="ij")
    grids = tuple(g[..., None] for g in grids)
    return out.at[grids + (idx,)].add(val)


def truncate_renormalize(probs: jax.Array, k: int):
    """Top-k truncation + renormalization of draft distributions (paper Sec.
    II-B uplink compression).  Returns (idx (.., k), val (.., k))."""
    val, idx = jax.lax.top_k(probs, k)
    val = val / jnp.sum(val, axis=-1, keepdims=True)
    return idx, val


def verify_drafts(key: jax.Array,
                  draft_tokens: jax.Array,     # (B, L) int32
                  draft_probs: jax.Array,      # (B, L) p_S of each drafted token
                  target_logits: jax.Array,    # (B, L+1, V) LLM logits
                  q_dense: jax.Array | None = None,    # (B, L, V) SLM dists
                  q_idx: jax.Array | None = None,      # (B, L, Vhat) sparse form
                  q_val: jax.Array | None = None,
                  draft_len: jax.Array | None = None,  # (B,) true L_k <= L (zero-pad)
                  ) -> VerifyResult:
    """Batched verification of K drafts in one pass (paper protocol step 4).

    ``target_logits[:, l]`` must condition on the prefix + draft tokens < l
    (the engine produces this with one forward_window call).  With
    heterogeneous draft lengths, rows are zero-padded to L = max L_k and
    ``draft_len`` marks each row's true length; padded positions are forced
    to rejection-impossible (they are simply never accepted).
    """
    B, L = draft_tokens.shape
    V = target_logits.shape[-1]
    k_accept, k_resid, k_bonus = jax.random.split(key, 3)
    u = jax.random.uniform(k_accept, (B, L))
    u_resid = jax.random.uniform(k_resid, (B,))

    if q_dense is None:
        # Sparse uplink-compressed SLM rows (the engine hot path): the
        # accept test + prefix count + calibrated residual token run as ONE
        # fused dispatch — the dense residual distribution never
        # materializes between ops (eq. 4 + eq. 5 in one kernel).
        accept, n_acc, calibrated = kops.fused_verify_sample(
            target_logits, draft_tokens, draft_probs, q_idx, q_val,
            u, u_resid, draft_len)
    else:
        # p_L(x_l) for every drafted position — fused softmax+gather kernel.
        flat_logits = target_logits[:, :L].reshape(B * L, V)
        p_target = kops.gather_softmax_prob(
            flat_logits, draft_tokens.reshape(B * L)).reshape(B, L)

        ratio = p_target / jnp.maximum(draft_probs, 1e-30)
        accept = u < jnp.minimum(ratio, 1.0)                  # eq. 4
        if draft_len is not None:
            accept = accept & (jnp.arange(L)[None, :] < draft_len[:, None])
        prefix_ok = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
        n_acc = jnp.sum(prefix_ok, axis=-1)                   # first-rej index

        # --- calibrated residual sample at the first rejection (eq. 5) ---
        sel = jnp.minimum(n_acc, L - 1)
        logits_rej = jnp.take_along_axis(
            target_logits, sel[:, None, None], axis=1)[:, 0]  # (B, V)
        p_rej = jax.nn.softmax(logits_rej.astype(jnp.float32), axis=-1)
        q_rej = jnp.take_along_axis(q_dense, sel[:, None, None], axis=1)[:, 0]
        calibrated = kops.residual_sample(p_rej, q_rej, u_resid)  # (B,)

    # --- bonus token when the whole draft is accepted ---
    true_len = draft_len if draft_len is not None else jnp.full((B,), L)
    logits_bonus = jnp.take_along_axis(
        target_logits, true_len[:, None, None], axis=1)[:, 0]
    bonus = jax.random.categorical(k_bonus, logits_bonus.astype(jnp.float32),
                                   axis=-1).astype(jnp.int32)

    full_accept = n_acc >= true_len
    extra = jnp.where(full_accept, bonus, calibrated)

    # --- assemble outputs: draft[:n] + extra at position n ---
    pos = jnp.arange(L + 1)[None, :]
    n_col = n_acc[:, None]
    padded_draft = jnp.pad(draft_tokens, ((0, 0), (0, 1)))
    out = jnp.where(pos < n_col, padded_draft,
                    jnp.where(pos == n_col, extra[:, None], 0)).astype(jnp.int32)

    return VerifyResult(accept_counts=n_acc.astype(jnp.int32),
                        output_tokens=out,
                        output_len=(n_acc + 1).astype(jnp.int32),
                        accept_mask=accept)


def verify_tree(key: jax.Array,
                tree_tokens: jax.Array,      # (B, W) node tokens
                tree_parents: jax.Array,     # (B, W) parent idx (-1 root, -2 dead)
                tree_depth: jax.Array,       # (B, W) 1-based depth (0 dead)
                tree_probs: jax.Array,       # (B, W) p_S of each node token
                paths: jax.Array,            # (B, J, L) node idx per draft pos
                target_logits: jax.Array,    # (B, W+1, V) tree-window logits
                q_idx: jax.Array,            # (B, W, Vhat) sparse SLM dists
                q_val: jax.Array,
                draft_len: jax.Array,        # (B,) true L_k <= L
                ) -> TreeVerifyResult:
    """Batched token-tree verification (multi-draft protocol step 4).

    ``target_logits`` must come from ONE ancestor-masked window pass over
    [pending, node_0, ...]: the logits at a node's PARENT slot condition on
    exactly the root-to-parent path, so every node runs the standard accept
    test (eq. 4) in parallel.  The committed output is the LONGEST accepted
    root-to-leaf path (ties -> first draft), closed by the calibrated
    residual token at its first rejection (eq. 5) or a bonus token on full
    acceptance — i.e. the engine realization of the ``multidraft`` scheme's
    max-of-J acceptance model.

    At J = 1 (every tree a chain) this consumes the exact rng stream of
    ``verify_drafts`` and commits bit-identical tokens — the exactness
    guarantee of sequential speculative sampling is fully preserved.

    At J > 1 this is deliberately the scheme's MAX-OF-J law, not exact
    multi-draft speculative sampling: each node runs the unmodified
    min(1, p_L/p_S) test, so accepting a sibling after another sibling's
    rejection does NOT discount the residual the way SpecTr/SpecInfer's
    sequential-sibling scheme does, and the committed per-position
    distribution tilts toward draft-supported tokens (e.g. J=2, L=1,
    q=(.5,.5,0), p=(0,.5,.5) commits (0,.75,.25)).  That is the acceptance
    model the paper's ``multidraft`` goodput analysis and the
    ``SyntheticBackend`` assume (E[N] = 1 + sum_l 1-(1-a^l)^J) — parity
    with it is what the engine tests assert.
    """
    B, W = tree_tokens.shape
    V = target_logits.shape[-1]
    J, L = paths.shape[1], paths.shape[2]
    k_accept, k_resid, k_bonus = jax.random.split(key, 3)

    # p_L(token_i | path to parent): logits at each node's parent slot
    # (root parent = pending at slot 0; node i sits at slot i + 1).
    parent_slot = jnp.where(tree_parents >= 0, tree_parents + 1, 0)
    logits_par = jnp.take_along_axis(target_logits, parent_slot[:, :, None],
                                     axis=1)                  # (B, W, V)
    p_target = kops.gather_softmax_prob(
        logits_par.reshape(B * W, V),
        tree_tokens.reshape(B * W)).reshape(B, W)

    ratio = p_target / jnp.maximum(tree_probs, 1e-30)
    u = jax.random.uniform(k_accept, (B, W))
    valid = (tree_depth >= 1) & (tree_depth <= draft_len[:, None])
    accept = (u < jnp.minimum(ratio, 1.0)) & valid            # per NODE

    # per-path acceptance: shared prefixes share their nodes' outcomes
    safe_paths = jnp.maximum(paths, 0).reshape(B, J * L)
    acc_path = jnp.take_along_axis(
        accept.astype(jnp.int32), safe_paths, axis=1).reshape(B, J, L)
    acc_path = jnp.where(paths >= 0, acc_path, 0)
    prefix_ok = jnp.cumprod(acc_path, axis=-1)
    n_path = jnp.sum(prefix_ok, axis=-1)                      # (B, J)
    n_acc = jnp.max(n_path, axis=-1)
    winner = jnp.argmax(n_path, axis=-1).astype(jnp.int32)    # first max

    path_w = jnp.take_along_axis(
        paths, winner[:, None, None], axis=1)[:, 0]           # (B, L)

    # --- calibrated residual at the winner's first rejected node (eq. 5) ---
    sel = jnp.minimum(n_acc, L - 1)
    rej_node = jnp.take_along_axis(path_w, sel[:, None], axis=1)[:, 0]
    rej_node = jnp.maximum(rej_node, 0)     # past-length rows: bonus wins below
    rej_slot = jnp.take_along_axis(parent_slot, rej_node[:, None], axis=1)[:, 0]
    logits_rej = jnp.take_along_axis(target_logits, rej_slot[:, None, None],
                                     axis=1)[:, 0]            # (B, V)
    p_rej = jax.nn.softmax(logits_rej.astype(jnp.float32), axis=-1)
    idx_rej = jnp.take_along_axis(q_idx, rej_node[:, None, None], axis=1)[:, 0]
    val_rej = jnp.take_along_axis(q_val, rej_node[:, None, None], axis=1)[:, 0]
    q_rej = _scatter_last(jnp.zeros((B, V), jnp.float32), idx_rej,
                          val_rej.astype(jnp.float32))
    u_resid = jax.random.uniform(k_resid, (B,))
    calibrated = kops.residual_sample(p_rej, q_rej, u_resid)  # (B,)

    # --- bonus token when the winner's whole draft is accepted ---
    last = jnp.maximum(draft_len - 1, 0)
    last_node = jnp.take_along_axis(path_w, last[:, None], axis=1)[:, 0]
    bonus_slot = jnp.maximum(last_node, 0) + 1
    logits_bonus = jnp.take_along_axis(target_logits, bonus_slot[:, None, None],
                                       axis=1)[:, 0]
    bonus = jax.random.categorical(k_bonus, logits_bonus.astype(jnp.float32),
                                   axis=-1).astype(jnp.int32)

    full_accept = n_acc >= draft_len
    extra = jnp.where(full_accept, bonus, calibrated)

    # --- assemble outputs: winner path[:n] + extra at position n ---
    path_tokens = jnp.take_along_axis(tree_tokens, jnp.maximum(path_w, 0),
                                      axis=1)                 # (B, L)
    pos = jnp.arange(L + 1)[None, :]
    n_col = n_acc[:, None]
    padded_path = jnp.pad(path_tokens, ((0, 0), (0, 1)))
    out = jnp.where(pos < n_col, padded_path,
                    jnp.where(pos == n_col, extra[:, None], 0)).astype(jnp.int32)

    return TreeVerifyResult(accept_counts=n_acc.astype(jnp.int32),
                            output_tokens=out,
                            output_len=(n_acc + 1).astype(jnp.int32),
                            accept_mask=accept,
                            winner=winner,
                            node_valid=valid)
