"""Server-side speculative verification (paper Sec. II-A2, eq. 4-5).

Implements exact speculative sampling [Leviathan et al. 2023]: each drafted
token is accepted with probability min(1, p_L/p_S); the first rejected
position is replaced by a sample from the calibrated residual distribution
normalize(max(p_L - p_S, 0)); full acceptance earns one bonus token from the
LLM distribution.  The composition is distributed exactly as LLM sampling —
property-tested in tests/test_verification.py.

Supports both dense SLM distributions (co-located engine path) and the
paper's uplink-compressed sparse form (top-|V^hat| values + indices, Sec.
II-B): the device samples from the truncated+renormalized SLM distribution
and uploads exactly that distribution, so verification remains exact.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


@dataclasses.dataclass
class VerifyResult:
    """Outcome of one batched verification round.

    accept_counts: (B,) int32 — n_k in [0, L_k]: accepted draft tokens.
    output_tokens: (B, L+1) int32 — accepted tokens + calibrated/bonus token
        at position n_k; positions > n_k are padding (0).
    output_len:    (B,) int32 — n_k + 1 (paper: N_k, includes the extra token).
    accept_mask:   (B, L) bool — per-position Bernoulli outcomes A_{k,l}.
    """

    accept_counts: jax.Array
    output_tokens: jax.Array
    output_len: jax.Array
    accept_mask: jax.Array


def sparse_to_dense(idx: jax.Array, val: jax.Array, vocab: int) -> jax.Array:
    """Scatter top-|V^hat| (idx, val) rows into dense (.., V) distributions."""
    out = jnp.zeros(idx.shape[:-1] + (vocab,), val.dtype)
    return _scatter_last(out, idx, val)


def _scatter_last(out, idx, val):
    grids = jnp.meshgrid(*[jnp.arange(s) for s in idx.shape[:-1]], indexing="ij")
    grids = tuple(g[..., None] for g in grids)
    return out.at[grids + (idx,)].add(val)


def truncate_renormalize(probs: jax.Array, k: int):
    """Top-k truncation + renormalization of draft distributions (paper Sec.
    II-B uplink compression).  Returns (idx (.., k), val (.., k))."""
    val, idx = jax.lax.top_k(probs, k)
    val = val / jnp.sum(val, axis=-1, keepdims=True)
    return idx, val


def verify_drafts(key: jax.Array,
                  draft_tokens: jax.Array,     # (B, L) int32
                  draft_probs: jax.Array,      # (B, L) p_S of each drafted token
                  target_logits: jax.Array,    # (B, L+1, V) LLM logits
                  q_dense: jax.Array | None = None,    # (B, L, V) SLM dists
                  q_idx: jax.Array | None = None,      # (B, L, Vhat) sparse form
                  q_val: jax.Array | None = None,
                  draft_len: jax.Array | None = None,  # (B,) true L_k <= L (zero-pad)
                  ) -> VerifyResult:
    """Batched verification of K drafts in one pass (paper protocol step 4).

    ``target_logits[:, l]`` must condition on the prefix + draft tokens < l
    (the engine produces this with one forward_window call).  With
    heterogeneous draft lengths, rows are zero-padded to L = max L_k and
    ``draft_len`` marks each row's true length; padded positions are forced
    to rejection-impossible (they are simply never accepted).
    """
    B, L = draft_tokens.shape
    V = target_logits.shape[-1]
    k_accept, k_resid, k_bonus = jax.random.split(key, 3)

    # p_L(x_l) for every drafted position — fused softmax+gather kernel.
    flat_logits = target_logits[:, :L].reshape(B * L, V)
    p_target = kops.gather_softmax_prob(
        flat_logits, draft_tokens.reshape(B * L)).reshape(B, L)

    ratio = p_target / jnp.maximum(draft_probs, 1e-30)
    u = jax.random.uniform(k_accept, (B, L))
    accept = u < jnp.minimum(ratio, 1.0)                      # eq. 4
    if draft_len is not None:
        accept = accept & (jnp.arange(L)[None, :] < draft_len[:, None])
    prefix_ok = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_acc = jnp.sum(prefix_ok, axis=-1)                       # (B,) first-rej index

    # --- calibrated residual sample at the first rejected position (eq. 5) ---
    sel = jnp.minimum(n_acc, L - 1)
    logits_rej = jnp.take_along_axis(
        target_logits, sel[:, None, None], axis=1)[:, 0]      # (B, V)
    p_rej = jax.nn.softmax(logits_rej.astype(jnp.float32), axis=-1)
    if q_dense is not None:
        q_rej = jnp.take_along_axis(q_dense, sel[:, None, None], axis=1)[:, 0]
    else:
        idx_rej = jnp.take_along_axis(q_idx, sel[:, None, None], axis=1)[:, 0]
        val_rej = jnp.take_along_axis(q_val, sel[:, None, None], axis=1)[:, 0]
        q_rej = _scatter_last(jnp.zeros((B, V), jnp.float32), idx_rej,
                              val_rej.astype(jnp.float32))
    u_resid = jax.random.uniform(k_resid, (B,))
    calibrated = kops.residual_sample(p_rej, q_rej, u_resid)  # (B,)

    # --- bonus token when the whole draft is accepted ---
    true_len = draft_len if draft_len is not None else jnp.full((B,), L)
    logits_bonus = jnp.take_along_axis(
        target_logits, true_len[:, None, None], axis=1)[:, 0]
    bonus = jax.random.categorical(k_bonus, logits_bonus.astype(jnp.float32),
                                   axis=-1).astype(jnp.int32)

    full_accept = n_acc >= true_len
    extra = jnp.where(full_accept, bonus, calibrated)

    # --- assemble outputs: draft[:n] + extra at position n ---
    pos = jnp.arange(L + 1)[None, :]
    n_col = n_acc[:, None]
    padded_draft = jnp.pad(draft_tokens, ((0, 0), (0, 1)))
    out = jnp.where(pos < n_col, padded_draft,
                    jnp.where(pos == n_col, extra[:, None], 0)).astype(jnp.int32)

    return VerifyResult(accept_counts=n_acc.astype(jnp.int32),
                        output_tokens=out,
                        output_len=(n_acc + 1).astype(jnp.int32),
                        accept_mask=accept)
