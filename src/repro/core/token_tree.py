"""Token-tree packing for multi-draft verification (SpecInfer-style).

Each device uploads J i.i.d. drafts of length L.  Because the SLM
distribution at a position depends only on the token prefix, drafts that
share a token prefix drew from IDENTICAL distributions there — so the J
sequences pack losslessly into a prefix-deduplicated trie: one node per
distinct (parent, token) edge, each node carrying the draft probability and
the uploaded sparse SLM distribution of its position.  The server then
scores ALL nodes in one target pass: the verification window is

    [pending, node_0, node_1, ... ]        (construction order, W+1 slots)

where node i's rope position is ``pos + depth_i`` and attention is masked
to committed KV plus in-window ANCESTORS (``window_mask``).  The target
logits at a node's window slot therefore condition on exactly the
root-to-node path — the quantity tree verification needs for every node's
accept test (``core.verification.verify_tree``).

Construction is host-side numpy (J and L are round-plan sized); everything
returned is padded to the static width W = J * L so the device-side pass
compiles once per (B, J, L) shape.
"""

from __future__ import annotations

import dataclasses

import numpy as np

DEAD = -2  # parent marker for padding nodes (never valid, never attended)
ROOT = -1  # parent marker for depth-1 nodes (their parent is `pending`)


@dataclasses.dataclass
class TokenTreeBatch:
    """A batch of packed draft trees, padded to W = J * L nodes per row.

    tokens:  (B, W) int32   node tokens (0 on dead padding nodes)
    parents: (B, W) int32   in-tree parent index; ROOT (-1) for depth-1
                            nodes, DEAD (-2) marks padding
    depth:   (B, W) int32   1-based node depth (0 on dead nodes)
    probs:   (B, W) f32     p_S of the node token (1.0 on dead nodes so the
                            accept ratio can never fire there)
    q_idx:   (B, W, Vhat)   the node position's uploaded sparse SLM dist
    q_val:   (B, W, Vhat)
    paths:   (B, J, L) int32  node index of draft j's l-th token (shared
                            prefixes point at the same node); -1 past a
                            row's true draft length
    n_nodes: (B,) int32     live node count per row
    """

    tokens: np.ndarray
    parents: np.ndarray
    depth: np.ndarray
    probs: np.ndarray
    q_idx: np.ndarray
    q_val: np.ndarray
    paths: np.ndarray
    n_nodes: np.ndarray

    @property
    def num_drafts(self) -> int:
        return self.paths.shape[1]

    @property
    def width(self) -> int:
        return self.tokens.shape[1]

    def window_tokens(self, pending: np.ndarray) -> np.ndarray:
        """(B, W+1) verification-window tokens: pending at slot 0, node i at
        slot i + 1 (dead nodes ride as zero pads)."""
        pend = np.asarray(pending).reshape(-1, 1)
        return np.concatenate([pend, self.tokens], axis=1).astype(np.int64)

    def window_depth(self) -> np.ndarray:
        """(B, W+1) position offsets of the window: pending at offset 0,
        node i at its tree depth (dead nodes collapse to 0 — their rope
        position is irrelevant, they are never attended)."""
        zero = np.zeros((self.depth.shape[0], 1), self.depth.dtype)
        return np.concatenate([zero, self.depth], axis=1)

    def window_mask(self) -> np.ndarray:
        """(B, W+1, W+1) bool ancestor-or-self matrix over window slots.

        Row/col 0 is the pending token: ancestor of every node, attending
        only itself.  Node i attends pending, its ancestors, and itself.
        Dead nodes keep {pending, self} so their (discarded) softmax row
        stays well-formed; nothing live ever attends them.  A J=1 chain
        yields exactly the lower-triangular causal window mask.
        """
        B, W = self.parents.shape
        T = W + 1
        mask = np.zeros((B, T, T), dtype=bool)
        mask[:, :, 0] = True  # everyone sees pending
        mask[:, 0, 1:] = False  # pending sees only itself
        for b in range(B):
            for i in range(int(self.n_nodes[b])):
                p = self.parents[b, i]
                if p >= 0:
                    mask[b, i + 1] = mask[b, p + 1]
                mask[b, i + 1, i + 1] = True
            for i in range(int(self.n_nodes[b]), W):
                mask[b, i + 1, i + 1] = True  # dead: {pending, self}
        return mask


class TreeScratch:
    """Reusable host-side trie buffers for ``build_token_tree``.

    Multi-draft rounds call the builder every round at a small set of
    recurring (B, J, L) shapes (draft lengths are round-plan bucketed), so
    instead of allocating 8 fresh arrays per call the engine hands the
    builder one of these pools.  Buffers are keyed by the exact
    (B, J, L, Vhat) shape and reset with a HIGH-WATER wipe: only the node
    prefix actually written last round (and the path prefix up to the last
    true draft length) is restored to the fill values, so sparse trees pay
    proportional reset cost, never a full reallocation.

    The returned ``TokenTreeBatch`` ALIASES the pool: it is valid until the
    next ``build_token_tree`` call with the same scratch and shape.  The
    engine uploads the trie to device within the same round, well before
    the next build, so the aliasing is invisible there.
    """

    def __init__(self):
        self._pool: dict[tuple, TokenTreeBatch] = {}
        self._high_water: dict[tuple, tuple[int, int]] = {}

    def acquire(self, B: int, J: int, L: int, Vhat: int) -> TokenTreeBatch:
        key = (B, J, L, Vhat)
        W = J * L
        out = self._pool.get(key)
        if out is None:
            out = _fresh_tree_buffers(B, J, L, Vhat)
            self._pool[key] = out
            return out
        hw_nodes, hw_len = self._high_water.get(key, (W, L))
        out.tokens[:, :hw_nodes] = 0
        out.parents[:, :hw_nodes] = DEAD
        out.depth[:, :hw_nodes] = 0
        out.probs[:, :hw_nodes] = 1.0
        out.q_idx[:, :hw_nodes] = 0
        out.q_val[:, :hw_nodes] = 0.0
        out.paths[:, :, :hw_len] = -1
        out.n_nodes[:] = 0
        return out

    def note(self, B: int, J: int, L: int, Vhat: int, used_nodes: int, used_len: int) -> None:
        """Record how much of the pool the last build touched."""
        self._high_water[(B, J, L, Vhat)] = (int(used_nodes), int(used_len))


def _fresh_tree_buffers(B: int, J: int, L: int, Vhat: int) -> TokenTreeBatch:
    W = J * L
    return TokenTreeBatch(
        tokens=np.zeros((B, W), np.int32),
        parents=np.full((B, W), DEAD, np.int32),
        depth=np.zeros((B, W), np.int32),
        probs=np.ones((B, W), np.float32),
        q_idx=np.zeros((B, W, Vhat), np.int32),
        q_val=np.zeros((B, W, Vhat), np.float32),
        paths=np.full((B, J, L), -1, np.int32),
        n_nodes=np.zeros(B, np.int32),
    )


def build_token_tree(
    tokens: np.ndarray,
    probs: np.ndarray,
    q_idx: np.ndarray,
    q_val: np.ndarray,
    lengths: np.ndarray,
    scratch: TreeScratch | None = None,
) -> TokenTreeBatch:
    """Pack J drafts per row into prefix-deduplicated trees.

    tokens / probs: (B, J, L); q_idx / q_val: (B, J, L, Vhat);
    lengths: (B,) true draft lengths (positions >= lengths_b are padding
    and never become nodes).  ``scratch`` reuses pooled buffers instead of
    allocating — the result then aliases the pool (see ``TreeScratch``).
    """
    tokens = np.asarray(tokens)
    probs = np.asarray(probs)
    q_idx = np.asarray(q_idx)
    q_val = np.asarray(q_val)
    lengths = np.asarray(lengths, dtype=np.int64)
    B, J, L = tokens.shape
    Vhat = q_idx.shape[-1]

    if scratch is not None:
        out = scratch.acquire(B, J, L, Vhat)
    else:
        out = _fresh_tree_buffers(B, J, L, Vhat)
    for b in range(B):
        children: dict[tuple[int, int], int] = {}
        n = 0
        for j in range(J):
            parent = ROOT
            for pos in range(int(lengths[b])):
                tok = int(tokens[b, j, pos])
                key = (parent, tok)
                node = children.get(key)
                if node is None:
                    node = n
                    children[key] = node
                    out.tokens[b, node] = tok
                    out.parents[b, node] = parent
                    out.depth[b, node] = pos + 1
                    out.probs[b, node] = probs[b, j, pos]
                    out.q_idx[b, node] = q_idx[b, j, pos]
                    out.q_val[b, node] = q_val[b, j, pos]
                    n += 1
                out.paths[b, j, pos] = node
                parent = node
        out.n_nodes[b] = n
    if scratch is not None:
        scratch.note(B, J, L, Vhat, int(out.n_nodes.max(initial=0)), int(lengths.max(initial=0)))
    return out
