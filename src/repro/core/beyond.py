"""Beyond-paper goodput optimizations (§Perf / DESIGN.md §3).

The paper's verification-latency model T_ver = T_fix + K*T_lin is
draft-length-agnostic (its footnote 1), and its round is fully synchronous
(T_e2e = T_ma + T_ver).  Two standard serving-systems ideas transfer:

1. **Packed ragged verification** — zero-padding heterogeneous drafts to
   (K, L_max+1) wastes verification compute on pad tokens.  Under the
   token-budget refinement T_ver = T_fix + c_tok * (total window tokens),
   packing the K windows into one ragged batch (block-diagonal attention —
   the flash kernel path supports it via per-row lengths) replaces
   K*(L_max+1) tokens with sum_k (L_k+1).  The heterogeneous-length
   optimizer is re-solved under the packed objective: longer drafts no
   longer inflate other devices' verification cost, which shifts L* upward
   for high-alpha devices.

2. **Pipelined half-batch rounds** — split the K devices into two
   half-cells that alternate: while half A drafts+uploads, half B verifies.
   After pipeline fill, the round period is max(T_ma(K/2), T_ver(K/2))
   instead of T_ma(K) + T_ver(K).  Exactness is untouched (each half runs
   the unmodified protocol); only the schedule changes.

Both are evaluated with the same closed-form machinery as the paper's
optimizer so gains are apples-to-apples (benchmarks/bench_beyond.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bandwidth import solve_equalized_phi, solve_equalized_theta
from .draft_control import (
    DraftControlSolution,
    heterogeneous_lengths,
    round_lengths,
    search_grids,
)
from .goodput import expected_accepted_tokens


@dataclasses.dataclass(frozen=True)
class TokenBudgetVerifier:
    """Two-part verification cost: per-sequence + per-window-token.

        T_ver = T_fix + K * c_seq + c_tok * (total window tokens)

    The per-sequence term c_seq models the length-AGNOSTIC work (reading the
    device's whole KV cache / prefix state — the memory-bound bulk of batched
    verification, and the reason the paper's length-agnostic T_lin is a good
    model); c_tok models the pad-sensitive per-token compute.  Calibrated
    against the paper's affine model at a reference draft length with a
    kv_fraction split:  T_lin = c_seq + c_tok * (L_ref + 1).
    """

    t_fix: float
    c_seq: float
    c_tok: float

    @classmethod
    def from_affine(cls, t_fix: float, t_lin: float, L_ref: int = 8,
                    kv_fraction: float = 0.7):
        return cls(t_fix=t_fix, c_seq=t_lin * kv_fraction,
                   c_tok=t_lin * (1 - kv_fraction) / (L_ref + 1))

    def padded(self, K: int, L_max):
        """Zero-padded batch cost; ``L_max`` may carry batch dimensions."""
        return self.t_fix + self.c_seq * K + self.c_tok * K * (L_max + 1.0)

    def packed(self, lengths: np.ndarray) -> float:
        K = len(lengths)
        return (self.t_fix + self.c_seq * K
                + self.c_tok * float(np.sum(np.asarray(lengths) + 1.0)))


def solve_heterogeneous_packed(alphas, T_S, r, Q_tok, B,
                               verifier: TokenBudgetVerifier,
                               L_max: int = 25, n_phi: int = 40,
                               n_lam: int = 40) -> DraftControlSolution:
    """Algorithm-1 grid search under the PACKED token-budget objective.

    Proposition-1 lengths remain the candidate generator (they solve the
    constant-T_ver KKT system); each candidate is re-scored with the packed
    objective, so the returned solution maximizes the true packed goodput
    over the candidate set (near-optimal; exact for the paper's objective).
    """
    alphas = np.asarray(alphas, dtype=np.float64)
    T_S = np.asarray(T_S, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)

    phis, lams = search_grids(alphas, T_S, r, Q_tok, B, L_max, n_phi, n_lam)
    PH, LM = np.meshgrid(phis, lams, indexing="ij")
    grid = np.stack([PH.ravel(), LM.ravel()], axis=-1)

    L_tilde = heterogeneous_lengths(grid[:, :1], grid[:, 1:2], alphas[None, :],
                                    T_S[None, :], r[None, :], Q_tok)
    L_int = round_lengths(np.nan_to_num(L_tilde, nan=1.0), L_max)
    phi_hat, _ = solve_equalized_phi(L_int, T_S[None, :], r[None, :], Q_tok, B)

    n_acc = np.sum(expected_accepted_tokens(alphas[None, :], L_int), axis=-1)
    K = len(alphas)
    t_ver = (verifier.t_fix + verifier.c_seq * K
             + verifier.c_tok * np.sum(L_int + 1.0, axis=-1))
    tau = n_acc / (phi_hat + t_ver)
    tau = np.where(np.isfinite(tau), tau, -np.inf)

    best = int(np.argmax(tau))
    L_best = L_int[best].astype(np.int64)
    phi_best, B_best = solve_equalized_phi(L_best, T_S, r, Q_tok, B)
    return DraftControlSolution(
        lengths=L_best, bandwidth=np.asarray(B_best), goodput=float(tau[best]),
        equalized_latency=float(phi_best),
        meta={"scheme": "hete-packed", "t_ver": float(t_ver[best])},
    )


def solve_heterogeneous_padded_tokenbudget(alphas, T_S, r, Q_tok, B,
                                           verifier: TokenBudgetVerifier,
                                           L_max: int = 25, n_phi: int = 40,
                                           n_lam: int = 40) -> DraftControlSolution:
    """Same token-budget verifier but ZERO-PADDED batching (paper layout):
    T_ver charges K * (max L_k + 1) tokens.  The honest baseline for
    measuring the packing gain."""
    alphas = np.asarray(alphas, dtype=np.float64)
    T_S = np.asarray(T_S, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)
    K = len(alphas)

    phis, lams = search_grids(alphas, T_S, r, Q_tok, B, L_max, n_phi, n_lam)
    PH, LM = np.meshgrid(phis, lams, indexing="ij")
    grid = np.stack([PH.ravel(), LM.ravel()], axis=-1)
    L_tilde = heterogeneous_lengths(grid[:, :1], grid[:, 1:2], alphas[None, :],
                                    T_S[None, :], r[None, :], Q_tok)
    L_int = round_lengths(np.nan_to_num(L_tilde, nan=1.0), L_max)
    phi_hat, _ = solve_equalized_phi(L_int, T_S[None, :], r[None, :], Q_tok, B)
    n_acc = np.sum(expected_accepted_tokens(alphas[None, :], L_int), axis=-1)
    t_ver = verifier.padded(K, np.max(L_int, axis=-1))  # vectorized over grid
    tau = n_acc / (phi_hat + t_ver)
    tau = np.where(np.isfinite(tau), tau, -np.inf)
    best = int(np.argmax(tau))
    L_best = L_int[best].astype(np.int64)
    phi_best, B_best = solve_equalized_phi(L_best, T_S, r, Q_tok, B)
    return DraftControlSolution(
        lengths=L_best, bandwidth=np.asarray(B_best), goodput=float(tau[best]),
        equalized_latency=float(phi_best),
        # the token-budget padded cost is the scheme's OWN verification
        # model — carried in meta so executed rounds bill it instead of the
        # affine T_ver(K) (same contract as the packed solver)
        meta={"scheme": "hete-padded-tokenbudget", "t_ver": float(t_ver[best])},
    )


def pipelined_plan(scheme, obs) -> dict:
    """Two half-batch pipeline: steady-state period = max(T_ma, T_ver).

    ``scheme`` is a registered ``repro.core.schemes.Scheme`` instance and
    ``obs`` the full-cell ``CellObservation``; each half is planned on its
    sub-observation at the FULL bandwidth (the other half is in its verify
    phase while this one uploads).  Returns
    ``{goodput, period, halves: [RoundPlan]}``.
    """
    if scheme.capabilities.server_drafting:
        raise ValueError(
            f"scheme {scheme.name!r} drafts on the server (capability "
            f"'server_drafting'): a two-half pipeline would overlap the "
            f"server's own drafting with its own verification")
    alphas = np.asarray(obs.alphas, dtype=np.float64)
    idx = np.argsort(alphas)          # interleave to balance the halves
    halves = [h for h in (idx[0::2], idx[1::2]) if len(h)]
    total_tokens, plans, t_ma, t_ver = 0.0, [], [], []
    for h in halves:
        obs_h = obs.take(h)
        plan = scheme.plan(obs_h)
        total_tokens += (float(plan.expected_tokens)
                         if plan.expected_tokens is not None else
                         float(np.sum(expected_accepted_tokens(alphas[h],
                                                               plan.lengths))))
        t_ma.append(plan.equalized_latency)
        # a scheme with its own verification model reports the true t_ver
        t_ver.append(float(plan.t_ver) if plan.t_ver is not None
                     else obs_h.t_ver())
        plans.append(plan)
    if len(halves) == 1:              # K == 1: nothing to overlap with
        period = t_ma[0] + t_ver[0]
    else:
        # steady-state cycle: verify(A) overlaps draft/upload(B), vice versa
        period = (max(t_ma[0], t_ver[1]) + max(t_ma[1], t_ver[0]))
    return {"goodput": total_tokens / period, "period": float(period),
            "halves": plans}


# ---------------------------------------------------------------------------
# Multi-draft verification (paper Sec. I cites [25]: multiple drafts raise
# acceptance at higher local C2 cost — here the tradeoff is OPTIMIZED)
# ---------------------------------------------------------------------------

def expected_accepted_multidraft(alpha, L, J, xp=np):
    """E[N] when each device uploads J i.i.d. drafts of length L and the
    server keeps the longest-accepted one (SpecInfer-style tree verification
    preserves exactness).

    N = max_j n_j + 1 with n_j ~ geometric truncated at L:
    P(n_j >= l) = alpha^l  =>  E[max] = sum_{l=1..L} (1 - (1 - alpha^l)^J).
    J = 1 reduces to eq. 12.
    """
    alpha = xp.asarray(alpha, dtype=np.float64 if xp is np else None)
    ls = xp.arange(1, L + 1)
    surv = 1.0 - (1.0 - alpha[..., None] ** ls) ** J
    return xp.sum(surv, axis=-1) + 1.0


def solve_uniform_multidraft(alpha, T_S, r, Q_tok, B,
                             verifier: TokenBudgetVerifier, K: int,
                             L_max: int = 25, J_max: int = 6,
                             J_min: int = 1) -> dict:
    """Joint (L, J) optimization in the uniform regime, vectorized over the
    whole (J, L) grid.

    Per round: each device drafts J*L tokens locally (J sequential draft
    passes share the prefix KV, so drafting costs J*L*T_S), uploads J*L
    token payloads, and the server verifies K*J sequences of L+1 window
    tokens.  Returns the grid optimum and the J=1 (paper) baseline, plus
    the Lemma-1 bandwidth shares at the winning J.  ``J_min`` floors the
    searched widths (engine benchmarks pin J_min=2 to exercise the tree
    path even where the latency model prefers J*=1); the reported
    ``single_draft`` baseline is always the true J=1 optimum.
    """
    if not 1 <= J_min <= J_max:
        raise ValueError(f"need 1 <= J_min <= J_max, got "
                         f"J_min={J_min}, J_max={J_max}")
    T_S = np.asarray(T_S, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)
    Kd = len(T_S)
    Js = np.arange(1, J_max + 1, dtype=np.float64)
    # Equalized theta with a J-fold payload: eq. 20 with Q_tok*J is the same
    # root as eq. 20 with budget B/J (both sides scale by J), which batches
    # all J rows through one bisection.  The realized shares are then
    # B_k = J * B_k(scaled).
    theta_J, B_scaled = solve_equalized_theta(
        np.broadcast_to(T_S, (J_max, Kd)), np.broadcast_to(r, (J_max, Kd)),
        Q_tok, B / Js)

    Ls = np.arange(1, L_max + 1, dtype=np.float64)
    # E[N](J, L) = 1 + sum_{l<=L} (1 - (1 - alpha^l)^J): cumulative sum of
    # the survival terms gives every L at once.
    surv = 1.0 - (1.0 - np.float64(alpha) ** Ls[None, :]) ** Js[:, None]
    e_n = np.cumsum(surv, axis=1) + 1.0                       # (J, L)
    t_ma = Ls[None, :] * theta_J[:, None]
    t_ver = (verifier.t_fix + verifier.c_seq * K * Js[:, None]
             + verifier.c_tok * K * Js[:, None] * (Ls[None, :] + 1.0))
    tau = K * e_n / (t_ma + t_ver)

    def rec(j: int, l: int) -> dict:
        return {"goodput": float(tau[j, l]), "L": int(Ls[l]), "J": int(Js[j]),
                "E_N": float(e_n[j, l]), "t_ma": float(t_ma[j, l]),
                "t_ver": float(t_ver[j, l])}

    tau_adm = tau[J_min - 1:]                   # admissible J >= J_min
    j_adm, l_best = np.unravel_index(int(np.argmax(tau_adm)), tau_adm.shape)
    j_best = j_adm + J_min - 1
    best = rec(j_best, l_best)
    base = rec(0, int(np.argmax(tau[0])))
    return {"best": best, "single_draft": base,
            "gain": best["goodput"] / base["goodput"] - 1.0,
            "theta": float(theta_J[j_best]),
            "bandwidth": Js[j_best] * B_scaled[j_best]}
