"""Server-side multi-access draft controller (paper protocol step 1).

Each round the server receives device profiles (acceptance rate, compute
speed), measures uplink channels, and solves the multi-access draft control
problem for the configured scheme.  Also hosts the online acceptance-rate
estimator (EWMA over realized accept fractions) used when task profiles are
not declared a priori.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .draft_control import (
    DraftControlSolution,
    solve_fixed,
    solve_heterogeneous,
    solve_homogeneous_exhaustive,
    solve_uniform_bandwidth,
)

SCHEMES = ("hete", "homo", "uni-bw", "fixed", "hete-packed")


@dataclasses.dataclass
class VerificationLatencyModel:
    """T_ver(K) = T_fix + K T_lin (paper eq. 7), fitted per target model."""

    t_fix: float
    t_lin: float

    def __call__(self, K: int) -> float:
        return self.t_fix + K * self.t_lin


@dataclasses.dataclass
class MultiSpinController:
    scheme: str
    q_tok_bits: float
    bandwidth_hz: float
    t_ver_model: VerificationLatencyModel
    L_max: int = 25
    L_fixed: int = 8
    n_phi: int = 40
    n_lam: int = 40

    def __post_init__(self):
        assert self.scheme in SCHEMES, self.scheme

    def plan(self, alphas: np.ndarray, T_S: np.ndarray,
             rates: np.ndarray) -> DraftControlSolution:
        K = len(alphas)
        T_ver = self.t_ver_model(K)
        kw = dict(T_S=T_S, r=rates, Q_tok=self.q_tok_bits,
                  B=self.bandwidth_hz, T_ver=T_ver)
        if self.scheme == "hete":
            return solve_heterogeneous(alphas, L_max=self.L_max,
                                       n_phi=self.n_phi, n_lam=self.n_lam, **kw)
        if self.scheme == "hete-packed":
            from .beyond import TokenBudgetVerifier, solve_heterogeneous_packed
            verifier = TokenBudgetVerifier.from_affine(
                self.t_ver_model.t_fix, self.t_ver_model.t_lin)
            kw.pop("T_ver")
            return solve_heterogeneous_packed(
                alphas, verifier=verifier, L_max=self.L_max,
                n_phi=self.n_phi, n_lam=self.n_lam, **kw)
        if self.scheme == "homo":
            return solve_homogeneous_exhaustive(alphas, L_max=self.L_max, **kw)
        if self.scheme == "uni-bw":
            return solve_uniform_bandwidth(alphas, L_max=self.L_max, **kw)
        return solve_fixed(alphas, L_fixed=self.L_fixed, **kw)


class AcceptanceEstimator:
    """Online EWMA estimate of per-device acceptance rates from realized
    verification outcomes (used when devices do not report task profiles)."""

    def __init__(self, K: int, prior: float = 0.8, decay: float = 0.9):
        self.succ = np.full(K, prior)       # EWMA accepted Bernoulli trials
        self.trials = np.ones(K)            # EWMA total Bernoulli trials
        self.decay = decay

    @property
    def alpha_hat(self) -> np.ndarray:
        return np.clip(self.succ / np.maximum(self.trials, 1e-9), 0.01, 0.995)

    @alpha_hat.setter
    def alpha_hat(self, value):
        self.succ = np.asarray(value, dtype=np.float64).copy()
        self.trials = np.ones_like(self.succ)

    def update(self, accept_counts: np.ndarray, lengths: np.ndarray):
        """Each accepted draft token is a Bernoulli success; the (at most one)
        rejection is a failure.  EWMA of successes and trials separately —
        the ratio-of-sums estimator is consistent for the truncated
        geometric, unlike the per-round mean of ratios."""
        counts = np.asarray(accept_counts, dtype=np.float64)
        lengths = np.maximum(np.asarray(lengths, dtype=np.float64), 1.0)
        rejected = (counts < lengths).astype(np.float64)
        self.succ = self.decay * self.succ + (1 - self.decay) * counts
        self.trials = self.decay * self.trials + (1 - self.decay) * (counts + rejected)
        return self.alpha_hat
