"""Server-side multi-access draft controller (paper protocol step 1).

Each round the server receives device profiles (acceptance rate, compute
speed), measures uplink channels, assembles a ``CellObservation``, and asks
the configured scheme for a ``RoundPlan``.  Also hosts the online
acceptance-rate estimator (EWMA over realized accept fractions) used when
task profiles are not declared a priori.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .schemes import (
    CellObservation,
    RoundPlan,
    available_schemes,
    get_scheme,
)

def __getattr__(name):
    # Derived live from the scheme registry — register new schemes in
    # ``repro.core.schemes``; a scheme registered after import (the
    # ``@register_scheme`` extension point) is visible here immediately.
    if name == "SCHEMES":
        return available_schemes()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class VerificationLatencyModel:
    """T_ver(K) = T_fix + K T_lin (paper eq. 7), fitted per target model.

    The same affine-in-batch law models server-side drafting for Cen-SPIN
    (a batched SLM forward per drafted token)."""

    t_fix: float
    t_lin: float

    def __call__(self, K: int) -> float:
        return self.t_fix + K * self.t_lin


@dataclasses.dataclass
class MultiSpinController:
    scheme: str
    q_tok_bits: float
    bandwidth_hz: float
    t_ver_model: VerificationLatencyModel
    t_draft_model: VerificationLatencyModel | None = None  # Cen-SPIN drafting
    L_max: int = 25
    L_fixed: int = 8
    n_phi: int = 40
    n_lam: int = 40
    deadline_factor: float | None = None
    scheme_params: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        params = dict(self.scheme_params)
        # legacy knob: the fixed scheme's length rides on the controller, so
        # CellConfig(L_fixed=...) keeps working; scheme_params wins when set
        cls = get_scheme(self.scheme)
        if "L_fixed" in {f.name for f in dataclasses.fields(cls.Params)}:
            params.setdefault("L_fixed", self.L_fixed)
        self.scheme_obj = cls(**params)

    def observe(self, alphas: np.ndarray, T_S: np.ndarray,
                rates: np.ndarray) -> CellObservation:
        """Assemble the per-round observation record for the scheme."""
        td = self.t_draft_model
        return CellObservation(
            alphas=np.asarray(alphas, dtype=np.float64),
            T_S=np.asarray(T_S, dtype=np.float64),
            rates=np.asarray(rates, dtype=np.float64),
            q_tok_bits=self.q_tok_bits, bandwidth_hz=self.bandwidth_hz,
            t_ver_fix=self.t_ver_model.t_fix, t_ver_lin=self.t_ver_model.t_lin,
            t_draft_fix=(td.t_fix if td is not None else 0.0),
            t_draft_lin=(td.t_lin if td is not None else 0.0),
            L_max=self.L_max, n_phi=self.n_phi, n_lam=self.n_lam,
            deadline_factor=self.deadline_factor)

    def plan(self, alphas: np.ndarray, T_S: np.ndarray,
             rates: np.ndarray) -> RoundPlan:
        return self.scheme_obj.plan(self.observe(alphas, T_S, rates))

    def plan_pipelined(self, alphas: np.ndarray, T_S: np.ndarray,
                       rates: np.ndarray) -> dict:
        """Two-half-batch pipelined plan: {goodput, period, halves}."""
        from .beyond import pipelined_plan
        return pipelined_plan(self.scheme_obj,
                              self.observe(alphas, T_S, rates))


class AcceptanceEstimator:
    """Online EWMA estimate of per-device acceptance rates from realized
    verification outcomes (used when devices do not report task profiles)."""

    def __init__(self, K: int, prior: float = 0.8, decay: float = 0.9):
        self.prior = prior
        self.succ = np.full(K, prior)       # EWMA accepted Bernoulli trials
        self.trials = np.ones(K)            # EWMA total Bernoulli trials
        self.decay = decay

    def extend(self, n: int):
        """Open EWMA slots for ``n`` devices joining the cell."""
        self.succ = np.concatenate([self.succ, np.full(n, self.prior)])
        self.trials = np.concatenate([self.trials, np.ones(n)])

    def keep(self, keep_mask: np.ndarray):
        """Drop EWMA slots of devices leaving the cell."""
        keep_mask = np.asarray(keep_mask, dtype=bool)
        self.succ = self.succ[keep_mask]
        self.trials = self.trials[keep_mask]

    @property
    def alpha_hat(self) -> np.ndarray:
        return np.clip(self.succ / np.maximum(self.trials, 1e-9), 0.01, 0.995)

    @alpha_hat.setter
    def alpha_hat(self, value):
        self.succ = np.asarray(value, dtype=np.float64).copy()
        self.trials = np.ones_like(self.succ)

    def update(self, accept_counts: np.ndarray, lengths: np.ndarray,
               mask: np.ndarray | None = None):
        """Each accepted draft token is a Bernoulli success; the (at most one)
        rejection is a failure.  EWMA of successes and trials separately —
        the ratio-of-sums estimator is consistent for the truncated
        geometric, unlike the per-round mean of ratios.

        ``mask`` selects the devices that actually participated in the round:
        a deadline-dropped device reports accepted=0, which is NOT a run of
        rejections, so its EWMA state must be left untouched.
        """
        counts = np.asarray(accept_counts, dtype=np.float64)
        lengths = np.maximum(np.asarray(lengths, dtype=np.float64), 1.0)
        rejected = (counts < lengths).astype(np.float64)
        new_succ = self.decay * self.succ + (1 - self.decay) * counts
        new_trials = (self.decay * self.trials
                      + (1 - self.decay) * (counts + rejected))
        if mask is None:
            mask = np.ones_like(counts, dtype=bool)
        else:
            mask = np.asarray(mask, dtype=bool)
        self.succ = np.where(mask, new_succ, self.succ)
        self.trials = np.where(mask, new_trials, self.trials)
        return self.alpha_hat
