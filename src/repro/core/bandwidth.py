"""Optimal OFDMA bandwidth allocation (paper Lemmas 1 and 3).

Both lemmas reduce to a one-dimensional root of a strictly decreasing rational
function, solved here by fixed-iteration bisection (jit/vmap compatible and
exact to ~1 ulp of the bracket width after 100 halvings).
"""

from __future__ import annotations

import numpy as np

_BISECT_ITERS = 100


def _bisect_decreasing(f, lo, hi, xp=np, iters: int = _BISECT_ITERS):
    """Root of strictly-decreasing f on (lo, hi) with f(lo+)>0>f(hi-)."""
    lo = xp.asarray(lo, dtype=np.float64 if xp is np else None)
    hi = xp.asarray(hi, dtype=np.float64 if xp is np else None)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        pos = f(mid) > 0.0
        lo = xp.where(pos, mid, lo)
        hi = xp.where(pos, hi, mid)
    return 0.5 * (lo + hi)


def solve_equalized_theta(T_S, r, Q_tok, B, xp=np):
    """Lemma 1: minimal per-token multi-access latency theta*.

    Solves  sum_k Q_tok / (r_k (theta - T_k^S)) = B   over theta > max_k T_k^S
    (paper eq. 20).  Returns (theta_star, B_star) with
    B_k* = Q_tok / (r_k (theta* - T_k^S))   (paper eq. 19).

    Leading batch dimensions on ``T_S``/``r`` are supported; the device axis
    is the last one.
    """
    T_S = xp.asarray(T_S, dtype=np.float64 if xp is np else None)
    r = xp.asarray(r, dtype=np.float64 if xp is np else None)

    def excess(theta):
        return xp.sum(Q_tok / (r * (xp.expand_dims(theta, -1) - T_S)), axis=-1) - B

    t_max = xp.max(T_S, axis=-1)
    K = T_S.shape[-1]
    hi = t_max + (K * Q_tok) / (B * xp.min(r, axis=-1)) + 1.0
    lo = t_max * (1.0 + 1e-12) + 1e-15
    theta = _bisect_decreasing(excess, lo, hi, xp=xp)
    B_star = Q_tok / (r * (xp.expand_dims(theta, -1) - T_S))
    return theta, B_star


def solve_equalized_phi(L, T_S, r, Q_tok, B, xp=np):
    """Lemma 3: equalized multi-access latency phi for given draft lengths.

    Solves  sum_k Q_tok L_k / (r_k (phi - L_k T_k^S)) = B   over
    phi > max_k L_k T_k^S (paper eq. 28).  Returns (phi, B(L)) with
    B_k(L) = Q_tok L_k / (r_k (phi - L_k T_k^S))   (paper eq. 27).
    """
    L = xp.asarray(L, dtype=np.float64 if xp is np else None)
    T_S = xp.asarray(T_S, dtype=np.float64 if xp is np else None)
    r = xp.asarray(r, dtype=np.float64 if xp is np else None)

    def excess(phi):
        phi_b = xp.expand_dims(phi, -1)
        return xp.sum(Q_tok * L / (r * (phi_b - L * T_S)), axis=-1) - B

    p_max = xp.max(L * T_S, axis=-1)
    K = T_S.shape[-1]
    hi = p_max + (K * Q_tok * xp.max(L, axis=-1)) / (B * xp.min(r, axis=-1)) + 1.0
    lo = p_max * (1.0 + 1e-12) + 1e-15
    phi = _bisect_decreasing(excess, lo, hi, xp=xp)
    B_of_L = Q_tok * L / (r * (xp.expand_dims(phi, -1) - L * T_S))
    return phi, B_of_L


def uniform_bandwidth(B, K, xp=np):
    """Heterogeneity-agnostic baseline: B_k = B / K."""
    return xp.full((K,), B / K)
