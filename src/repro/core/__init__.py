"""Multi-SPIN core: the paper's contribution as a composable library.

Layers:
  * analytic goodput model        (`goodput`)
  * wireless channel model        (`channel`)
  * bandwidth allocation          (`bandwidth`, Lemmas 1/3)
  * draft-length control          (`draft_control`, Thm 1 / Prop 1 / Alg 1)
  * speculative verification      (`verification`, eq. 4-5 exact sampling)
  * draft generation              (`drafting`)
  * round protocol + controller   (`protocol`, `controller`)
"""

from . import (  # noqa: F401
    bandwidth,
    channel,
    controller,
    draft_control,
    drafting,
    goodput,
    lambertw,
    protocol,
    verification,
)
