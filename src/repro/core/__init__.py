"""Multi-SPIN core: the paper's contribution as a composable library.

Layers:
  * analytic goodput model        (`goodput`)
  * wireless channel model        (`channel`)
  * bandwidth allocation          (`bandwidth`, Lemmas 1/3)
  * draft-length control          (`draft_control`, Thm 1 / Prop 1 / Alg 1)
  * speculative verification      (`verification`, eq. 4-5 exact sampling)
  * draft generation              (`drafting`)
  * round controller              (`controller`; the round protocol itself
                                   lives in `repro.serving.cell`)
"""

from . import (  # noqa: F401
    bandwidth,
    channel,
    controller,
    draft_control,
    goodput,
    lambertw,
    schemes,
)

# Resolved lazily: `drafting` / `verification` import jax, and the analytic
# layer (channel, draft control, cell with a synthetic backend) must stay
# importable without paying the jax startup cost.
_LAZY = ("drafting", "verification")


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
