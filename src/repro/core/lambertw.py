"""Lambert W function (principal ``W0`` and lower ``W-1`` branches).

The paper's closed-form draft-length solutions (Theorem 1, eq. 23 and
Proposition 1, eq. 33) are expressed through the Lambert W function.  scipy is
not a guaranteed dependency of the deployment environment, so we implement W
ourselves with a branch-aware initial guess followed by Halley iterations
(cubic convergence; a fixed iteration count keeps the routine jit-compatible).

Both branches are implemented against a pluggable array namespace ``xp`` so the
same code serves the float64 numpy controller path and jnp-traced graphs.
"""

from __future__ import annotations

import numpy as np

_INV_E = -np.exp(-1.0)

_HALLEY_ITERS = 24


def _halley(xp, w, x, iters: int = _HALLEY_ITERS):
    """Halley iterations for w*exp(w) = x, branch-agnostic."""
    for _ in range(iters):
        ew = xp.exp(w)
        f = w * ew - x
        # Halley update: w -= f / (ew*(w+1) - (w+2)*f/(2w+2)).
        # Guard the w = -1 branch point (both inner divisions degenerate).
        two_w = xp.where(xp.abs(2.0 * w + 2.0) < 1e-30, 1e-30, 2.0 * w + 2.0)
        denom = ew * (w + 1.0) - (w + 2.0) * f / two_w
        denom = xp.where(xp.abs(denom) < 1e-300, 1e-300, denom)
        w = w - f / denom
    return w


def lambert_w0(x, xp=np):
    """Principal branch W0(x) for x >= -1/e.

    Accurate to ~1e-12 (float64) across the domain; returns NaN below -1/e.
    """
    x = xp.asarray(x)
    x = x * xp.ones_like(x)  # materialize scalars
    # Initial guesses per region.
    # Near branch point: series W ~ -1 + p - p^2/3 with p = sqrt(2(e x + 1)).
    p = xp.sqrt(xp.maximum(2.0 * (xp.e * x + 1.0), 0.0))
    w_branch = -1.0 + p - p * p / 3.0 + 11.0 / 72.0 * p * p * p
    # Moderate |x|: W0(x) ~ log(1+x) is within ~15% on [-0.25, 3), plenty for
    # Halley.  Log asymptotics only for genuinely large x (lnln x well-defined).
    safe_x = xp.maximum(x, 3.0)
    lx = xp.log(safe_x)
    llx = xp.log(lx)
    w_log = lx - llx + llx / lx
    w_mid = xp.log1p(xp.maximum(x, -0.999))
    w0 = xp.where(x < -0.25, w_branch, xp.where(x < 3.0, w_mid, w_log))
    w = _halley(xp, w0, x)
    return xp.where(x < _INV_E - 1e-12, xp.nan, w)


def lambert_wm1(x, xp=np):
    """Lower branch W-1(x) for -1/e <= x < 0.

    Returns NaN outside the branch domain.
    """
    x = xp.asarray(x)
    x = x * xp.ones_like(x)
    # Near branch point: series with p = -sqrt(2(e x + 1)) (negative root).
    p = -xp.sqrt(xp.maximum(2.0 * (xp.e * x + 1.0), 0.0))
    w_branch = -1.0 + p - p * p / 3.0 + 11.0 / 72.0 * p * p * p
    # Asymptotic for x -> 0-:  W-1(x) ~ ln(-x) - ln(-ln(-x)).
    nx = xp.minimum(x, -1e-300)
    l1 = xp.minimum(xp.log(-nx), -1e-10)  # valid domain has log(-x) < -1
    l2 = xp.log(xp.maximum(-l1, 1e-300))
    w_asym = l1 - l2 + l2 / l1
    w0 = xp.where(x < -0.27, w_branch, w_asym)
    w = _halley(xp, w0, x)
    bad = (x < _INV_E - 1e-12) | (x >= 0.0)
    return xp.where(bad, xp.nan, w)
