"""OFDMA uplink channel model (paper Sec. II-B).

Devices transmit with constant power spectral density (paper Sec. VI-A3), so
the per-Hz SNR — and therefore the spectrum efficiency r_k of eq. (8) — is
independent of the allocated bandwidth.  This is exactly why the paper can
treat r_k as a constant inside the draft-control optimization.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** ((dbm - 30.0) / 10.0)


def db_to_linear(db: float) -> float:
    return 10.0 ** (db / 10.0)


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """System-level wireless parameters (paper Sec. VI-A3 defaults)."""

    total_bandwidth_hz: float = 10e6          # B = 10 MHz
    total_power_dbm: float = 23.0             # P = 23 dBm
    noise_psd_dbm_hz: float = -170.0          # N0 = -170 dBm/Hz
    retained_vocab: int = 1024                # |V^hat|
    prob_bits: int = 16                       # Q_B
    vocab_size: int = 32000                   # V (per target model)
    snr_lo_db: float = 18.2                   # avg received SNR range
    snr_hi_db: float = 22.2

    @property
    def power_psd(self) -> float:
        """Transmit PSD [W/Hz]: constant-PSD transmission."""
        return dbm_to_watt(self.total_power_dbm) / self.total_bandwidth_hz

    @property
    def noise_psd(self) -> float:
        return dbm_to_watt(self.noise_psd_dbm_hz)

    @property
    def q_tok_bits(self) -> float:
        """Q_tok = |V^hat| (Q_B + ceil(log2 V))   (paper eq. 9)."""
        return self.retained_vocab * (self.prob_bits + int(np.ceil(np.log2(self.vocab_size))))


def sample_average_gains(cfg: ChannelConfig, K: int, rng: np.random.Generator) -> np.ndarray:
    """Draw average channel power gains H̄_k such that the average received
    SNR is uniform in [snr_lo_db, snr_hi_db] (paper Sec. VI-A3)."""
    snr_db = rng.uniform(cfg.snr_lo_db, cfg.snr_hi_db, size=K)
    snr = db_to_linear(snr_db)
    # snr = PSD * H̄ / N0  =>  H̄ = snr * N0 / PSD
    return snr * cfg.noise_psd / cfg.power_psd


def sample_rayleigh_gains(avg_gains: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Block Rayleigh fading: H_k = |h_k|^2, h_k ~ CN(0, H̄_k).

    |h|^2 is exponential with mean H̄_k.
    """
    return rng.exponential(scale=avg_gains)


def spectrum_efficiency(cfg: ChannelConfig, gains: np.ndarray) -> np.ndarray:
    """r_k = log2(1 + PSD * H_k / N0)   (eq. 8 under constant-PSD power)."""
    snr = cfg.power_psd * np.asarray(gains) / cfg.noise_psd
    return np.log2(1.0 + snr)


@dataclasses.dataclass
class ChannelState:
    """One block-fading realization for K devices."""

    cfg: ChannelConfig
    avg_gains: np.ndarray
    gains: np.ndarray
    rates: np.ndarray  # spectrum efficiency r_k [bit/s/Hz]

    @classmethod
    def sample(cls, cfg: ChannelConfig, K: int, rng: np.random.Generator,
               avg_gains: np.ndarray | None = None) -> "ChannelState":
        if avg_gains is None:
            avg_gains = sample_average_gains(cfg, K, rng)
        gains = sample_rayleigh_gains(avg_gains, rng)
        return cls(cfg=cfg, avg_gains=avg_gains, gains=gains,
                   rates=spectrum_efficiency(cfg, gains))

    def refade(self, rng: np.random.Generator) -> "ChannelState":
        """New small-scale fading block with the same large-scale gains."""
        return ChannelState.sample(self.cfg, len(self.avg_gains), rng,
                                   avg_gains=self.avg_gains)

    def uplink_rate_bps(self, bandwidth_hz: np.ndarray) -> np.ndarray:
        """R_k = B_k r_k [bit/s]   (eq. 8)."""
        return np.asarray(bandwidth_hz) * self.rates
