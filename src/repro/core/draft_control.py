"""Multi-access draft control (paper Sec. IV and V).

Implements:
  * Theorem 1 — closed-form optimal uniform draft length via Lambert W-1.
  * Proposition 1 — closed-form heterogeneous draft lengths via Lambert W0.
  * Algorithm 1 — joint (phi, lambda) grid search for problem (P2).
  * Baseline controllers (Fixed BW&L, Uni-BW, Homo-Multi-SPIN, P2P, Cen-SPIN)
    used in benchmarks for Figs. 6-8.

The controller runs on the host at the start of every Multi-SPIN round (paper
Fig. 2, step 1), so it is implemented in float64 numpy; all routines also
accept jnp via ``xp`` for vmapped parameter sweeps.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bandwidth import solve_equalized_phi, solve_equalized_theta, uniform_bandwidth
from .goodput import (
    expected_accepted_tokens,
    goodput_from_equalized_latency,
    goodput_homogeneous,
)
from .lambertw import lambert_w0, lambert_wm1


# ---------------------------------------------------------------------------
# Theorem 1: uniform draft-length control
# ---------------------------------------------------------------------------

def optimal_uniform_length(alpha, theta, T_ver, L_max: int | None = None, xp=np):
    """Closed-form optimal uniform draft length (paper Theorem 1, eq. 22-23).

    Returns (L_star, L_tilde): the integer optimum and the continuous
    relaxation.  When T_ver/theta <= (1-alpha)/(alpha |ln alpha|) the goodput
    is decreasing and L* = 1.
    """
    alpha = xp.asarray(alpha, dtype=np.float64 if xp is np else None)
    theta = xp.asarray(theta, dtype=np.float64 if xp is np else None)
    t = T_ver / theta
    ln_a = xp.log(alpha)
    interior = t > (1.0 - alpha) / (alpha * xp.abs(ln_a))

    # eq. 23:  L~* = -ln(-W_{-1}(-alpha^(t-1)/e)) / ln(alpha) - 1
    arg = -(alpha ** (t - 1.0)) / xp.e
    arg = xp.clip(arg, -np.exp(-1.0), -1e-300)  # numerical guard at branch point
    w = lambert_wm1(arg, xp=xp)
    L_tilde = -xp.log(-w) / ln_a - 1.0
    L_tilde = xp.where(interior, L_tilde, 1.0)

    lo = xp.maximum(xp.floor(L_tilde), 1.0)
    hi = lo + 1.0
    if L_max is not None:
        lo = xp.minimum(lo, float(L_max))
        hi = xp.minimum(hi, float(L_max))
    g_lo = _tau_uniform(alpha, lo, theta, T_ver, xp)
    g_hi = _tau_uniform(alpha, hi, theta, T_ver, xp)
    L_star = xp.where(interior, xp.where(g_hi > g_lo, hi, lo), 1.0)
    return L_star, L_tilde


def _tau_uniform(alpha, L, theta, T_ver, xp):
    """Goodput of one device under uniform length (K factors out of argmax)."""
    return goodput_homogeneous(alpha, L, theta, T_ver, K=1, xp=xp)


# ---------------------------------------------------------------------------
# Proposition 1: heterogeneous draft lengths for given (phi, lambda)
# ---------------------------------------------------------------------------

def heterogeneous_lengths(phi, lam, alphas, T_S, r, Q_tok, xp=np):
    """Closed-form continuous draft lengths (paper Proposition 1, eq. 33).

    L~_k = phi/T_k^S + (2/ln a_k) W0( a_k^(-phi/(2 T_k^S)) / (2 T_k^S)
             * sqrt( lam Q_tok phi |ln a_k| (1-a_k) / (r_k a_k) ) )
    """
    alphas = xp.asarray(alphas, dtype=np.float64 if xp is np else None)
    T_S = xp.asarray(T_S, dtype=np.float64 if xp is np else None)
    r = xp.asarray(r, dtype=np.float64 if xp is np else None)
    ln_a = xp.log(alphas)
    # a^(-phi/(2T)) can overflow float64 for tiny alpha / large phi; compute in
    # log space and clamp.
    log_pref = (-phi / (2.0 * T_S)) * ln_a - xp.log(2.0 * T_S)
    log_sqrt = 0.5 * xp.log(lam * Q_tok * phi * xp.abs(ln_a) * (1.0 - alphas)
                            / (r * alphas))
    log_w_arg = xp.clip(log_pref + log_sqrt, -700.0, 700.0)
    w = lambert_w0(xp.exp(log_w_arg), xp=xp)
    return phi / T_S + (2.0 / ln_a) * w


def round_lengths(L_tilde, L_max: int, xp=np):
    """Rounding rule of eq. 32, clipped into the admissible range [1, L_max]."""
    return xp.clip(xp.round(L_tilde), 1.0, float(L_max))


# ---------------------------------------------------------------------------
# Algorithm 1: joint multi-access draft control for (P2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DraftControlSolution:
    """Controller output for one Multi-SPIN round."""

    lengths: np.ndarray           # integer draft lengths L_k*
    bandwidth: np.ndarray         # B_k* [Hz]
    goodput: float                # predicted sum goodput [tokens/s]
    equalized_latency: float      # phi* (or L* theta* in the uniform regime)
    meta: dict


def search_grids(alphas, T_S, r, Q_tok, B, L_max: int,
                 n_phi: int = 40, n_lam: int = 40):
    """Bounded search grids for (phi, lambda) (paper Appendix F)."""
    T_S = np.asarray(T_S, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)
    alphas = np.asarray(alphas, dtype=np.float64)
    phi_lo = np.max(T_S + Q_tok / (B * r))
    phi_hi = np.max(L_max * (T_S + len(T_S) * Q_tok / (B * r)))
    ln_a = np.log(alphas)
    lam_lo = 1e-9
    lam_hi = np.max(r * (phi_hi - T_S) ** 2 / (Q_tok * phi_hi)
                    * (-ln_a) / (1.0 - alphas) * alphas ** 2)
    phis = np.geomspace(phi_lo * (1 + 1e-9), phi_hi, n_phi)
    lams = np.geomspace(lam_lo, max(lam_hi, lam_lo * 10), n_lam)
    return phis, lams


def solve_heterogeneous(alphas, T_S, r, Q_tok, B, T_ver, L_max: int = 25,
                        n_phi: int = 40, n_lam: int = 40) -> DraftControlSolution:
    """Algorithm 1: grid search over (phi, lambda), closed-form inner steps.

    Vectorized over the whole grid: for every candidate pair we compute the
    Proposition-1 lengths, re-equalize phi via Lemma 3 (eq. 28 root), and
    evaluate the eq. 29 goodput; the best feasible candidate wins.
    """
    alphas = np.asarray(alphas, dtype=np.float64)
    T_S = np.asarray(T_S, dtype=np.float64)
    r = np.asarray(r, dtype=np.float64)

    phis, lams = search_grids(alphas, T_S, r, Q_tok, B, L_max, n_phi, n_lam)
    PH, LM = np.meshgrid(phis, lams, indexing="ij")
    grid = np.stack([PH.ravel(), LM.ravel()], axis=-1)  # (G, 2)

    # Proposition 1 lengths for every grid point: (G, K)
    L_tilde = heterogeneous_lengths(grid[:, :1], grid[:, 1:2],
                                    alphas[None, :], T_S[None, :], r[None, :], Q_tok)
    L_int = round_lengths(np.nan_to_num(L_tilde, nan=1.0), L_max)

    # Lemma 3 re-equalization for the rounded integer lengths (Alg. 1, step 4).
    phi_hat, B_of_L = solve_equalized_phi(L_int, T_S[None, :], r[None, :], Q_tok, B)

    tau = goodput_from_equalized_latency(alphas[None, :], L_int, phi_hat, T_ver)
    tau = np.where(np.isfinite(tau), tau, -np.inf)

    best = int(np.argmax(tau))
    L_best = L_int[best].astype(np.int64)
    phi_best, B_best = solve_equalized_phi(L_best, T_S, r, Q_tok, B)
    return DraftControlSolution(
        lengths=L_best,
        bandwidth=np.asarray(B_best),
        goodput=float(tau[best]),
        equalized_latency=float(phi_best),
        meta={"phi_grid": phis, "lam_grid": lams, "grid_best": grid[best],
              "scheme": "hete-multi-spin"},
    )


# ---------------------------------------------------------------------------
# Homogeneous controller (Sec. IV) and benchmark baselines (Sec. VI-A4)
# ---------------------------------------------------------------------------

def solve_homogeneous(alpha_eff, alphas, T_S, r, Q_tok, B, T_ver,
                      L_max: int = 25) -> DraftControlSolution:
    """Optimal uniform-length control: Lemma 1 bandwidth + Theorem 1 length.

    ``alpha_eff`` is the common acceptance rate used by the controller (the
    paper's uniform regime); the realized goodput is evaluated with the true
    per-device ``alphas``.
    """
    alphas = np.asarray(alphas, dtype=np.float64)
    theta, B_star = solve_equalized_theta(T_S, r, Q_tok, B)
    L_star, _ = optimal_uniform_length(alpha_eff, theta, T_ver, L_max=L_max)
    L = np.full(len(alphas), int(L_star), dtype=np.int64)
    tau = float(np.sum(expected_accepted_tokens(alphas, L))
                / (int(L_star) * float(theta) + T_ver))
    return DraftControlSolution(
        lengths=L, bandwidth=np.asarray(B_star), goodput=tau,
        equalized_latency=float(L_star * theta),
        meta={"theta_star": float(theta), "scheme": "homo-multi-spin"},
    )


def solve_homogeneous_exhaustive(alphas, T_S, r, Q_tok, B, T_ver,
                                 L_max: int = 25) -> DraftControlSolution:
    """Homo-Multi-SPIN baseline: exhaustive search over uniform L with
    Lemma-1-optimal bandwidth (paper Sec. VI-A4), vectorized over the whole
    L grid."""
    alphas = np.asarray(alphas, dtype=np.float64)
    theta, B_star = solve_equalized_theta(T_S, r, Q_tok, B)
    Ls = np.arange(1, L_max + 1, dtype=np.float64)
    n_acc = np.sum(expected_accepted_tokens(alphas[None, :], Ls[:, None]),
                   axis=-1)
    taus = n_acc / (Ls * float(theta) + T_ver)
    best = int(np.argmax(taus))
    L = np.full(len(alphas), int(Ls[best]), dtype=np.int64)
    return DraftControlSolution(
        lengths=L, bandwidth=np.asarray(B_star), goodput=float(taus[best]),
        equalized_latency=float(Ls[best] * theta),
        meta={"theta_star": float(theta), "scheme": "homo-multi-spin"},
    )


def solve_uniform_bandwidth(alphas, T_S, r, Q_tok, B, T_ver,
                            L_max: int = 25, n_phi: int = 200) -> DraftControlSolution:
    """Uni-BW Multi-SPIN baseline: heterogeneous lengths under B_k = B/K.

    With fixed bandwidth the per-device per-token latency c_k is constant, so
    for a target round latency phi the optimal lengths are
    L_k = floor(phi / c_k) (goodput numerator is increasing in each L_k); a 1-D
    sweep over phi recovers the optimum of (P2.1a) under uniform bandwidth.
    """
    alphas = np.asarray(alphas, dtype=np.float64)
    K = len(alphas)
    B_k = uniform_bandwidth(B, K)
    c = np.asarray(T_S) + Q_tok / (B_k * np.asarray(r))
    phi_lo, phi_hi = np.min(c), L_max * np.max(c)
    phis = np.linspace(phi_lo, phi_hi, n_phi)
    L_grid = np.clip(np.floor(phis[:, None] / c[None, :]), 1.0, L_max)  # (n_phi, K)
    t_ma = np.max(L_grid * c[None, :], axis=-1)
    taus = np.sum(expected_accepted_tokens(alphas[None, :], L_grid), axis=-1) / (t_ma + T_ver)
    best = int(np.argmax(taus))
    return DraftControlSolution(
        lengths=L_grid[best].astype(np.int64), bandwidth=B_k,
        goodput=float(taus[best]), equalized_latency=float(t_ma[best]),
        meta={"scheme": "uni-bw-multi-spin"},
    )


def solve_fixed(alphas, T_S, r, Q_tok, B, T_ver, L_fixed: int = 8) -> DraftControlSolution:
    """Fixed BW&L baseline: L_k = L_fixed, B_k = B/K (paper Sec. VI-A4)."""
    alphas = np.asarray(alphas, dtype=np.float64)
    K = len(alphas)
    B_k = uniform_bandwidth(B, K)
    c = np.asarray(T_S) + Q_tok / (B_k * np.asarray(r))
    L = np.full(K, L_fixed, dtype=np.int64)
    t_ma = float(np.max(L * c))
    tau = float(np.sum(expected_accepted_tokens(alphas, L)) / (t_ma + T_ver))
    return DraftControlSolution(lengths=L, bandwidth=B_k, goodput=tau,
                                equalized_latency=t_ma,
                                meta={"scheme": "fixed-bw-l"})


def solve_p2p(alpha, T_S, r, Q_tok, B, T_ver_single, L_max: int = 25) -> DraftControlSolution:
    """P2P-SPIN baseline: one device, full bandwidth, exhaustive L."""
    c = float(T_S) + Q_tok / (B * float(r))
    Ls = np.arange(1, L_max + 1, dtype=np.float64)
    taus = expected_accepted_tokens(float(alpha), Ls) / (Ls * c + T_ver_single)
    best = int(np.argmax(taus))
    return DraftControlSolution(
        lengths=np.array([int(Ls[best])], dtype=np.int64),
        bandwidth=np.array([B]), goodput=float(taus[best]),
        equalized_latency=float(Ls[best] * c), meta={"scheme": "p2p-spin"},
    )


def solve_centralized(alphas, T_ver, T_draft_fix, T_draft_lin,
                      L_max: int = 25) -> DraftControlSolution:
    """Cen-SPIN baseline: server drafts AND verifies for all K prompts.

    Server-side drafting is a batched SLM forward per token with the same
    affine batch-latency law as verification: per drafted token the server
    spends T_draft_fix + K*T_draft_lin; no uplink is involved.
    """
    alphas = np.asarray(alphas, dtype=np.float64)
    K = len(alphas)
    per_tok = T_draft_fix + K * T_draft_lin
    Ls = np.arange(1, L_max + 1, dtype=np.float64)
    n_acc = np.sum(expected_accepted_tokens(alphas[None, :], Ls[:, None]),
                   axis=-1)
    taus = n_acc / (Ls * per_tok + T_ver)
    best = int(np.argmax(taus))
    return DraftControlSolution(
        lengths=np.full(K, int(Ls[best]), dtype=np.int64),
        bandwidth=np.zeros(K), goodput=float(taus[best]),
        equalized_latency=float(Ls[best] * per_tok), meta={"scheme": "cen-spin"},
    )
