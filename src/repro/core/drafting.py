"""Device-side draft generation (paper Sec. II-A1, protocol step 2).

The SLM drafts autoregressively; each step's distribution is truncated to the
top-|V^hat| tokens and renormalized — the device samples from exactly the
distribution it uploads (eq. 9 payload), which keeps server-side verification
exact under uplink compression.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .verification import truncate_renormalize


@dataclasses.dataclass
class DraftResult:
    """One round of drafting for a batch of B device streams.

    tokens: (B, L) sampled draft tokens.
    probs:  (B, L) probability of each sampled token under the (truncated)
            SLM distribution — the p_S of eq. 4.
    q_idx / q_val: (B, L, Vhat) the uploaded sparse SLM distributions.
    cache:  SLM cache after processing [pending, d_1 .. d_{L-1}].
    """

    tokens: jax.Array
    probs: jax.Array
    q_idx: jax.Array
    q_val: jax.Array
    cache: object


# pytree registration lets jitted round-step functions return a DraftResult
# directly (serving/compiled.py) instead of unpacking to tuples at the jit
# boundary; every field is array data, so there are no static fields
jax.tree_util.register_dataclass(
    DraftResult,
    data_fields=["tokens", "probs", "q_idx", "q_val", "cache"],
    meta_fields=[])


@dataclasses.dataclass
class DraftForest:
    """J i.i.d. drafting rounds per stream (the ``multidraft`` scheme's
    device-side step).  Axes: (B, J, L[, Vhat]); ``cache`` is the SLM cache
    after the LAST run — every run re-draws from the same committed prefix,
    so run j's window writes fully shadow run j-1's.

    ``windows`` (with ``keep_windows=True``) snapshots every run's window
    K/V — cache-leaf name -> (Ln, B, J, L, KV, D), the K/V written at slots
    [pos + 1, pos + L] by run j (slot ``pos`` holds the pending token,
    identical across runs).  The engine's scatter-commit selects the
    winning run's rows from here instead of re-forwarding the accepted
    path through the draft model.
    """

    tokens: jax.Array
    probs: jax.Array
    q_idx: jax.Array
    q_val: jax.Array
    cache: object
    windows: dict | None = None


jax.tree_util.register_dataclass(
    DraftForest,
    data_fields=["tokens", "probs", "q_idx", "q_val", "cache", "windows"],
    meta_fields=[])


_KV_LEAVES = ("k", "v", "dense_k", "dense_v")


def generate_draft_forest(model, params, cache, pending: jax.Array,
                          pos: jax.Array, L: int, J: int, key: jax.Array,
                          vhat: int, temperature: float = 1.0,
                          keep_windows: bool = False) -> DraftForest:
    """Draft J independent length-L runs per stream.

    Run 0 consumes ``key`` exactly like ``generate_drafts`` (J = 1 is
    stream-identical to single drafting); run j > 0 folds j into the key.
    Each run starts from the same committed prefix: its window writes land
    at cache slots [pos, pos + L], past every valid position, so runs never
    see each other (causal masking) and the last run's writes are the only
    survivors.  ``keep_windows=True`` snapshots each run's window K/V right
    after the run (the cache only retains the LAST run's) so the engine can
    scatter-commit the accepted branch without a repair forward.
    """
    from repro.models.layers import gather_kv_window

    tokens, probs, q_idx, q_val = [], [], [], []
    snaps: list[dict] = []
    if keep_windows:
        win_pos = pos[:, None] + 1 + jnp.arange(L)[None, :]     # (B, L)
        page_table = cache.get("pages") if isinstance(cache, dict) else None
    for j in range(J):
        kj = key if j == 0 else jax.random.fold_in(key, j)
        res = generate_drafts(model, params, cache, pending, pos, L, kj,
                              vhat=vhat, temperature=temperature)
        cache = res.cache
        tokens.append(res.tokens)
        probs.append(res.probs)
        q_idx.append(res.q_idx)
        q_val.append(res.q_val)
        if keep_windows:
            snaps.append({leaf: gather_kv_window(cache[leaf], win_pos,
                                                 page_table=page_table)
                          for leaf in _KV_LEAVES if leaf in cache})
    windows = None
    if keep_windows:
        windows = {leaf: jnp.stack([s[leaf] for s in snaps], axis=2)
                   for leaf in snaps[0]}                # (Ln, B, J, L, KV, D)
    return DraftForest(tokens=jnp.stack(tokens, axis=1),
                       probs=jnp.stack(probs, axis=1),
                       q_idx=jnp.stack(q_idx, axis=1),
                       q_val=jnp.stack(q_val, axis=1),
                       cache=cache,
                       windows=windows)


def generate_drafts(model, params, cache, pending: jax.Array, pos: jax.Array,
                    L: int, key: jax.Array, vhat: int,
                    temperature: float = 1.0) -> DraftResult:
    """Draft L tokens per stream.

    pending: (B,) the last committed token not yet in the SLM cache.
    pos:     (B,) SLM cache fill levels (tokens already processed).
    """
    toks = pending
    keys = jax.random.split(key, L)
    out_tokens, out_probs, out_idx, out_val = [], [], [], []
    for t in range(L):
        logits, cache = model.forward_window(params, toks[:, None], cache, pos + t)
        probs = jax.nn.softmax(logits[:, 0].astype(jnp.float32) / temperature,
                               axis=-1)
        idx, val = truncate_renormalize(probs, vhat)
        j = jax.random.categorical(keys[t], jnp.log(jnp.maximum(val, 1e-30)),
                                   axis=-1)                       # (B,)
        toks = jnp.take_along_axis(idx, j[:, None], axis=-1)[:, 0]
        p_tok = jnp.take_along_axis(val, j[:, None], axis=-1)[:, 0]
        out_tokens.append(toks)
        out_probs.append(p_tok)
        out_idx.append(idx)
        out_val.append(val)
    # Write d_L into the cache (logits discarded): on full acceptance the
    # committed prefix includes d_L, and without this step the SLM cache
    # would have a hole at its position.  This (L+1)-th SLM pass overlaps the
    # upload in the latency model (DESIGN.md §7).
    _, cache = model.forward_window(params, toks[:, None], cache, pos + L)
    return DraftResult(
        tokens=jnp.stack(out_tokens, axis=1),
        probs=jnp.stack(out_probs, axis=1),
        q_idx=jnp.stack(out_idx, axis=1),
        q_val=jnp.stack(out_val, axis=1),
        cache=cache,
    )
