"""The one-stop Multi-SPIN serving API.

Everything needed to stand up, drive, and extend a Multi-SPIN cell::

    from repro.api import CellConfig, MultiSpinCell, Request

    cell = MultiSpinCell(CellConfig(scheme="hete", max_batch=4))
    cell.submit(Request(rid=0, prompt_len=8, max_new_tokens=64,
                        alpha=0.86, T_S=0.009))
    cell.drain()
    print(cell.scheduler.stats.goodput)

Scheme solvers are registered ``Scheme`` classes (``@register_scheme``)
planning a structured ``CellObservation`` into a ``RoundPlan``; so are the
verification backends pluggable (``SyntheticBackend`` for analytic sweeps,
``EngineBackend`` for real JAX models).  ``SpecEngine`` and the
paged-KV-cache names are resolved lazily to keep the analytic path free of
jax import cost.

Layer-by-layer documentation lives in ``docs/`` — ``architecture.md``
(request lifecycle), ``kernels.md`` (Pallas ops + dispatch),
``benchmarks.md`` (tracked perf baselines).
"""

from repro.core.channel import ChannelConfig, ChannelState  # noqa: F401
from repro.core.controller import (  # noqa: F401
    AcceptanceEstimator,
    MultiSpinController,
    VerificationLatencyModel,
)
from repro.core.schemes import (  # noqa: F401
    CellObservation,
    RoundPlan,
    Scheme,
    SchemeCapabilities,
    SchemeCapabilityError,
    available_schemes,
    build_scheme,
    get_scheme,
    register_scheme,
    scheme_table_markdown,
)
from repro.serving.backends import (  # noqa: F401
    ContinuousBackend,
    EngineBackend,
    SyntheticBackend,
    VerificationBackend,
)
from repro.serving.cell import (  # noqa: F401
    SCHEDULES,
    CellConfig,
    MultiSpinCell,
    RoundRecord,
)
from repro.serving.gateway import (  # noqa: F401
    GatewayClient,
    GatewayConfig,
    MetricsHub,
    MultiSpinGateway,
    RoundMetrics,
)
from repro.serving.scheduler import (  # noqa: F401
    Request,
    RoundScheduler,
    SchedulerStats,
)

__all__ = [
    "AcceptanceEstimator",
    "CellConfig",
    "CellObservation",
    "ChannelConfig",
    "ChannelState",
    "ContinuousBackend",
    "ContinuousEngine",
    "EngineBackend",
    "GatewayClient",
    "GatewayConfig",
    "MetricsHub",
    "MultiSpinCell",
    "MultiSpinGateway",
    "MultiSpinController",
    "PagedKVCache",
    "PagePoolExhausted",
    "Request",
    "RoundMetrics",
    "RoundPlan",
    "RoundRecord",
    "RoundScheduler",
    "SCHEDULES",
    "Scheme",
    "SchemeCapabilities",
    "SchemeCapabilityError",
    "SchedulerStats",
    "SpecEngine",
    "SyntheticBackend",
    "VerificationBackend",
    "VerificationLatencyModel",
    "available_schemes",
    "build_scheme",
    "get_scheme",
    "register_scheme",
    "scheme_table_markdown",
]

_LAZY_JAX = ("SpecEngine", "PagedKVCache", "PagePoolExhausted",
             "ContinuousEngine")


def __getattr__(name):
    if name in _LAZY_JAX:
        import repro.serving as serving
        return getattr(serving, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
