"""The one-stop Multi-SPIN serving API.

Everything needed to stand up, drive, and extend a Multi-SPIN cell::

    from repro.api import CellConfig, MultiSpinCell, Request

    cell = MultiSpinCell(CellConfig(scheme="hete", max_batch=4))
    cell.submit(Request(rid=0, prompt_len=8, max_new_tokens=64,
                        alpha=0.86, T_S=0.009))
    cell.drain()
    print(cell.scheduler.stats.goodput)

Scheme solvers are pluggable (``@register_scheme``), as are verification
backends (``SyntheticBackend`` for analytic sweeps, ``EngineBackend`` for
real JAX models).  ``SpecEngine`` is resolved lazily to keep the analytic
path free of jax import cost.
"""

from repro.core.channel import ChannelConfig, ChannelState  # noqa: F401
from repro.core.controller import (  # noqa: F401
    AcceptanceEstimator,
    MultiSpinController,
    VerificationLatencyModel,
)
from repro.core.protocol import DeviceProfile, MultiSpinProtocol  # noqa: F401 (deprecated shim)
from repro.core.schemes import (  # noqa: F401
    available_schemes,
    get_scheme,
    register_scheme,
)
from repro.serving.backends import (  # noqa: F401
    EngineBackend,
    SyntheticBackend,
    VerificationBackend,
)
from repro.serving.cell import (  # noqa: F401
    SCHEDULES,
    CellConfig,
    MultiSpinCell,
    RoundRecord,
)
from repro.serving.scheduler import (  # noqa: F401
    Request,
    RoundScheduler,
    SchedulerStats,
)

__all__ = [
    "AcceptanceEstimator",
    "CellConfig",
    "ChannelConfig",
    "ChannelState",
    "DeviceProfile",
    "EngineBackend",
    "MultiSpinCell",
    "MultiSpinController",
    "MultiSpinProtocol",
    "Request",
    "RoundRecord",
    "RoundScheduler",
    "SCHEDULES",
    "SchedulerStats",
    "SpecEngine",
    "SyntheticBackend",
    "VerificationBackend",
    "VerificationLatencyModel",
    "available_schemes",
    "get_scheme",
    "register_scheme",
]


def __getattr__(name):
    if name == "SpecEngine":
        from repro.serving.spec_engine import SpecEngine
        return SpecEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
