"""Compiled round path: jitted draft/verify/commit step functions.

The engine's three row-subset round steps (``draft_rows`` / ``verify_rows``
/ ``commit_rows``) bottom out in the pure step functions built here.  Each
step takes the model params, the KV-cache pytree, the (non-donated) page
table and the stream-state arrays as ARGUMENTS — nothing round-varying is
closure-captured — so ``jax.jit`` can alias the donated buffers:

  * ``draft_step``  — donates the DRAFT KV cache (argnum 1)
  * ``verify_step`` — donates the TARGET KV cache (argnum 1)
  * ``commit_step`` — donates pending / target_pos / draft_pos (0, 1, 2)

Donation invariants (docs/architecture.md "compilation & memory model"):

  * a donated buffer is DEAD after the call — the engine adopts the
    returned cache/state pytree and must never re-read the old reference;
  * the page-table array is never donated: the allocator's persistent
    device mirror (``PagedKVCache.device_table``) keeps a live reference
    across rounds;
  * step functions strip the ``"pages"`` entry from the cache they return,
    so a stale page table can never ride along inside an adopted cache.

Shapes are keyed at the same pow2 (batch, length) buckets the continuous
engine's ``BatchAssembler`` emits, which bounds retraces; the ``record``
hook fires only at TRACE time (python inside a jitted body), mirroring the
``prefill_shapes`` / ``BatchAssembler.shapes`` accounting idiom, so tests
can assert the retrace count.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.drafting import generate_drafts
from repro.core.verification import verify_drafts
from repro.models.transformer import strip_view

COMPILE_MODES = ("eager", "jit", "jit+donate")


def setup_compilation_cache(cache_dir: str | None = None) -> str | None:
    """Enable JAX's persistent compilation cache at ``cache_dir``.

    Falls back to the ``REPRO_COMPILE_CACHE`` env var when ``cache_dir`` is
    None; returns the directory actually installed (or None when disabled).
    Cold gateway starts recompile the full round path (~minutes at real
    shapes); with the cache installed a restart at the same shapes loads
    the compiled executables from disk instead.
    """
    cache_dir = cache_dir or os.environ.get("REPRO_COMPILE_CACHE")
    if not cache_dir:
        return None
    cache_dir = os.path.abspath(os.path.expanduser(str(cache_dir)))
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    # default thresholds skip small/fast compiles; serving wants every
    # round-step executable persisted so warm restarts pay zero compiles
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except (AttributeError, ValueError):  # older jax spells it differently
        pass
    return cache_dir


def commit_step(pending: jax.Array, target_pos: jax.Array,
                draft_pos: jax.Array, rows: jax.Array, skip: jax.Array,
                output_tokens: jax.Array, accept_counts: jax.Array):
    """Row-subset commit, entirely on device.

    rows: (n,) int32 state-row index per ticket slot, ``-1`` = padding.
    skip: (n,) bool — padding / frozen / retired slots commit nothing.
    output_tokens: (n, L+1); accept_counts: (n,).

    Updates ONLY the affected rows of the (B,) state arrays — padding maps
    to row 0 with a zero delta, and because integer scatter-add of zeros is
    exact, duplicated padding rows are harmless (live rows are distinct by
    the engine's one-live-ticket-per-row invariant).  Returns the new state
    arrays plus a packed ``(n, L+2)`` int32 emission —
    ``[advance, output_tokens...]`` per slot — which is the ONE device->host
    fetch the engine performs per round.
    """
    safe = jnp.where(rows < 0, 0, rows)
    k = accept_counts.astype(jnp.int32)
    adv = jnp.where(skip, 0, k + 1).astype(jnp.int32)
    new_tok = jnp.take_along_axis(output_tokens, k[:, None], axis=1)[:, 0]
    old = jnp.take(pending, safe)
    delta = jnp.where(skip, 0, new_tok.astype(pending.dtype) - old)
    pending = pending.at[safe].add(delta)
    target_pos = target_pos.at[safe].add(adv)
    draft_pos = draft_pos.at[safe].add(adv)
    emission = jnp.concatenate(
        [adv[:, None], output_tokens.astype(jnp.int32)], axis=1)
    return pending, target_pos, draft_pos, emission


@dataclasses.dataclass
class RoundSteps:
    """The three compiled (or eager) step callables for one engine.

    ``draft`` / ``verify`` are None in eager mode — the engine keeps its
    op-by-op dispatch path; ``commit`` is always callable (the eager path
    shares the same device-side commit math, just unjitted).
    """

    mode: str
    draft: Callable | None
    verify: Callable | None
    commit: Callable


def build_round_steps(target_model, draft_model, *, mode: str,
                      record: Callable[[tuple], None] | None = None,
                      ) -> RoundSteps:
    """Build the round-step callables for a (target, draft) model pair.

    ``record`` is invoked with a ``(step, B, L)`` shape key inside each
    function body — under ``jit`` that python runs at trace time only, so
    the callback counts RETRACES, not calls.
    """
    if mode not in COMPILE_MODES:
        raise ValueError(f"compile_mode must be one of {COMPILE_MODES}, "
                         f"got {mode!r}")
    donate = mode == "jit+donate"

    def _record(kind: str, n: int, L: int):
        if record is not None:
            record((kind, n, L))

    def draft_step(params, kv, pages, pending, dpos, key, *, L, vhat):
        _record("draft", pending.shape[0], L)
        cache = kv if pages is None else dict(kv, pages=pages)
        res = generate_drafts(draft_model, params, cache, pending, dpos,
                              L, key, vhat=vhat)
        return dataclasses.replace(res, cache=strip_view(res.cache))

    def verify_step(params, kv, pages, pending, tokens, probs, q_idx,
                    q_val, tpos, draft_len, key):
        _record("verify", tokens.shape[0], tokens.shape[1])
        cache = kv if pages is None else dict(kv, pages=pages)
        window = jnp.concatenate([pending[:, None], tokens], axis=1)
        logits, cache = target_model.forward_window(params, window, cache,
                                                    tpos)
        res = verify_drafts(key, tokens, probs, logits, q_idx=q_idx,
                            q_val=q_val, draft_len=draft_len)
        return res, strip_view(cache)

    def commit(pending, target_pos, draft_pos, rows, skip, output_tokens,
               accept_counts):
        _record("commit", rows.shape[0], output_tokens.shape[1] - 1)
        return commit_step(pending, target_pos, draft_pos, rows, skip,
                           output_tokens, accept_counts)

    if mode == "eager":
        return RoundSteps(mode=mode, draft=None, verify=None,
                          commit=commit_step)
    return RoundSteps(
        mode=mode,
        draft=jax.jit(draft_step, static_argnames=("L", "vhat"),
                      donate_argnums=(1,) if donate else ()),
        verify=jax.jit(verify_step,
                       donate_argnums=(1,) if donate else ()),
        commit=jax.jit(commit, donate_argnums=(0, 1, 2) if donate else ()),
    )
