"""Continuous-batching engine: per-stream round state machines with
drafting overlapped against in-flight verification (ROADMAP item 2,
DiP-SD/WISP direction).

The lockstep ``SpecEngine.spin_round`` makes every stream draft, then every
stream verify — one slow stream stalls the whole cell.  This module removes
the barrier:

  * every stream runs its own round state machine
    (``DRAFTING -> READY -> VERIFYING -> COMMITTING``, terminal ``FINISHED``
    / ``RETIRED``; every transition is validated, and ``retire`` is legal
    from ANY state and always returns the stream's pages);
  * a ``BatchAssembler`` packs verification windows from whichever READY
    streams exist, bucketed to power-of-two batch/length shapes so churny
    ready-sets bound the number of XLA retraces (the paged prefill-bucketing
    idiom — ``shapes`` + ``on_assemble_trace`` make the trace count
    testable);
  * dispatch is asynchronous end to end: drafting for the next round is
    dispatched while the previous verification batch is still in flight,
    with NO intermediate ``block_until_ready`` — the only host sync is the
    commit, applied when a batch's results complete (``is_ready`` polling as
    the completion callback, with ``max_inflight`` as the backpressure
    bound).

Correctness anchor: with the barrier forced — ``max_inflight=1``,
``exact_shapes=True`` (a single bucket) — every dispatch has the lockstep
shapes and key discipline, so committed tokens are bit-identical to
``spin_round`` at the same seed (tested).

The engine is network-free like ``SpecEngine``; ``MultiSpinCell`` wraps it
(``schedule="continuous"`` + ``ContinuousBackend``) with the channel/latency
model to produce goodput numbers.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.obs import trace

from .spec_engine import PagePoolExhausted, RoundTicket, SpecEngine, _span

# ---------------------------------------------------------------------------
# per-stream round state machine
# ---------------------------------------------------------------------------

DRAFTING = "DRAFTING"       # draft dispatch owed (or in flight on device)
READY = "READY"             # drafted; waiting for a verification batch slot
VERIFYING = "VERIFYING"     # member of an in-flight verification batch
COMMITTING = "COMMITTING"   # batch results landed; commit being applied
FINISHED = "FINISHED"       # token budget reached
RETIRED = "RETIRED"         # pages returned; terminal

PHASES = (DRAFTING, READY, VERIFYING, COMMITTING, FINISHED, RETIRED)

# every phase may retire (disconnects happen at any point of a round and
# must return pages immediately); the round cycle itself is strict
_LEGAL = {
    DRAFTING: {READY, RETIRED},
    READY: {VERIFYING, RETIRED},
    VERIFYING: {COMMITTING, RETIRED},
    COMMITTING: {DRAFTING, FINISHED, RETIRED},
    FINISHED: {RETIRED},
    RETIRED: set(),
}


class IllegalTransition(ValueError):
    """A state-machine transition outside ``_LEGAL`` — always a driver bug,
    never a load condition, so it raises instead of being swallowed."""


@dataclasses.dataclass
class StreamFSM:
    """One stream's round state machine (keyed by engine row)."""

    row: int
    length: int = 4               # planned draft length for the next round
    budget: int | None = None     # tokens to generate before FINISHED
    phase: str = DRAFTING
    generated: int = 0            # committed tokens (bonus included)
    rounds: int = 0

    def to(self, phase: str) -> "StreamFSM":
        if phase not in _LEGAL[self.phase]:
            raise IllegalTransition(
                f"stream row={self.row}: {self.phase} -> {phase} "
                f"(legal: {sorted(_LEGAL[self.phase])})")
        self.phase = phase
        return self

    @property
    def live(self) -> bool:
        return self.phase not in (FINISHED, RETIRED)


# ---------------------------------------------------------------------------
# verification-batch assembly (shape bucketing)
# ---------------------------------------------------------------------------

def _pow2_bucket(n: int, lo: int) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class BatchAssembler:
    """Packs READY streams into verification batches at bucketed shapes.

    Shapes are ``(batch_bucket, length_bucket)`` powers of two (batch from
    ``min_batch``, length from ``min_len``) so arbitrary ready-set churn
    compiles at most one XLA trace per bucket pair instead of one per
    distinct (K, L).  ``exact=True`` disables all padding — every batch is
    dispatched at its true (K, L); the forced-barrier parity mode.

    Mirrors the paged-prefill accounting idiom: ``shapes`` records every
    distinct dispatched shape and ``on_assemble_trace`` (when set) fires
    once per NEW shape, so tests can bound the retrace count under churn.
    """

    def __init__(self, max_batch: int | None = None, exact: bool = False,
                 min_batch: int = 1, min_len: int = 4):
        self.max_batch = max_batch
        self.exact = exact
        self.min_batch = int(min_batch)
        self.min_len = int(min_len)
        self.shapes: set[tuple[int, int]] = set()
        self.on_assemble_trace = None

    def length_bucket(self, L: int) -> int:
        return int(L) if self.exact else _pow2_bucket(int(L), self.min_len)

    def batch_bucket(self, K: int) -> int:
        if self.exact:
            return int(K)
        b = _pow2_bucket(int(K), self.min_batch)
        return min(b, self.max_batch) if self.max_batch else b

    def record(self, shape: tuple[int, int]) -> None:
        if shape not in self.shapes:
            self.shapes.add(shape)
            if self.on_assemble_trace is not None:
                self.on_assemble_trace(shape)

    def assemble(self, ready: list) -> list[list]:
        """Group READY members — ``(member, length)`` pairs — into batches:
        one batch per length bucket, split at ``max_batch``.  Returns the
        member groups; the driver pads each to its batch bucket and
        dispatches.  Order within a bucket is preserved (FIFO fairness)."""
        by_len: dict[int, list] = {}
        for member, L in ready:
            by_len.setdefault(self.length_bucket(int(L)), []).append(member)
        batches = []
        for Lb in sorted(by_len):
            members = by_len[Lb]
            cap = self.max_batch or len(members)
            for i in range(0, len(members), cap):
                chunk = members[i:i + cap]
                self.record((self.batch_bucket(len(chunk)), Lb))
                batches.append(chunk)
        return batches


# ---------------------------------------------------------------------------
# the continuous engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CommitEvent:
    """One landed verification batch (the continuous analogue of a round)."""

    rows: list[int]
    accepted: np.ndarray          # per member, bonus incl.; 0 = skipped
    occupancy: float              # live members / dispatched batch bucket
    seq: int                      # dispatch sequence number of the batch


@dataclasses.dataclass
class _Batch:
    """In-flight verification batch: the ticket plus its member FSMs."""

    ticket: RoundTicket
    members: list[StreamFSM]
    seq: int
    bucket: int                   # padded batch size actually dispatched


class ContinuousEngine:
    """Drives a paged ``SpecEngine`` with per-stream state machines and
    overlapped draft/verify dispatch.

    Two driving modes share all machinery:

      * **self-paced** (``add_stream`` + ``step``/``drain``) — the engine
        grows rounds for every live stream, assembling batches from
        whichever streams are READY each tick; used by the bit-identity
        tests and the overlap benchmark.
      * **externally paced** (``dispatch_round`` + ``commit``) — the caller
        (``ContinuousBackend`` under the cell's ``schedule="continuous"``
        event simulation) decides membership and timing; the engine
        supplies async dispatch, FSM safety, and shape bucketing.

    ``max_inflight`` bounds uncommitted verification batches: 1 forces the
    lockstep barrier (with ``exact_shapes=True`` this reproduces
    ``spin_round`` bit-for-bit); 2+ lets the next round's drafting dispatch
    while verification is still on device.
    """

    def __init__(self, engine: SpecEngine, state, key,
                 vhat: int = 64, max_inflight: int = 2,
                 max_batch: int | None = None, exact_shapes: bool = False):
        if engine.cache_kind != "paged":
            raise ValueError("continuous batching needs cache_kind='paged' "
                             "(row subsets + page reclaim per commit)")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.engine = engine
        self.state = state
        self.key = key
        self.vhat = vhat
        self.max_inflight = int(max_inflight)
        self.assembler = BatchAssembler(max_batch=max_batch,
                                        exact=exact_shapes)
        self.fsm: dict[int, StreamFSM] = {}
        self._inflight: deque[_Batch] = deque()
        self._seq = 0                     # key-derivation dispatch counter
        # draft tickets awaiting batch assembly: fsm.row -> (ticket, i, kv)
        self._ready: dict[int, tuple] = {}
        self.commits: list[CommitEvent] = []

    def warmup(self, batch_sizes, lengths) -> dict:
        """Pre-compile the engine's jitted round steps at every
        (batch, length) bucket this driver's ``BatchAssembler`` would emit
        for the given populations.  Cold starts otherwise pay one XLA
        trace+compile per bucket MID-ROUND; no-op on eager engines.
        Adopts the engine's returned state (jit+donate commits donate the
        state arrays)."""
        buckets = {(self.assembler.batch_bucket(int(b)),
                    self.assembler.length_bucket(int(L)))
                   for b in batch_sizes for L in lengths}
        self.state, info = self.engine.warmup(self.state, sorted(buckets),
                                              vhat=self.vhat)
        return info

    # -- stream lifecycle ----------------------------------------------

    def add_stream(self, row: int, length: int = 4,
                   budget: int | None = None) -> StreamFSM:
        """Register engine row ``row`` (already prefilled via ``start`` /
        ``add_streams``) as a live stream drafting ``length`` tokens per
        round until ``budget`` generated tokens (None = externally paced)."""
        fsm = StreamFSM(row=int(row), length=int(length), budget=budget)
        self.fsm[int(row)] = fsm
        return fsm

    def retire(self, row: int) -> None:
        """Retire from ANY phase: pages return to the pool immediately and
        an in-flight batch holding this stream skips it at commit (JAX
        arrays are immutable, so the batch's device work is unaffected)."""
        fsm = self.fsm.get(int(row))
        if fsm is None or fsm.phase == RETIRED:
            return
        fsm.to(RETIRED)
        self._ready.pop(fsm.row, None)
        self.engine.retire_stream(fsm.row)

    @property
    def done(self) -> bool:
        return (not self._inflight
                and all(not f.live for f in self.fsm.values()))

    def ready_depth(self) -> int:
        return len(self._ready)

    # -- keys ----------------------------------------------------------

    def _next_keys(self):
        """Per-dispatch key pair, lockstep-compatible: dispatch ``seq``
        folds into the base key and splits draft/verify halves exactly like
        ``spin_round``'s per-round split, so barrier mode replays the
        lockstep stream."""
        import jax

        k = jax.random.fold_in(self.key, self._seq)
        self._seq += 1
        return jax.random.split(k)

    # -- dispatch (async, no host sync) --------------------------------

    def _dispatch_draft_group(self, members: list[StreamFSM], lengths,
                              key=None):
        """Draft one group (one length bucket): rows padded to the batch
        bucket with ``-1`` sentinels, window padded to the length bucket.
        Marks members READY holding their slice of the group ticket."""
        Lb = self.assembler.length_bucket(int(np.max(lengths)))
        Bb = self.assembler.batch_bucket(len(members))
        self.assembler.record((Bb, Lb))
        if key is None:
            kd, kv = self._next_keys()
        else:
            import jax
            kd, kv = jax.random.split(key)
        rows = [f.row for f in members] + [-1] * (Bb - len(members))
        lens = np.concatenate([np.asarray(lengths, np.int64),
                               np.ones(Bb - len(members), np.int64)])
        args = None if trace.active() is None else {
            "B": Bb, "K": len(members), "L": Lb}
        with _span("engine.dispatch_draft", args):
            ticket = self.engine.draft_rows(self.state, rows, lens, kd,
                                            vhat=self.vhat, pad_to=Lb)
        for i, f in enumerate(members):
            f.to(READY)
            self._ready[f.row] = (ticket, i, kv)
        return ticket

    def _merge_members(self, members: list[StreamFSM]):
        """Build a verification ticket for READY ``members``, regathering
        their draft rows (members may come from different draft groups —
        WISP-style packing from whichever streams are ready).  When the
        members are exactly one whole draft group in order, the group
        ticket is reused as-is (no gather, and the group's verify-key half
        keeps the lockstep key discipline)."""
        import jax
        import jax.numpy as jnp

        first_ticket, _, kv = self._ready[members[0].row]
        idxs = [self._ready[f.row][1] for f in members]
        same_group = all(self._ready[f.row][0] is first_ticket
                         for f in members)
        if (same_group and len(members) == len(first_ticket.freeze)
                and idxs == list(range(len(members)))):
            return first_ticket, kv
        Lb = self.assembler.length_bucket(
            int(max(self._ready[f.row][0].L for f in members)))
        Bb = self.assembler.batch_bucket(len(members))
        self.assembler.record((Bb, Lb))

        def gather(field):
            parts = [getattr(self._ready[f.row][0].draft, field)[i]
                     for f, i in zip(members, idxs)]
            pad = [jnp.zeros_like(parts[0])] * (Bb - len(parts))
            out = jnp.stack(parts + pad)
            if out.shape[1] < Lb:     # mixed length buckets: right-pad
                padw = [(0, 0)] * out.ndim
                padw[1] = (0, Lb - out.shape[1])
                out = jnp.pad(out, padw)
            return out

        draft = dataclasses.replace(
            self._ready[members[0].row][0].draft,
            tokens=gather("tokens"), probs=gather("probs"),
            q_idx=gather("q_idx"), q_val=gather("q_val"))
        rows = [f.row for f in members] + [-1] * (Bb - len(members))
        lens = np.array([int(self._ready[f.row][0].lengths[i])
                         for f, i in zip(members, idxs)]
                        + [1] * (Bb - len(members)), np.int64)
        pend = jnp.concatenate(
            [t.pending[i][None] for t, i in
             ((self._ready[f.row][0], self._ready[f.row][1])
              for f in members)]
            + [jnp.zeros(Bb - len(members), first_ticket.pending.dtype)])
        tpos = jnp.concatenate(
            [t.target_pos[i][None] for t, i in
             ((self._ready[f.row][0], self._ready[f.row][1])
              for f in members)]
            + [jnp.zeros(Bb - len(members), jnp.int32)])
        frz = np.array([False] * len(members)
                       + [True] * (Bb - len(members)))
        ticket = RoundTicket(rows=rows, lengths=lens, L=Lb, freeze=frz,
                             pending=pend, target_pos=tpos, draft=draft)
        kv = jax.random.fold_in(self.key, self._seq)
        self._seq += 1
        return ticket, kv

    def _dispatch_verify(self, members: list[StreamFSM], key=None):
        args = None if trace.active() is None else {
            "K": len(members), "rows": [f.row for f in members]}
        with _span("engine.dispatch_verify", args):
            ticket, kv = self._merge_members(members)
            ticket = self.engine.verify_rows(ticket, key if key is not None
                                             else kv)
        for f in members:
            self._ready.pop(f.row, None)
            f.to(VERIFYING)
        batch = _Batch(ticket=ticket, members=members, seq=self._seq,
                       bucket=len(ticket.freeze))
        self._inflight.append(batch)
        return batch

    # -- commit (the only host sync) ------------------------------------

    @staticmethod
    def _result_ready(batch: _Batch) -> bool:
        is_ready = getattr(batch.ticket.res.accept_counts, "is_ready", None)
        return bool(is_ready()) if callable(is_ready) else False

    def _commit_batch(self, batch: _Batch) -> CommitEvent:
        skip = np.zeros(len(batch.ticket.freeze), dtype=bool)
        for i, f in enumerate(batch.members):
            if f.phase == RETIRED:        # retired mid-verify: skip, pages
                skip[i] = True            # already returned by retire()
            else:
                f.to(COMMITTING)
        args = None if trace.active() is None else {
            "K": len(batch.members), "seq": batch.seq}
        with _span("engine.commit_batch", args):
            self.state, accepted = self.engine.commit_rows(
                self.state, batch.ticket, skip=skip)
        live = 0
        for i, f in enumerate(batch.members):
            if f.phase == RETIRED:
                continue
            live += 1
            f.generated += int(accepted[i])
            f.rounds += 1
            if f.budget is not None and f.generated >= f.budget:
                f.to(FINISHED)
            else:
                f.to(DRAFTING)
        ev = CommitEvent(rows=[f.row for f in batch.members],
                         accepted=accepted[:len(batch.members)],
                         occupancy=live / batch.bucket if batch.bucket else 0.0,
                         seq=batch.seq)
        self.commits.append(ev)
        return ev

    # -- externally paced API (ContinuousBackend) ------------------------

    def ensure_stream(self, row: int, length: int = 4) -> StreamFSM:
        fsm = self.fsm.get(int(row))
        if fsm is None or not fsm.live:
            fsm = self.add_stream(row, length=length)
        return fsm

    def dispatch_round(self, rows, lengths, key=None) -> _Batch:
        """Draft + verify one externally chosen batch (async end to end);
        the caller later lands it with ``commit``.  The whole group goes
        through DRAFTING -> READY -> VERIFYING in one dispatch chain — the
        overlap with other in-flight batches comes from the caller
        dispatching before collecting."""
        lengths = np.asarray(lengths, dtype=np.int64)
        members = [self.ensure_stream(r, int(length))
                   for r, length in zip(rows, lengths)]
        self._dispatch_draft_group(members, lengths, key=key)
        return self._dispatch_verify(members)

    def commit(self, batch: _Batch) -> np.ndarray:
        """Land a dispatched batch; returns accepted counts aligned with
        its rows (0 for streams retired mid-flight)."""
        self._inflight.remove(batch)
        return self._commit_batch(batch).accepted

    # -- self-paced driver ----------------------------------------------

    def step(self) -> list[CommitEvent]:
        """One tick: land completed batches, dispatch drafting for every
        DRAFTING stream, assemble verification batches from the READY set,
        and apply ``max_inflight`` backpressure.  Returns the commits."""
        events = []
        # completion callbacks: commit every batch whose results are ready
        # (no blocking — is_ready is a poll)
        while self._inflight and self._result_ready(self._inflight[0]):
            events.append(self._commit_batch(self._inflight.popleft()))
        # draft next rounds while verification batches are still in flight
        drafting = [f for f in self.fsm.values() if f.phase == DRAFTING]
        if drafting:
            groups = self.assembler.assemble(
                [(f, f.length) for f in drafting])
            for g in groups:
                try:
                    self._dispatch_draft_group(
                        g, np.array([f.length for f in g], np.int64))
                except PagePoolExhausted:
                    # pool dry: hold the group in DRAFTING; in-flight
                    # commits below return pages for the next tick
                    break
        ready = [f for f in self.fsm.values() if f.phase == READY]
        dispatched = False
        if ready and len(self._inflight) < self.max_inflight:
            for g in self.assembler.assemble([(f, f.length) for f in ready]):
                self._dispatch_verify(g)
                dispatched = True
                if len(self._inflight) >= self.max_inflight:
                    break
        # backpressure: at the pipeline depth bound the oldest batch lands
        while len(self._inflight) > self.max_inflight - 1 and (
                len(self._inflight) >= self.max_inflight or not dispatched):
            events.append(self._commit_batch(self._inflight.popleft()))
            if len(self._inflight) < self.max_inflight:
                break
        if not events and not dispatched and not drafting and self._inflight:
            # nothing else can make progress: force the oldest commit
            events.append(self._commit_batch(self._inflight.popleft()))
        return events

    def drain(self, max_ticks: int = 100_000) -> list[CommitEvent]:
        """Run ``step`` until every stream is FINISHED/RETIRED."""
        for _ in range(max_ticks):
            if self.done:
                return self.commits
            self.step()
        raise RuntimeError("continuous drain did not converge "
                           f"(phases: {[f.phase for f in self.fsm.values()]})")
