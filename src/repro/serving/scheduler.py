"""Round-granular request scheduler for the Multi-SPIN cell.

The paper's protocol serves K devices per round; real cells have churn:
requests finish (EOS / max_tokens) and new devices join.  The scheduler keeps
the verification batch full (continuous batching at ROUND granularity — the
natural analogue of token-level continuous batching under synchronized
batched verification), tracks per-request accounting, and exposes the
device-profile view the controller plans against.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    task: str = ""
    alpha: float = 0.8            # task-profile acceptance estimate
    T_S: float = 0.03             # device compute speed
    prompt: tuple | None = None   # prompt tokens (engine-backed admission
                                  # after start(); None -> synthetic prompt)
    generated: int = 0
    rounds: int = 0
    done: bool = False
    submit_time: float = 0.0
    first_token_time: float = 0.0  # clock at first committed token (TTFT)
    finish_time: float = 0.0


@dataclasses.dataclass
class SchedulerStats:
    completed: int = 0
    total_tokens: int = 0
    total_rounds: int = 0
    wall_time: float = 0.0
    # per-request time-to-first-token in SIMULATED seconds (queue wait +
    # the rounds until the first commit), appended as each request first
    # produces; telemetry reports percentiles over this
    ttft_s: list = dataclasses.field(default_factory=list)
    # head-of-line blocking: the longest any SERVABLE request has so far
    # waited at the FIFO head (simulated seconds).  Capacity-blocked heads
    # hold the whole queue behind them — this is the tail cost the
    # continuous engine's per-stream rounds attack; unservable heads are
    # evicted and never counted
    hol_wait_max: float = 0.0

    @property
    def goodput(self) -> float:
        return self.total_tokens / self.wall_time if self.wall_time else 0.0


class RoundScheduler:
    """Admission + retirement around the Multi-SPIN round loop."""

    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.queue: deque[Request] = deque()
        self.active: list[Request] = []
        self.stats = SchedulerStats()
        self.clock = 0.0

    def submit(self, req: Request):
        req.submit_time = self.clock
        self.queue.append(req)

    def admit(self, can_admit=None, on_admit=None, servable=None,
              on_reject=None) -> list[Request]:
        """Fill free batch slots from the queue; returns the active set.

        ``can_admit`` (when given) is the backend's admission-control
        predicate — e.g. page-pool capacity.  Admission stays FIFO: a
        capacity-blocked head request waits at the front rather than being
        jumped, so a large request cannot starve behind a stream of small
        ones.  A head that can NEVER be served (``servable(req)`` False —
        prompt longer than the engine's max stream length, or a contiguous
        batch with no rows left) is evicted instead: marked done and handed
        to ``on_reject`` — it must not wedge the FIFO forever.  ``on_admit``
        fires per admitted request BEFORE the next capacity query, so each
        admission consumes its backend resources (page allocation) and
        ``can_admit`` always sees the true remainder."""
        while len(self.active) < self.max_batch and self.queue:
            head = self.queue[0]
            if servable is not None and not servable(head):
                self.queue.popleft()
                head.done = True
                head.finish_time = self.clock
                if on_reject is not None:
                    on_reject(head)
                continue
            if can_admit is not None and not can_admit(head):
                break
            self.active.append(self.queue.popleft())
            if on_admit is not None:
                on_admit(head)
        if self.queue:
            # whatever still heads the queue is servable (unservable heads
            # were evicted above) but blocked — by capacity or a full batch
            self.stats.hol_wait_max = max(
                self.stats.hol_wait_max,
                self.clock - self.queue[0].submit_time)
        return self.active

    def device_profiles(self):
        """(alphas, T_S) of the active set for the controller."""
        return (np.array([r.alpha for r in self.active]),
                np.array([r.T_S for r in self.active]))

    def complete_round(self, accepted: np.ndarray, round_time: float,
                       participated: np.ndarray | None = None):
        """Account one round; retire requests that reached their budget.

        ``participated`` (when given, aligned with the active set) marks
        which requests actually took part — the off half of a pipelined
        half-round sits out and must not accrue a per-request round."""
        self.clock += round_time
        self.stats.total_rounds += 1
        self.stats.wall_time += round_time
        still = []
        for i, (req, n) in enumerate(zip(self.active, accepted)):
            produced = int(min(n, req.max_new_tokens - req.generated))
            if produced > 0 and req.generated == 0:
                req.first_token_time = self.clock
                self.stats.ttft_s.append(self.clock - req.submit_time)
            req.generated += produced
            if participated is None or participated[i]:
                req.rounds += 1
            self.stats.total_tokens += produced
            if req.generated >= req.max_new_tokens:
                req.done = True
                req.finish_time = self.clock
                self.stats.completed += 1
            else:
                still.append(req)
        self.active = still

    @property
    def idle(self) -> bool:
        return not self.active and not self.queue
