"""Round-granular request scheduler for the Multi-SPIN cell.

The paper's protocol serves K devices per round; real cells have churn:
requests finish (EOS / max_tokens) and new devices join.  The scheduler keeps
the verification batch full (continuous batching at ROUND granularity — the
natural analogue of token-level continuous batching under synchronized
batched verification), tracks per-request accounting, and exposes the
device-profile view the controller plans against.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    task: str = ""
    alpha: float = 0.8            # task-profile acceptance estimate
    T_S: float = 0.03             # device compute speed
    generated: int = 0
    rounds: int = 0
    done: bool = False
    submit_time: float = 0.0
    finish_time: float = 0.0


@dataclasses.dataclass
class SchedulerStats:
    completed: int = 0
    total_tokens: int = 0
    total_rounds: int = 0
    wall_time: float = 0.0

    @property
    def goodput(self) -> float:
        return self.total_tokens / self.wall_time if self.wall_time else 0.0


class RoundScheduler:
    """Admission + retirement around the Multi-SPIN round loop."""

    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.queue: deque[Request] = deque()
        self.active: list[Request] = []
        self.stats = SchedulerStats()
        self.clock = 0.0

    def submit(self, req: Request):
        req.submit_time = self.clock
        self.queue.append(req)

    def admit(self) -> list[Request]:
        """Fill free batch slots from the queue; returns the active set."""
        while len(self.active) < self.max_batch and self.queue:
            self.active.append(self.queue.popleft())
        return self.active

    def device_profiles(self):
        """(alphas, T_S) of the active set for the controller."""
        return (np.array([r.alpha for r in self.active]),
                np.array([r.T_S for r in self.active]))

    def complete_round(self, accepted: np.ndarray, round_time: float,
                       participated: np.ndarray | None = None):
        """Account one round; retire requests that reached their budget.

        ``participated`` (when given, aligned with the active set) marks
        which requests actually took part — the off half of a pipelined
        half-round sits out and must not accrue a per-request round."""
        self.clock += round_time
        self.stats.total_rounds += 1
        self.stats.wall_time += round_time
        still = []
        for i, (req, n) in enumerate(zip(self.active, accepted)):
            produced = int(min(n, req.max_new_tokens - req.generated))
            req.generated += produced
            if participated is None or participated[i]:
                req.rounds += 1
            self.stats.total_tokens += produced
            if req.generated >= req.max_new_tokens:
                req.done = True
                req.finish_time = self.clock
                self.stats.completed += 1
            else:
                still.append(req)
        self.active = still

    @property
    def idle(self) -> bool:
        return not self.active and not self.queue
