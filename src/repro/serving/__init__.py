"""Serving runtime: Multi-SPIN cell, verification backends, speculative
engine, cache utilities, scheduler.

``SpecEngine``/``StreamState`` import jax and are resolved lazily so the
analytic serving path (cell + synthetic backend) stays importable in
milliseconds on any host.
"""

from .backends import (  # noqa: F401
    ContinuousBackend,
    EngineBackend,
    SyntheticBackend,
    VerificationBackend,
)
from .cell import CellConfig, MultiSpinCell, RoundRecord  # noqa: F401
from .scheduler import Request, RoundScheduler, SchedulerStats  # noqa: F401

# kv_cache imports jax too (snapshot selection), so the paged-cache names
# stay lazy alongside the engine (continuous imports spec_engine, so its
# names ride the same lazy group); the gateway is stdlib-only but lazy to
# keep `import repro.serving` at its current cost
_GATEWAY = ("MultiSpinGateway", "GatewayConfig", "GatewayClient",
            "MetricsHub", "RoundMetrics")
_CONTINUOUS = ("ContinuousEngine", "StreamFSM", "BatchAssembler",
               "IllegalTransition")
_LAZY = ("SpecEngine", "StreamState", "PagedKVCache",
         "PagePoolExhausted") + _CONTINUOUS + _GATEWAY


def __getattr__(name):
    if name in ("SpecEngine", "StreamState"):
        from . import spec_engine
        return getattr(spec_engine, name)
    if name in ("PagedKVCache", "PagePoolExhausted"):
        from . import kv_cache
        return getattr(kv_cache, name)
    if name in _CONTINUOUS:
        from . import continuous
        return getattr(continuous, name)
    if name in _GATEWAY:
        from . import gateway
        return getattr(gateway, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
