"""Serving runtime: Multi-SPIN cell, verification backends, speculative
engine, cache utilities, scheduler.

``SpecEngine``/``StreamState`` import jax and are resolved lazily so the
analytic serving path (cell + synthetic backend) stays importable in
milliseconds on any host.
"""

from .backends import EngineBackend, SyntheticBackend, VerificationBackend  # noqa: F401
from .cell import CellConfig, MultiSpinCell, RoundRecord  # noqa: F401
from .scheduler import Request, RoundScheduler, SchedulerStats  # noqa: F401

# kv_cache imports jax too (snapshot selection), so the paged-cache names
# stay lazy alongside the engine
_LAZY = ("SpecEngine", "StreamState", "PagedKVCache", "PagePoolExhausted")


def __getattr__(name):
    if name in ("SpecEngine", "StreamState"):
        from . import spec_engine
        return getattr(spec_engine, name)
    if name in ("PagedKVCache", "PagePoolExhausted"):
        from . import kv_cache
        return getattr(kv_cache, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
