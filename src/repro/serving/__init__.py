"""Serving runtime: Multi-SPIN cell, verification backends, speculative
engine, cache utilities, scheduler.

``SpecEngine``/``StreamState`` import jax and are resolved lazily so the
analytic serving path (cell + synthetic backend) stays importable in
milliseconds on any host.
"""

from .backends import EngineBackend, SyntheticBackend, VerificationBackend  # noqa: F401
from .cell import CellConfig, MultiSpinCell, RoundRecord  # noqa: F401
from .scheduler import Request, RoundScheduler, SchedulerStats  # noqa: F401

_LAZY = ("SpecEngine", "StreamState")


def __getattr__(name):
    if name in _LAZY:
        from . import spec_engine
        return getattr(spec_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
