"""Serving runtime: speculative engine, cache utilities, scheduler."""

from .spec_engine import SpecEngine, StreamState  # noqa: F401
