"""Pluggable verification backends for the Multi-SPIN cell (protocol step 4).

The round loop is backend-agnostic: planning, latency bookkeeping, deadline
masking, and estimator feedback live in ``MultiSpinCell``; only the
draft-then-verify compute differs between

  * ``SyntheticBackend`` — acceptance outcomes drawn Bernoulli(alpha_k)
    (the paper's analytic regime; used for the large-scale sweeps of
    Figs. 6-8 and every benchmark);
  * ``EngineBackend``    — a real JAX ``SpecEngine`` drafting and
    batch-verifying on model weights (Fig. 3 empirical curves, serving).

Benchmarks and tests swap compute by passing a different backend — protocol
code is untouched.
"""

from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import numpy as np


@runtime_checkable
class VerificationBackend(Protocol):
    """One Multi-SPIN verification step for the cell's active set.

    ``verify`` receives the planned draft lengths (one per active request,
    in scheduler order) and returns the realized accepted token counts
    INCLUDING the bonus token, i.e. values in [1, L_k + 1].  ``mask``
    (when given, aligned with ``requests``) marks deadline-dropped devices
    False: the caller zeroes their accepted counts, and stateful backends
    must not advance their streams; stateless backends may ignore it.
    """

    def verify(self, lengths: np.ndarray, requests: Sequence,
               rng: np.random.Generator, key=None,
               mask: np.ndarray | None = None) -> np.ndarray: ...


class SyntheticBackend:
    """Bernoulli(alpha) acceptance draws from the requests' true task
    acceptance rates (``Request.alpha``).  The estimator, when enabled,
    only informs planning — draws always use the true rates.  ``mask`` is
    ignored: draws are stateless, and drawing the full set preserves the
    legacy protocol's exact rng stream under deadline masking."""

    def verify(self, lengths: np.ndarray, requests: Sequence,
               rng: np.random.Generator, key=None,
               mask: np.ndarray | None = None) -> np.ndarray:
        lengths = np.asarray(lengths, dtype=np.int64)
        K = len(lengths)
        true_alpha = np.array([r.alpha for r in requests])
        u = rng.random((K, int(lengths.max())))
        pos_ok = np.arange(int(lengths.max()))[None, :] < lengths[:, None]
        acc = (u < true_alpha[:, None]) & pos_ok
        n = np.sum(np.cumprod(acc, axis=1), axis=1)
        return n + 1


class EngineBackend:
    """Real-model verification through a ``repro.serving.SpecEngine``.

    The engine batch is fixed at ``start()`` time (B streams); the backend
    maps request ids onto engine rows in admission order (the cell calls
    ``bind`` as requests are admitted, matching ``start()`` prompt order;
    unbound requests fall back to first-seen order).  Rows whose
    request is not in this call's active set (retired, or the off half of a
    pipelined schedule) ride through the batched forward frozen: they
    commit nothing and their positions do not advance, so engine stream
    content always matches the cell's per-request accounting.
    """

    def __init__(self, engine, state, vhat: int = 64):
        self.engine = engine
        self.state = state
        self.vhat = vhat
        self._row_of: dict[int, int] = {}

    @property
    def batch_size(self) -> int:
        return int(self.state.pending.shape[0])

    def bind(self, requests: Sequence) -> None:
        """Pre-register engine rows for ``requests`` in admission order.

        The cell calls this as devices join, so row assignment always
        follows ``engine.start()`` prompt order — even when the first
        ``verify`` call only sees a reordered subset of the batch (the
        pipelined schedule verifies alpha-sorted half-batches)."""
        for r in requests:
            self._row(r)

    def _row(self, r) -> int:
        if r.rid not in self._row_of:
            nxt = len(self._row_of)
            if nxt >= self.batch_size:
                raise ValueError(
                    f"engine batch exhausted: {self.batch_size} streams, "
                    f"cannot map new request rid={r.rid}")
            self._row_of[r.rid] = nxt
        return self._row_of[r.rid]

    def verify(self, lengths: np.ndarray, requests: Sequence,
               rng: np.random.Generator, key=None,
               mask: np.ndarray | None = None) -> np.ndarray:
        import jax

        lengths = np.asarray(lengths, dtype=np.int64)
        rows = [self._row(r) for r in requests]
        full = np.ones(self.batch_size, dtype=np.int64)
        full[rows] = lengths
        freeze = np.ones(self.batch_size, dtype=bool)
        freeze[rows] = False
        if mask is not None:
            # deadline-dropped devices report nothing this round: their
            # engine streams must not advance with discarded tokens
            freeze[np.asarray(rows)[~np.asarray(mask, dtype=bool)]] = True
        if key is None:
            key = jax.random.PRNGKey(int(rng.integers(2 ** 31)))
        self.state, res, _ = self.engine.spin_round(
            self.state, full, key, vhat=self.vhat, freeze=freeze)
        return np.asarray(res.output_len, dtype=np.int64)[rows]
