"""Pluggable verification backends for the Multi-SPIN cell (protocol step 4).

The round loop is backend-agnostic: planning, latency bookkeeping, deadline
masking, and estimator feedback live in ``MultiSpinCell``; only the
draft-then-verify compute differs between

  * ``SyntheticBackend`` — acceptance outcomes drawn Bernoulli(alpha_k)
    (the paper's analytic regime; used for the large-scale sweeps of
    Figs. 6-8 and every benchmark);
  * ``EngineBackend``    — a real JAX ``SpecEngine`` drafting and
    batch-verifying on model weights (Fig. 3 empirical curves, serving).

Benchmarks and tests swap compute by passing a different backend — protocol
code is untouched.  Backends may optionally expose three lifecycle hooks the
cell calls around admission:

  * ``bind(requests)``      — requests were admitted (map them to compute rows)
  * ``can_admit(request)``  — admission-control predicate (page-pool capacity)
  * ``release(requests)``   — requests retired or left (return their memory)
"""

from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from repro.obs import trace


@runtime_checkable
class VerificationBackend(Protocol):
    """One Multi-SPIN verification step for the cell's active set.

    ``verify`` receives the planned draft lengths (one per active request,
    in scheduler order) and returns the realized accepted token counts
    INCLUDING the bonus token, i.e. values in [1, L_k + 1].  ``mask``
    (when given, aligned with ``requests``) marks deadline-dropped devices
    False: the caller zeroes their accepted counts, and stateful backends
    must not advance their streams; stateless backends may ignore it.
    ``draft_width`` (the plan's multi-draft J) is only passed when J > 1 —
    single-draft backends need not accept the keyword.
    """

    def verify(self, lengths: np.ndarray, requests: Sequence,
               rng: np.random.Generator, key=None,
               mask: np.ndarray | None = None) -> np.ndarray: ...


class SyntheticBackend:
    """Bernoulli(alpha) acceptance draws from the requests' true task
    acceptance rates (``Request.alpha``).  The estimator, when enabled,
    only informs planning — draws always use the true rates.  ``mask`` is
    ignored: draws are stateless, and drawing the full set preserves the
    legacy protocol's exact rng stream under deadline masking.

    ``draft_width`` J > 1 draws J independent runs per device and keeps the
    longest (the server verifies all J drafts and commits the best — the
    ``multidraft`` scheme's acceptance model)."""

    def verify(self, lengths: np.ndarray, requests: Sequence,
               rng: np.random.Generator, key=None,
               mask: np.ndarray | None = None,
               draft_width: int = 1) -> np.ndarray:
        lengths = np.asarray(lengths, dtype=np.int64)
        K = len(lengths)
        true_alpha = np.array([r.alpha for r in requests])
        # (K, J, L) fills C-order, so J == 1 consumes the exact legacy
        # rng stream of the (K, L) draw
        u = rng.random((K, int(draft_width), int(lengths.max())))
        pos_ok = np.arange(int(lengths.max()))[None, None, :] \
            < lengths[:, None, None]
        acc = (u < true_alpha[:, None, None]) & pos_ok
        n = np.max(np.sum(np.cumprod(acc, axis=-1), axis=-1), axis=-1)
        return n + 1


class EngineBackend:
    """Real-model verification through a ``repro.serving.SpecEngine``.

    Requests map onto engine rows in admission order (the cell calls
    ``bind`` as requests are admitted).  The first B requests take the B
    streams prefilled by ``engine.start()``; with a PAGED engine every later
    request is admitted dynamically — ``engine.add_streams`` prefills its
    prompt into pooled pages (recycling retired rows first) — and
    ``can_admit`` gates the cell's admission on true page-pool capacity.
    Contiguous engines keep the legacy hard limit: through the cell, over-
    batch requests are REJECTED at admission (``servable`` False ->
    ``cell.rejected``); binding one directly still raises.

    Rows whose request is not in this call's active set (retired, or the
    off half of a pipelined schedule) ride through the batched forward
    frozen: they commit nothing and their positions do not advance, so
    engine stream content always matches the cell's per-request accounting.
    ``release`` returns the pages of retired/left requests to the pool.

    ``admit_headroom`` is the token slack reserved beyond the prompt when
    answering ``can_admit`` — one verification window's worth, so a stream
    admitted this round cannot OOM the pool on its first spin.
    """

    def __init__(self, engine, state, vhat: int = 64,
                 admit_headroom: int = 32,
                 keep_finished_tokens: bool = False):
        self.engine = engine
        self.state = state
        self.vhat = vhat
        self.admit_headroom = admit_headroom
        # the gateway streams committed tokens per round; a request's final
        # round retires its row INSIDE cell.step (release -> pages freed,
        # row recyclable), so with this flag the generated suffix is kept
        # as a tombstone until the consumer calls ``drop_finished`` —
        # off by default so batch sessions carry no extra state
        self.keep_finished_tokens = keep_finished_tokens
        self._finished_tokens: dict[int, list[int]] = {}
        self._row_of: dict[int, int] = {}
        self._prompt_len_of: dict[int, int] = {}
        self._start_rows = int(state.pending.shape[0])
        self._next_start_row = 0
        # host-transfer accounting: engine.host_syncs delta across the last
        # verify/collect call (RoundRecord.n_host_syncs picks this up)
        self.last_round_host_syncs: int | None = None

    @property
    def batch_size(self) -> int:
        return int(self.state.pending.shape[0])

    @property
    def dynamic(self) -> bool:
        return getattr(self.engine, "cache_kind", "contiguous") == "paged"

    # -- lifecycle hooks (called by the cell) ---------------------------

    def servable(self, request) -> bool:
        """Whether the request can EVER run on this engine.  The cell evicts
        unservable requests (into ``cell.rejected``, done=True) — they must
        not sit in the FIFO forever.  Paged: the prompt plus one generated
        token has to fit a stream.  Contiguous: rows are never freed, so a
        request beyond the start batch can never be served (the legacy code
        raised 'engine batch exhausted' here; rejection keeps the signal
        loud without killing the cell's other streams)."""
        if not self.dynamic:
            return (request.rid in self._row_of
                    or self._next_start_row < self._start_rows)
        return self._prompt_len(request) + 1 <= self.engine.max_len

    def can_admit(self, request) -> bool:
        """True while start() streams remain unbound; afterwards defer to
        the engine's page pools (contiguous engines are full at that point).
        The capacity ask is clamped to the stream ceiling so near-max_len
        prompts are judged by what they can actually occupy."""
        if self._next_start_row < self._start_rows:
            return True
        if not self.dynamic:
            return False
        p = self._prompt_len(request)
        # the admission ask covers BOTH the bucketed prefill shape (paged
        # prefill pads the prompt to a power-of-two trace shape, which
        # transiently maps that many pages) and one verification window
        length = min(max(p + self.admit_headroom, self.engine.prompt_bucket(p)),
                     self.engine.max_len)
        return self.engine.can_admit(length)

    def bind(self, requests: Sequence) -> None:
        """Pre-register engine rows for ``requests`` in admission order.

        The cell calls this as devices join, so row assignment always
        follows ``engine.start()`` prompt order — even when the first
        ``verify`` call only sees a reordered subset of the batch (the
        pipelined schedule verifies alpha-sorted half-batches)."""
        for r in requests:
            self._row(r)

    def release(self, requests: Sequence) -> None:
        """Hand the engine rows of retired/departed requests back: their
        pages return to the pool and the rows become recyclable.  With
        ``keep_finished_tokens`` the generated suffix survives as a
        tombstone (``stream_tokens``) until ``drop_finished``."""
        for r in requests:
            if self.keep_finished_tokens and r.rid in self._row_of:
                self._finished_tokens[r.rid] = self.stream_tokens(r.rid)
            if not self.dynamic:
                continue
            row = self._row_of.pop(r.rid, None)
            if row is not None:
                self.engine.retire_stream(row)

    # -- telemetry / streaming accessors --------------------------------

    def pool_stats(self) -> dict:
        """Engine memory snapshot (paged: page-pool occupancy) for the
        cell's RoundRecord and the metrics hub."""
        return self.engine.pool_stats()

    def stream_tokens(self, rid: int) -> list[int]:
        """The committed tokens a request has GENERATED so far (prompt
        excluded), from its live engine row or its post-retirement
        tombstone; [] for unknown rids.  The gateway slices this against
        the scheduler's capped per-request counts, so uncapped final-round
        overshoot is never streamed."""
        row = self._row_of.get(rid)
        if row is None:
            return list(self._finished_tokens.get(rid, []))
        toks = self.state.committed[row]
        return [int(t) for t in toks[self._prompt_len_of[rid]:]]

    def drop_finished(self, rid: int) -> None:
        """Forget a finished request's token tombstone (called by the
        gateway once the final tokens are streamed out)."""
        self._finished_tokens.pop(rid, None)

    # -- row mapping ----------------------------------------------------

    def _prompt_len(self, r) -> int:
        if getattr(r, "prompt", None) is not None:
            return len(r.prompt)
        return max(int(r.prompt_len), 2)

    def _prompt_tokens(self, r):
        """The request's prompt, or a deterministic synthetic one (analytic
        callers describe devices by ``prompt_len`` only)."""
        import jax

        if getattr(r, "prompt", None) is not None:
            import jax.numpy as jnp
            return jnp.asarray(list(r.prompt), jnp.int32)
        vocab = self.engine.target_cfg.vocab_size
        return jax.random.randint(jax.random.PRNGKey(r.rid ^ 0x5eed),
                                  (self._prompt_len(r),), 0, vocab)

    def _row(self, r) -> int:
        if r.rid not in self._row_of:
            self._prompt_len_of[r.rid] = self._prompt_len(r)
            if self._next_start_row < self._start_rows:
                self._row_of[r.rid] = self._next_start_row
                self._next_start_row += 1
            elif self.dynamic:
                self.state, rows = self.engine.add_streams(
                    self.state, self._prompt_tokens(r)[None, :])
                self._row_of[r.rid] = rows[0]
            else:
                raise ValueError(
                    f"engine batch exhausted: {self.batch_size} contiguous "
                    f"streams, cannot map new request rid={r.rid} "
                    "(cache_kind='paged' serves churn)")
        return self._row_of[r.rid]

    # -- the verification step ------------------------------------------

    def verify(self, lengths: np.ndarray, requests: Sequence,
               rng: np.random.Generator, key=None,
               mask: np.ndarray | None = None,
               draft_width: int = 1) -> np.ndarray:
        import jax

        lengths = np.asarray(lengths, dtype=np.int64)
        rows = [self._row(r) for r in requests]
        B = self.batch_size
        full = np.ones(B, dtype=np.int64)
        full[rows] = lengths
        freeze = np.ones(B, dtype=bool)
        freeze[rows] = False
        if mask is not None:
            # deadline-dropped devices report nothing this round: their
            # engine streams must not advance with discarded tokens
            freeze[np.asarray(rows)[~np.asarray(mask, dtype=bool)]] = True
        if key is None:
            key = jax.random.PRNGKey(int(rng.integers(2 ** 31)))
        args = None if trace.active() is None else {
            "B": B, "K": len(rows), "L_max": int(lengths.max()),
            "J": int(draft_width)}
        h0 = int(getattr(self.engine, "host_syncs", 0))
        with trace.span("engine.verify", cat="engine", args=args) as sp:
            self.state, res, _ = self.engine.spin_round(
                self.state, full, key, vhat=self.vhat, freeze=freeze,
                draft_width=int(draft_width))
            sp.attach(res.output_len)
        self.last_round_host_syncs = \
            int(getattr(self.engine, "host_syncs", 0)) - h0
        # the commit's packed emission already landed the accepted counts on
        # host (0 for frozen rows — both cell schedules zero masked entries
        # anyway), so reading them here costs no extra device fetch
        accepted = getattr(self.engine, "last_accepted", None)
        if accepted is not None and len(accepted) == B:
            return np.asarray(accepted, dtype=np.int64)[rows]
        return np.asarray(res.output_len, dtype=np.int64)[rows]


class ContinuousBackend(EngineBackend):
    """``EngineBackend`` driven through the continuous-batching engine
    (``serving.continuous.ContinuousEngine``): verification batches are
    dispatched asynchronously and landed later, so the cell's
    ``schedule="continuous"`` mode can overlap the next round's drafting
    with verification still in flight.

    The split API is

      * ``verify_async(lengths, requests, ...)`` — draft + verify dispatch
        for exactly these requests (shape-bucketed; async, no host sync);
        returns an opaque in-flight batch handle;
      * ``collect(handle)`` — land the batch: the ONLY host sync; commits
        accepted tokens, truncates rejected-draft pages, returns accepted
        counts aligned with the handle's requests (0 for streams that
        retired mid-flight).

    ``verify`` (the plain protocol method) is dispatch + immediate collect,
    so this backend also drops into the sync/pipelined schedules unchanged.
    Engine state lives in the continuous engine; ``self.state`` is a view
    onto it so every inherited accessor (``stream_tokens``, ``add_streams``
    binding, pool stats) stays correct.
    """

    def __init__(self, engine, state, vhat: int = 64,
                 admit_headroom: int = 32,
                 keep_finished_tokens: bool = False,
                 max_inflight: int = 2, max_batch: int | None = None,
                 exact_shapes: bool = False, seed: int = 0):
        import jax

        from .continuous import ContinuousEngine

        # cont must exist before super().__init__ assigns self.state
        # (the property below delegates into it)
        self.cont = ContinuousEngine(
            engine, state, jax.random.PRNGKey(seed), vhat=vhat,
            max_inflight=max_inflight, max_batch=max_batch,
            exact_shapes=exact_shapes)
        super().__init__(engine, state, vhat=vhat,
                         admit_headroom=admit_headroom,
                         keep_finished_tokens=keep_finished_tokens)

    @property
    def state(self):
        return self.cont.state

    @state.setter
    def state(self, value):
        self.cont.state = value

    def ready_depth(self) -> int:
        return self.cont.ready_depth()

    def verify_async(self, lengths: np.ndarray, requests: Sequence,
                     rng: np.random.Generator = None, key=None):
        """Dispatch one draft+verify chain for ``requests`` without any
        host synchronization; pair with ``collect``."""
        lengths = np.asarray(lengths, dtype=np.int64)
        rows = [self._row(r) for r in requests]
        return self.cont.dispatch_round(rows, lengths, key=key)

    def collect(self, handle) -> np.ndarray:
        """Land an in-flight batch (host sync + commit + page reclaim)."""
        h0 = int(getattr(self.engine, "host_syncs", 0))
        out = np.asarray(self.cont.commit(handle), dtype=np.int64)
        self.last_round_host_syncs = \
            int(getattr(self.engine, "host_syncs", 0)) - h0
        return out

    def verify(self, lengths: np.ndarray, requests: Sequence,
               rng: np.random.Generator, key=None,
               mask: np.ndarray | None = None,
               draft_width: int = 1) -> np.ndarray:
        if int(draft_width) > 1:
            raise NotImplementedError(
                "continuous batching is single-draft (J=1); multidraft "
                "token trees run on the lockstep EngineBackend")
        lengths = np.asarray(lengths, dtype=np.int64)
        if mask is not None:
            keep = np.asarray(mask, dtype=bool)
            out = np.zeros(len(requests), dtype=np.int64)
            if keep.any():
                sub = [r for r, m in zip(requests, keep) if m]
                out[keep] = self.collect(
                    self.verify_async(lengths[keep], sub, rng, key=key))
            return out
        return self.collect(self.verify_async(lengths, requests, rng,
                                              key=key))

    def release(self, requests: Sequence) -> None:
        """Retire through the state machines first (legal from any phase;
        an in-flight batch holding the stream skips it at commit), then the
        inherited bookkeeping (tombstones, row-map cleanup)."""
        for r in requests:
            row = self._row_of.get(r.rid)
            if row is not None:
                self.cont.retire(row)
        super().release(requests)
