"""Speculative decoding engine: SLM drafting + LLM batched verification on
real model weights (the compute core of Multi-SPIN, paper Fig. 1).

One ``SpecEngine`` drives B concurrent streams (one per edge device).  Each
round (paper Fig. 2):

  2. drafting       — ``generate_drafts`` on the draft model (per-stream
                       heterogeneous lengths, zero-padded to the window)
  4. verification   — ONE ``forward_window`` of the target model over
                       [pending, d_1 .. d_L] followed by exact accept/reject
                       (``verify_drafts``)
  5. state update   — pointer arithmetic for attention caches; snapshot
                       rollback for SSM state

The engine is deliberately network-free: the protocol layer wraps it with the
channel/latency model to produce goodput numbers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.drafting import generate_drafts
from repro.core.verification import VerifyResult, verify_drafts
from repro.models import build_model

from .kv_cache import merge_snapshot_into_cache, needs_state_rollback, select_snapshots


@dataclasses.dataclass
class StreamState:
    """Per-batch serving state (B streams)."""

    pending: jax.Array        # (B,) last committed token, not yet in caches
    target_pos: jax.Array     # (B,) target-cache fill level
    draft_pos: jax.Array      # (B,) draft-cache fill level
    committed: list           # python-side committed token lists (B)


class SpecEngine:
    def __init__(self, target_cfg: ModelConfig, draft_cfg: ModelConfig,
                 max_len: int = 512, cache_dtype=jnp.float32):
        assert target_cfg.vocab_size == draft_cfg.vocab_size, \
            "SLM/LLM pair must share a vocabulary"
        self.target_cfg = target_cfg
        self.draft_cfg = draft_cfg
        self.target = build_model(target_cfg)
        self.draft = build_model(draft_cfg)
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.t_params = None
        self.d_params = None

    # ------------------------------------------------------------------

    def init_params(self, key):
        kt, kd = jax.random.split(key)
        self.t_params = self.target.init(kt)
        self.d_params = self.draft.init(kd)
        return self.t_params, self.d_params

    def start(self, prompts: jax.Array) -> StreamState:
        """Prefill both models on the prompts (B, M).  The last prompt token
        becomes the pending token (its logits seed round 1)."""
        B, M = prompts.shape
        self.t_cache = self.target.init_cache(B, self.max_len, self.cache_dtype)
        self.d_cache = self.draft.init_cache(B, self.max_len, self.cache_dtype)
        _, self.t_cache, _ = self.target.prefill(self.t_params, prompts[:, :-1],
                                                 self.t_cache)
        _, self.d_cache, _ = self.draft.prefill(self.d_params, prompts[:, :-1],
                                                self.d_cache)
        return StreamState(
            pending=prompts[:, -1],
            target_pos=jnp.full((B,), M - 1, jnp.int32),
            draft_pos=jnp.full((B,), M - 1, jnp.int32),
            committed=[list(np.asarray(prompts[b])) for b in range(B)],
        )

    # ------------------------------------------------------------------

    def spin_round(self, state: StreamState, lengths: np.ndarray,
                   key: jax.Array, vhat: int = 64,
                   freeze: np.ndarray | None = None):
        """One Multi-SPIN round with per-stream draft lengths (zero-padded to
        the max).  Returns (state, VerifyResult, draft_result).

        ``freeze`` marks streams that must NOT advance this round (retired
        requests, or the off half of a pipelined schedule).  Frozen rows
        still ride through the batched forwards (the reference engine cannot
        skip batch rows) but commit nothing: positions, pending token, and
        committed text are untouched.  For attention targets/drafts the
        cache is pointer-indexed, so the stale window writes are overwritten
        on the row's next live round; SSM targets would need a pre-window
        state restore and are rejected.
        """
        B = state.pending.shape[0]
        lengths = np.asarray(lengths, dtype=np.int64)
        frz_np = (np.zeros(B, dtype=bool) if freeze is None
                  else np.asarray(freeze, dtype=bool))
        if frz_np.any() and needs_state_rollback(self.target_cfg):
            raise NotImplementedError(
                "freezing streams of an SSM/hybrid target needs a pre-window "
                "state snapshot (see ROADMAP open items)")
        L = int(lengths.max())
        k_draft, k_verify = jax.random.split(key)

        # --- step 2: distributed drafting (SLM) ---
        d_snap = self.d_cache if needs_state_rollback(self.draft_cfg) else None
        draft_res = generate_drafts(self.draft, self.d_params, self.d_cache,
                                    state.pending, state.draft_pos, L,
                                    k_draft, vhat=vhat)
        self.d_cache = draft_res.cache

        # --- step 4: batched verification (LLM) ---
        window = jnp.concatenate([state.pending[:, None], draft_res.tokens],
                                 axis=1)                       # (B, L+1)
        if needs_state_rollback(self.target_cfg):
            logits, t_cache, snaps = self.target.forward_window(
                self.t_params, window, self.t_cache, state.target_pos,
                return_snapshots=True)
        else:
            logits, t_cache = self.target.forward_window(
                self.t_params, window, self.t_cache, state.target_pos)
            snaps = None

        draft_len = jnp.asarray(lengths, jnp.int32)
        res = verify_drafts(k_verify, draft_res.tokens, draft_res.probs,
                            logits, q_idx=draft_res.q_idx, q_val=draft_res.q_val,
                            draft_len=draft_len)

        # --- step 5: commit + rollback ---
        # target cache: row b processed [pending, d_1..d_n]; snapshot index n
        # (0-based: snapshot t is the state after feeding window[:, :t+1]).
        if snaps is not None:
            sel = select_snapshots(snaps, res.accept_counts,
                                   self.target.CACHE_BATCH_AXES)
            t_cache = merge_snapshot_into_cache(t_cache, sel)
        self.t_cache = t_cache

        # draft cache: processed [pending, d_1..d_{L-1}]; valid prefix for row
        # b is pending + n accepted drafts. SSM draft state rolls back via
        # re-prefill from scratch in this reference engine only when needed.
        if needs_state_rollback(self.draft_cfg):
            raise NotImplementedError(
                "SSM draft models need snapshot drafting; assigned pairs use "
                "attention SLMs (DESIGN.md §Arch-applicability)")

        frz = jnp.asarray(frz_np)
        adv = jnp.where(frz, 0, 1 + res.accept_counts)
        new_target_pos = state.target_pos + adv
        new_draft_pos = state.draft_pos + adv
        sampled = jnp.take_along_axis(
            res.output_tokens, res.accept_counts[:, None], axis=1)[:, 0]
        new_pending = jnp.where(frz, state.pending, sampled)

        out_np = np.asarray(res.output_tokens)
        n_np = np.asarray(res.accept_counts)
        for b in range(B):
            if not frz_np[b]:
                state.committed[b].extend(out_np[b, :n_np[b] + 1].tolist())

        new_state = StreamState(pending=new_pending, target_pos=new_target_pos,
                                draft_pos=new_draft_pos,
                                committed=state.committed)
        return new_state, res, draft_res
