"""Speculative decoding engine: SLM drafting + LLM batched verification on
real model weights (the compute core of Multi-SPIN, paper Fig. 1).

One ``SpecEngine`` drives B concurrent streams (one per edge device).  Each
round (paper Fig. 2):

  2. drafting       — ``generate_drafts`` on the draft model (per-stream
                       heterogeneous lengths, zero-padded to the window)
  4. verification   — ONE ``forward_window`` of the target model over
                       [pending, d_1 .. d_L] followed by exact accept/reject
                       (``verify_drafts``)
  5. state update   — pointer arithmetic for attention caches; snapshot
                       rollback for SSM state

Two cache layouts:

  * ``cache_kind="contiguous"`` — the classic (B, max_len) slabs fixed at
    ``start()``; the stream population can never change.
  * ``cache_kind="paged"``      — both models' KV lives in fixed-size pages
    of a preallocated pool (``PagedKVCache``); streams may join after
    ``start()`` (``add_streams``) and leave (``retire_stream``), rejected
    speculative tokens return their pages each round, and admission is
    bounded only by the page pool (``can_admit``).

The engine is deliberately network-free: the cell layer wraps it with the
channel/latency model to produce goodput numbers.
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.drafting import generate_draft_forest, generate_drafts
from repro.core.token_tree import TreeScratch, build_token_tree
from repro.core.verification import verify_drafts, verify_tree
from repro.models import build_model
from repro.models.layers import gather_kv_window, scatter_kv_window
from repro.models.transformer import strip_view
from repro.obs import trace

from .compiled import (
    COMPILE_MODES,
    build_round_steps,
    setup_compilation_cache,
)
from .kv_cache import (
    PagedKVCache,
    PagePoolExhausted,
    cache_bytes,
    merge_snapshot_into_cache,
    needs_state_rollback,
    paged_pool_bytes_per_page,
    select_snapshots,
)

CACHE_KINDS = ("contiguous", "paged")


def _span(name: str, args: dict | None = None):
    """Engine-phase span (``cat="engine"``).  These fire once per round (not
    per dispatched op), so the few args dicts built per round are noise; the
    per-op hot path in ``kernels/ops.py`` has the strict zero-allocation
    guard."""
    if trace.active() is None:
        return trace.NULL_SPAN
    return trace.span(name, cat="engine", args=args)


@dataclasses.dataclass
class StreamState:
    """Per-batch serving state (B streams)."""

    pending: jax.Array        # (B,) last committed token, not yet in caches
    target_pos: jax.Array     # (B,) target-cache fill level
    draft_pos: jax.Array      # (B,) draft-cache fill level
    committed: list           # python-side committed token lists (B)


@dataclasses.dataclass
class RoundTicket:
    """In-flight round work for a row subset, between dispatch and commit.

    Produced by ``draft_rows``, completed by ``verify_rows``, consumed by
    ``commit_rows``.  Everything on it is either host metadata or an
    ASYNCHRONOUSLY dispatched jax array — holding a ticket never blocks, so
    a continuous-batching driver can dispatch the next round's drafting
    while this ticket's verification is still in flight.

    ``rows`` may contain ``-1`` padding entries (batch-shape bucketing):
    their page-table rows are all ``-1`` — cache writes dropped, reads
    masked — and ``commit_rows`` skips them unconditionally.
    """

    rows: list | None             # engine rows; None = the full batch
    lengths: np.ndarray           # per-row planned draft lengths
    L: int                        # the dispatched window length (max/bucket)
    freeze: np.ndarray            # per-row do-not-advance mask
    pending: jax.Array            # (n,) pending tokens at dispatch
    target_pos: jax.Array         # (n,) target positions at dispatch
    draft: object | None = None   # DraftResult from generate_drafts
    res: object | None = None     # VerifyResult from verify_drafts


class SpecEngine:
    """Speculative-decoding engine for B device streams: a small draft
    model proposes tokens, a large target model batch-verifies them, and
    both models' KV caches advance only over committed tokens.

    ``spin_round`` is one protocol round.  ``draft_width`` J > 1 runs
    token-TREE verification: J i.i.d. drafts per stream packed into a
    prefix-deduplicated trie, scored in ONE ancestor-masked target pass,
    with the longest accepted root-to-leaf path committed.  The row-subset
    API (``draft_rows`` / ``verify_rows`` / ``commit_rows``) exposes the
    same round as async pieces for continuous batching.

    ``cache_kind``: ``"contiguous"`` fixes the batch at ``start()``;
    ``"paged"`` serves churn from a pooled ``PagedKVCache`` (streams join
    after start, retire, recycle rows).  Attention over either layout
    dispatches through the Pallas kernel ops when ``REPRO_KERNELS`` selects
    them (docs/kernels.md).

    ``tree_commit``: how accepted tree branches reach the cache.
    ``"scatter"`` (default) gathers the winning branch's K/V from the
    already-written tree window and scatters it to contiguous positions —
    no extra forward pass (span ``engine.kv_commit``); ``"repair"`` keeps
    the reference re-forward over ``[pending, accepted path]`` (span
    ``engine.cache_repair``).  Both commit identical tokens at the same
    seed (tested, and asserted by ``bench_beyond --engine``)."""

    def __init__(self, target_cfg: ModelConfig, draft_cfg: ModelConfig,
                 max_len: int = 512, cache_dtype=jnp.float32,
                 cache_kind: str = "contiguous", page_size: int = 16,
                 num_pages: int | None = None, tree_commit: str = "scatter",
                 compile_mode: str | None = None,
                 compile_cache: str | None = None):
        assert target_cfg.vocab_size == draft_cfg.vocab_size, \
            "SLM/LLM pair must share a vocabulary"
        if cache_kind not in CACHE_KINDS:
            raise ValueError(f"cache_kind must be one of {CACHE_KINDS}")
        if tree_commit not in ("scatter", "repair"):
            raise ValueError("tree_commit must be 'scatter' or 'repair'")
        if cache_kind == "paged" and (needs_state_rollback(target_cfg)
                                      or needs_state_rollback(draft_cfg)):
            raise NotImplementedError(
                "paged caches cover attention KV only; SSM/hybrid recurrent "
                "state is O(1) per stream and needs no paging (ROADMAP)")
        if compile_mode is None:
            compile_mode = os.environ.get("REPRO_COMPILE", "eager")
        if compile_mode not in COMPILE_MODES:
            raise ValueError(f"compile_mode must be one of {COMPILE_MODES}, "
                             f"got {compile_mode!r}")
        if compile_mode != "eager" and (needs_state_rollback(target_cfg)
                                        or needs_state_rollback(draft_cfg)):
            raise NotImplementedError(
                "compiled round steps cover attention models; SSM/hybrid "
                "snapshot rollback re-enters python between the target pass "
                "and the cache merge (ROADMAP open items)")
        self.target_cfg = target_cfg
        self.draft_cfg = draft_cfg
        self.target = build_model(target_cfg)
        self.draft = build_model(draft_cfg)
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.cache_kind = cache_kind
        self.tree_commit = tree_commit
        self.page_size = int(page_size)
        self.pages_per_stream = -(-max_len // self.page_size)
        self.num_pages = num_pages
        self.t_params = None
        self.d_params = None
        self.t_pages: PagedKVCache | None = None
        self.d_pages: PagedKVCache | None = None
        self._free_rows: list[int] = []
        self._retired: set[int] = set()
        # compile accounting: every paged-prefill batch shape actually traced
        # (one XLA trace per distinct shape); tests hook ``on_prefill_trace``
        self.prefill_shapes: set[tuple[int, int]] = set()
        self.on_prefill_trace = None
        # compiled round path: jitted draft/verify/commit step functions
        # (serving/compiled.py).  ``step_shapes`` collects every (step, B, L)
        # actually TRACED — the hook fires from inside the jitted bodies, so
        # it counts retraces, not calls; ``warmup()`` pre-seeds the buckets.
        self.compile_mode = compile_mode
        if compile_mode != "eager":
            setup_compilation_cache(compile_cache)
        self.step_shapes: set[tuple] = set()
        self.on_step_trace = None
        self._steps = build_round_steps(self.target, self.draft,
                                        mode=compile_mode,
                                        record=self._record_step)
        # host-transfer accounting: every blocking device->host fetch on the
        # round path funnels through ``_host_fetch`` and bumps this
        self.host_syncs = 0
        # host-side accepted counts of the last FULL-BATCH commit (the
        # lockstep backend reads these instead of re-fetching output_len);
        # None after row-subset commits, whose alignment is ticket-local
        self.last_accepted: np.ndarray | None = None
        self._tree_scratch = TreeScratch()

    def _record_step(self, shape: tuple) -> None:
        self.step_shapes.add(shape)
        if self.on_step_trace is not None:
            self.on_step_trace(shape)

    def _host_fetch(self, value):
        """Blocking device->host fetch.  The ONE per-round call site is the
        packed commit emission in ``commit_rows``; tree rounds add the
        host-side trie build and (repair mode) the accepted-depth fetch.
        Counting every fetch here keeps ``RoundRecord.n_host_syncs``
        honest."""
        self.host_syncs += 1
        trace.incr("engine.host_sync")
        return jax.device_get(value)

    # ------------------------------------------------------------------

    def init_params(self, key):
        kt, kd = jax.random.split(key)
        self.t_params = self.target.init(kt)
        self.d_params = self.draft.init(kd)
        return self.t_params, self.d_params

    def start(self, prompts: jax.Array) -> StreamState:
        """Prefill both models on the prompts (B, M).  The last prompt token
        becomes the pending token (its logits seed round 1).

        Paged engines size the pool here when ``num_pages`` was not given:
        2x the pages the start batch needs at max_len, so churn has headroom
        by default."""
        B, M = prompts.shape
        self._free_rows, self._retired = [], set()
        if self.cache_kind == "paged":
            if self.num_pages is None:
                self.num_pages = 2 * B * self.pages_per_stream
            self.t_cache = self.target.init_paged_cache(
                self.num_pages, self.page_size, self.cache_dtype)
            self.d_cache = self.draft.init_paged_cache(
                self.num_pages, self.page_size, self.cache_dtype)
            self.t_pages = PagedKVCache(
                self.num_pages, self.page_size, self.pages_per_stream,
                paged_pool_bytes_per_page(self.t_cache))
            self.d_pages = PagedKVCache(
                self.num_pages, self.page_size, self.pages_per_stream,
                paged_pool_bytes_per_page(self.d_cache))
            state = StreamState(
                pending=jnp.zeros((0,), prompts.dtype),
                target_pos=jnp.zeros((0,), jnp.int32),
                draft_pos=jnp.zeros((0,), jnp.int32),
                committed=[])
            state, _ = self.add_streams(state, prompts)
            return state
        self.t_cache = self.target.init_cache(B, self.max_len, self.cache_dtype)
        self.d_cache = self.draft.init_cache(B, self.max_len, self.cache_dtype)
        _, self.t_cache, _ = self.target.prefill(self.t_params, prompts[:, :-1],
                                                 self.t_cache)
        _, self.d_cache, _ = self.draft.prefill(self.d_params, prompts[:, :-1],
                                                self.d_cache)
        return StreamState(
            pending=prompts[:, -1],
            target_pos=jnp.full((B,), M - 1, jnp.int32),
            draft_pos=jnp.full((B,), M - 1, jnp.int32),
            committed=[list(np.asarray(prompts[b])) for b in range(B)],
        )

    # ------------------------------------------------------------------
    # dynamic stream admission (paged only)
    # ------------------------------------------------------------------

    def can_admit(self, length: int) -> bool:
        """Whether BOTH page pools can map a new stream of ``length`` tokens
        right now — the admission-control predicate (OOM-safe: pure query)."""
        if self.cache_kind != "paged":
            return False
        return (self.t_pages.can_allocate(length)
                and self.d_pages.can_allocate(length))

    def pool_stats(self) -> dict:
        """Byte-level accounting for placement / admission decisions."""
        if self.cache_kind != "paged":
            return {"cache_bytes": cache_bytes(self.t_cache)
                    + cache_bytes(self.d_cache)}
        return {
            "cache_bytes": cache_bytes(self.t_cache) + cache_bytes(self.d_cache),
            "free_bytes": self.t_pages.free_bytes() + self.d_pages.free_bytes(),
            "used_bytes": self.t_pages.used_bytes() + self.d_pages.used_bytes(),
            "free_pages": min(self.t_pages.num_free_pages,
                              self.d_pages.num_free_pages),
        }

    def prompt_bucket(self, M: int) -> int:
        """Power-of-two bucket (min 8, capped at ``max_len``) for paged
        prefill shapes.  Joining prompts are right-padded to the bucket so
        heavy churn compiles one XLA prefill trace per bucket instead of
        one per distinct prompt length."""
        b = 8
        while b < M:
            b *= 2
        return max(M, min(b, self.max_len))

    def add_streams(self, state: StreamState, prompts: jax.Array):
        """Admit ``prompts`` (n, M) as new streams AFTER ``start()``.

        Retired batch rows are recycled first; otherwise the batch grows.
        Pages are allocated from the pool (``PagePoolExhausted`` when it is
        truly out of memory — call ``can_admit`` first).  The prefill runs
        at the power-of-two ``prompt_bucket`` shape: pad K/V past the true
        prompt is written but never attended (per-row length masking) and
        its pages return to the pool right after the prefill.  Returns
        ``(new_state, rows)`` with the engine rows assigned in order."""
        if self.cache_kind != "paged":
            raise RuntimeError(
                "contiguous caches are fixed at start(); construct the "
                "engine with cache_kind='paged' to serve churn")
        n, M = prompts.shape
        Mb = self.prompt_bucket(M)
        B = state.pending.shape[0]
        rows = []
        for _ in range(n):
            row = self._free_rows.pop(0) if self._free_rows else B + len(
                [r for r in rows if r >= B])
            rows.append(row)
        allocated = []
        try:
            for row in rows:
                # transiently map the BUCKETED prefill extent; truncated back
                # to the true prompt right after the prefill below
                self.t_pages.alloc_stream(row, Mb - 1)
                allocated.append((self.t_pages, row))
                self.d_pages.alloc_stream(row, Mb - 1)
                allocated.append((self.d_pages, row))
        except Exception:
            for mgr, row in allocated:
                mgr.free_stream(row)
            self._free_rows = sorted(set(self._free_rows)
                                     | {r for r in rows if r < B})
            raise
        self._retired -= set(rows)

        # prefill ONLY the new rows; their pages view writes into the pools.
        # Right-pad to the bucket with the last prompt token: padded K/V
        # lands at positions >= M-1, which every later window write covers
        # before attention can reach it (causal mask kj <= position).
        if Mb > M:
            pad = jnp.tile(prompts[:, -1:], (1, Mb - M))
            padded = jnp.concatenate([prompts, pad], axis=1)
        else:
            padded = prompts
        self.prefill_shapes.add((n, Mb))
        if self.on_prefill_trace is not None:
            self.on_prefill_trace((n, Mb))
        t_view = dict(self.t_cache, pages=self.t_pages.device_table(rows))
        d_view = dict(self.d_cache, pages=self.d_pages.device_table(rows))
        _, t_view, _ = self.target.prefill(self.t_params, padded[:, :-1],
                                           t_view)
        _, d_view, _ = self.draft.prefill(self.d_params, padded[:, :-1],
                                          d_view)
        self.t_cache = strip_view(t_view)
        self.d_cache = strip_view(d_view)
        if Mb > M:
            # hand the bucket-padding pages straight back to the pool
            for row in rows:
                self.t_pages.truncate(row, M - 1)
                self.d_pages.truncate(row, M - 1)

        # splice the new rows into the batched state
        n_grow = max(0, max(r + 1 for r in rows) - B) if rows else 0
        pending = np.concatenate([np.asarray(state.pending),
                                  np.zeros(n_grow, np.asarray(prompts).dtype)])
        tpos = np.concatenate([np.asarray(state.target_pos),
                               np.zeros(n_grow, np.int32)])
        dpos = np.concatenate([np.asarray(state.draft_pos),
                               np.zeros(n_grow, np.int32)])
        committed = list(state.committed) + [None] * n_grow
        pnp = np.asarray(prompts)
        for i, row in enumerate(rows):
            pending[row] = pnp[i, -1]
            tpos[row] = dpos[row] = M - 1
            committed[row] = list(pnp[i])
        new_state = StreamState(pending=jnp.asarray(pending),
                                target_pos=jnp.asarray(tpos, jnp.int32),
                                draft_pos=jnp.asarray(dpos, jnp.int32),
                                committed=committed)
        return new_state, rows

    def retire_stream(self, row: int) -> None:
        """Return every page of ``row`` to the pool and recycle the batch
        slot.  The row keeps riding batched forwards frozen (writes through
        its emptied page table are dropped) until a new stream reuses it."""
        if self.cache_kind != "paged":
            raise RuntimeError("contiguous engines cannot retire streams")
        if row in self._retired:
            return
        self.t_pages.free_stream(row)
        self.d_pages.free_stream(row)
        self._retired.add(row)
        self._free_rows.append(row)
        self._free_rows.sort()

    # ------------------------------------------------------------------
    # compiled-path warmup
    # ------------------------------------------------------------------

    def warmup(self, state: StreamState, buckets, vhat: int = 64):
        """Pre-compile the jitted round steps at the given (B, L) buckets.

        Call after ``start()``.  Each bucket runs one draft + verify +
        commit step with dummy inputs at EXACTLY the shapes/dtypes the real
        dispatch uses, so serving never pays a trace+compile mid-round
        (gateway cold starts measured ~minutes at real shapes in PR 1);
        with ``setup_compilation_cache`` installed the executables also
        persist across process restarts.  Returns ``(state, info)`` where
        ``info`` maps each bucket to its warmup seconds — callers MUST
        adopt the returned state: in ``jit+donate`` mode the commit warmup
        donates the state arrays (it is a no-op commit: every slot skipped,
        values unchanged).

        Paged engines warm against the REAL pools under an all--1 page
        table — window writes are dropped, so the donated pool comes back
        bit-identical and is adopted.  Contiguous engines allocate a
        throwaway zero cache per bucket (their forwards need the cache
        batch axis to match the bucket) and only make sense at the full
        batch size.  No-op in eager mode.
        """
        if self._steps.draft is None:
            return state, {}
        paged = self.cache_kind == "paged"
        info: dict[tuple[int, int], float] = {}
        key = jax.random.PRNGKey(0)
        for n, L in sorted({(int(n), int(L)) for n, L in buckets}):
            t0 = time.perf_counter()
            pending = jnp.zeros((n,), state.pending.dtype)
            pos = jnp.zeros((n,), jnp.int32)
            if paged:
                blank_pt = jax.device_put(
                    np.full((n, self.pages_per_stream), -1, np.int32))
                d_kv, t_kv = self.d_cache, self.t_cache
            else:
                blank_pt = None
                d_kv = self.draft.init_cache(n, self.max_len,
                                             self.cache_dtype)
                t_kv = self.target.init_cache(n, self.max_len,
                                              self.cache_dtype)
            dres = self._steps.draft(self.d_params, d_kv, blank_pt, pending,
                                     pos, key, L=L, vhat=vhat)
            if paged:
                self.d_cache = dres.cache
            # chain the draft outputs into verify: exactly the real
            # shapes/dtypes with zero bookkeeping
            dlen = jax.device_put(np.full(n, L, np.int32))
            vres, t_out = self._steps.verify(
                self.t_params, t_kv, blank_pt, pending, dres.tokens,
                dres.probs, dres.q_idx, dres.q_val, pos, dlen, key)
            if paged:
                self.t_cache = t_out
            rows = jax.device_put(np.full(n, -1, np.int32))
            skip = jax.device_put(np.ones(n, bool))
            pend, tpos, dpos, emission = self._steps.commit(
                state.pending, state.target_pos, state.draft_pos, rows,
                skip, vres.output_tokens, vres.accept_counts)
            state = StreamState(pending=pend, target_pos=tpos,
                                draft_pos=dpos, committed=state.committed)
            jax.block_until_ready((pend, emission))
            info[(n, L)] = time.perf_counter() - t0
        return state, info

    # ------------------------------------------------------------------

    def _paged_views(self, B: int):
        """Per-round cache views: pools + page tables for rows [0, B)."""
        rows = range(B)
        t = dict(self.t_cache, pages=self.t_pages.device_table(rows))
        d = dict(self.d_cache, pages=self.d_pages.device_table(rows))
        return t, d

    # ------------------------------------------------------------------
    # round steps (reusable on row subsets — the continuous engine's core)
    # ------------------------------------------------------------------

    def _ticket_rows(self, ticket: RoundTicket) -> list:
        return (list(range(len(ticket.freeze))) if ticket.rows is None
                else ticket.rows)

    def draft_rows(self, state: StreamState, rows, lengths, key,
                   vhat: int = 64, freeze=None,
                   pad_to: int = 0) -> RoundTicket:
        """Dispatch SLM drafting for a row subset; returns a ``RoundTicket``.

        ``rows=None`` drafts the full batch (the only mode contiguous
        caches support — their forwards cannot run on row subsets).  Paged
        engines accept any subset, including ``-1`` padding entries
        (all--1 page-table rows: cache writes dropped, outputs discarded
        at commit) so a continuous driver can bucket batch shapes.

        Page mappings of live rows are extended to cover the L+1
        verification window up front, ATOMICALLY: a pool-dry failure rolls
        every grown row back and re-raises ``PagePoolExhausted``, so the
        caller can hold the streams READY and retry once in-flight commits
        return pages.  ``pad_to`` forces the dispatched window length
        (length-bucket shaping); acceptance is still capped at the true
        per-row ``lengths`` by the verifier.

        Nothing here blocks on device results: the draft forwards are
        dispatched asynchronously and the ticket only references their
        output arrays."""
        if needs_state_rollback(self.draft_cfg):
            raise NotImplementedError(
                "SSM draft models need snapshot drafting; assigned pairs use "
                "attention SLMs (DESIGN.md §Arch-applicability)")
        paged = self.cache_kind == "paged"
        full = rows is None
        if not paged and not full:
            raise RuntimeError("contiguous caches run full-batch rounds "
                               "only; row subsets need cache_kind='paged'")
        row_list = (list(range(int(state.pending.shape[0]))) if full
                    else [int(r) for r in rows])
        n = len(row_list)
        lengths = np.asarray(lengths, dtype=np.int64)
        frz = (np.zeros(n, dtype=bool) if freeze is None
               else np.asarray(freeze, dtype=bool).copy())
        for i, b in enumerate(row_list):
            if b < 0 or b in self._retired:
                frz[i] = True
        L = max(int(lengths.max()), int(pad_to))

        d_pt = None
        if paged:
            # growth is clamped at the stream ceiling (window writes past
            # max_len drop — the contiguous slab's semantics) and atomic: a
            # pool-dry failure rolls every row back so the dispatch leaves
            # the mappings untouched.  Positions come from the host-side
            # committed lists (invariant: target_pos == draft_pos ==
            # len(committed) - 1 on every path), NOT from the device arrays
            # — growing the mapping costs zero device reads.
            cap = self.pages_per_stream * self.page_size
            grown: list[tuple[int, int, int]] = []
            with _span("engine.page_alloc", {"B": n, "L": L}):
                try:
                    for i, b in enumerate(row_list):
                        if frz[i]:
                            continue
                        pos_b = len(state.committed[b]) - 1
                        grown.append((b, self.t_pages.length(b),
                                      self.d_pages.length(b)))
                        self.t_pages.extend(b, min(pos_b + L + 1, cap))
                        self.d_pages.extend(b, min(pos_b + L + 1, cap))
                except PagePoolExhausted:
                    for b, t_len, d_len in grown:
                        self.t_pages.truncate(b, t_len)
                        self.d_pages.truncate(b, d_len)
                    raise
            d_pt = self.d_pages.device_table(row_list)

        if full:
            pending, dpos, tpos = (state.pending, state.draft_pos,
                                   state.target_pos)
        else:
            idx = jax.device_put(
                np.asarray([max(b, 0) for b in row_list], np.int32))
            live = jax.device_put(np.asarray([b >= 0 for b in row_list]))
            # the zero fill is device_put EXPLICITLY: a python-scalar 0 (or
            # jnp.zeros, which embeds one) is an implicit h2d transfer and
            # trips jax.transfer_guard("disallow") on the dispatch path
            z = jax.device_put(np.zeros((), state.pending.dtype))
            zi = jax.device_put(np.zeros((), np.int32))
            pending = jnp.where(live, jnp.take(state.pending, idx), z)
            dpos = jnp.where(live, jnp.take(state.draft_pos, idx), zi)
            tpos = jnp.where(live, jnp.take(state.target_pos, idx), zi)

        # --- step 2: distributed drafting (SLM) ---
        with _span("engine.draft", {"B": n, "L": L}) as sp:
            if self._steps.draft is not None:
                # compiled path: ONE jitted call per (n, L) bucket; the
                # draft KV pytree is passed (and in jit+donate mode donated)
                # as an argument, the page table rides un-donated
                draft_res = self._steps.draft(self.d_params, self.d_cache,
                                              d_pt, pending, dpos, key,
                                              L=L, vhat=vhat)
                self.d_cache = draft_res.cache
                # the adopted cache must never be re-read through the
                # ticket: the NEXT draft call donates it
                draft_res = dataclasses.replace(draft_res, cache=None)
            else:
                d_cache = (dict(self.d_cache, pages=d_pt) if paged
                           else self.d_cache)
                draft_res = generate_drafts(self.draft, self.d_params,
                                            d_cache, pending, dpos, L, key,
                                            vhat=vhat)
                self.d_cache = strip_view(draft_res.cache)
            sp.attach(draft_res.tokens)
        return RoundTicket(rows=None if full else row_list, lengths=lengths,
                           L=L, freeze=frz, pending=pending, target_pos=tpos,
                           draft=draft_res)

    def verify_rows(self, ticket: RoundTicket, key) -> RoundTicket:
        """Dispatch the batched target pass + exact accept/reject for a
        drafted ticket.  Asynchronous like ``draft_rows``: the returned
        ticket's ``res`` arrays are in flight; ``commit_rows`` is the only
        host sync point, so drafting for other streams can be dispatched
        while this verification runs on device."""
        paged = self.cache_kind == "paged"
        row_list = self._ticket_rows(ticket)
        n = len(row_list)
        draft_res = ticket.draft
        t_pt = self.t_pages.device_table(row_list) if paged else None
        draft_len = jax.device_put(np.asarray(ticket.lengths, np.int32))

        if self._steps.verify is not None:
            # compiled path: the target pass AND the accept/reject run in
            # one jitted call (per (n, L) bucket); the target KV pytree is
            # donated in jit+donate mode, the page table rides un-donated
            with _span("engine.target_pass",
                       {"B": n, "W": ticket.L + 1}) as sp:
                res, t_kv = self._steps.verify(
                    self.t_params, self.t_cache, t_pt, ticket.pending,
                    draft_res.tokens, draft_res.probs, draft_res.q_idx,
                    draft_res.q_val, ticket.target_pos, draft_len, key)
                self.t_cache = t_kv
                sp.attach(res.accept_counts)
            ticket.res = res
            return ticket

        t_cache = dict(self.t_cache, pages=t_pt) if paged else self.t_cache

        # --- step 4: batched verification (LLM) ---
        window = jnp.concatenate([ticket.pending[:, None], draft_res.tokens],
                                 axis=1)                       # (n, L+1)
        with _span("engine.target_pass", {"B": n, "W": ticket.L + 1}) as sp:
            if needs_state_rollback(self.target_cfg):
                logits, t_cache, snaps = self.target.forward_window(
                    self.t_params, window, t_cache, ticket.target_pos,
                    return_snapshots=True)
            else:
                logits, t_cache = self.target.forward_window(
                    self.t_params, window, t_cache, ticket.target_pos)
                snaps = None
            sp.attach(logits)

        with _span("engine.verify_tokens", {"B": n, "L": ticket.L}) as sp:
            res = verify_drafts(key, draft_res.tokens, draft_res.probs,
                                logits, q_idx=draft_res.q_idx,
                                q_val=draft_res.q_val, draft_len=draft_len)
            sp.attach(res.accept_counts)

        # target cache: row i processed [pending, d_1..d_n]; snapshot index
        # n (0-based: snapshot t is the state after feeding window[:, :t+1])
        if snaps is not None:
            sel = select_snapshots(snaps, res.accept_counts,
                                   self.target.CACHE_BATCH_AXES)
            t_cache = merge_snapshot_into_cache(t_cache, sel)
        self.t_cache = strip_view(t_cache)
        ticket.res = res
        return ticket

    def commit_rows(self, state: StreamState, ticket: RoundTicket,
                    skip=None):
        """Land a verified ticket — THE host sync point of a round.

        The state arrays stay device-resident: a (jitted) ``commit_step``
        scatter-updates ONLY the ticket's rows of pending/target_pos/
        draft_pos on device (in ``jit+donate`` mode the old buffers are
        donated into the new ones), and the single blocking fetch of the
        round is the packed ``(n, L+2)`` emission — ``[advance, tokens...]``
        per slot — that extends the host-side committed lists and drives
        the page-pool truncation.  ``skip`` (aligned with the ticket's
        rows) marks members that must NOT commit — streams retired while
        the batch was in flight; rows retired through the engine and ``-1``
        padding rows are skipped automatically, so a mid-verify disconnect
        never corrupts the rest of the batch.  Returns ``(new_state,
        accepted)``: accepted counts incl. the bonus token, 0 for
        skipped/frozen rows, aligned with the ticket."""
        paged = self.cache_kind == "paged"
        row_list = self._ticket_rows(ticket)
        n = len(row_list)
        res = ticket.res
        skip_np = (np.zeros(n, dtype=bool) if skip is None
                   else np.asarray(skip, dtype=bool).copy())
        skip_np |= ticket.freeze
        for i, b in enumerate(row_list):
            if b < 0 or b in self._retired:
                skip_np[i] = True
        with _span("engine.commit", {"B": n}):
            rows_dev = jax.device_put(np.asarray(row_list, np.int32))
            skip_dev = jax.device_put(skip_np)
            pend, tpos, dpos, emission = self._steps.commit(
                state.pending, state.target_pos, state.draft_pos, rows_dev,
                skip_dev, res.output_tokens, res.accept_counts)
            pack = self._host_fetch(emission)    # the ONE host sync
            accepted = pack[:, 0].astype(np.int64)
            with _span("engine.page_free", {"B": n}):
                for i, b in enumerate(row_list):
                    adv = int(pack[i, 0])
                    if adv == 0:
                        continue
                    state.committed[b].extend(pack[i, 1:1 + adv].tolist())
                    if paged:
                        # speculative rejection hands pages straight back
                        new_len = len(state.committed[b]) - 1
                        self.t_pages.truncate(b, new_len)
                        self.d_pages.truncate(b, new_len)
        self.last_accepted = accepted if ticket.rows is None else None
        new_state = StreamState(pending=pend, target_pos=tpos,
                                draft_pos=dpos, committed=state.committed)
        return new_state, accepted

    def spin_round(self, state: StreamState, lengths: np.ndarray,
                   key: jax.Array, vhat: int = 64,
                   freeze: np.ndarray | None = None, draft_width: int = 1,
                   tree: bool | None = None):
        """One Multi-SPIN round with per-stream draft lengths (zero-padded to
        the max).  Returns (state, VerifyResult, draft_result).

        ``draft_width`` J > 1 runs the TOKEN-TREE round instead (the
        ``multidraft`` scheme): J drafts per stream packed into a
        prefix-deduplicated tree, scored in ONE ancestor-masked target pass,
        with the longest accepted root-to-leaf path committed
        (``_spin_round_tree``).  ``tree=True`` forces the tree machinery at
        J = 1 — it commits bit-identical tokens to the sequential path
        (equivalence-tested), so this is only useful for testing.

        ``freeze`` marks streams that must NOT advance this round (retired
        requests, or the off half of a pipelined schedule).  Frozen rows
        still ride through the batched forwards (the reference engine cannot
        skip batch rows) but commit nothing: positions, pending token, and
        committed text are untouched.  For attention targets/drafts the
        cache is pointer-indexed, so the stale window writes are overwritten
        on the row's next live round; SSM targets would need a pre-window
        state restore and are rejected.  Paged engines additionally freeze
        retired rows and grow/shrink page mappings around the round: live
        rows extend to cover the L+1 window up front and hand back every
        page past the accepted prefix afterwards.
        """
        if tree is None:
            tree = draft_width > 1
        if tree:
            return self._spin_round_tree(state, lengths, key, vhat=vhat,
                                         freeze=freeze, J=int(draft_width))
        B = state.pending.shape[0]
        frz_np = (np.zeros(B, dtype=bool) if freeze is None
                  else np.asarray(freeze, dtype=bool).copy())
        if self._retired:
            frz_np[list(self._retired)] = True
        if frz_np.any() and needs_state_rollback(self.target_cfg):
            raise NotImplementedError(
                "freezing streams of an SSM/hybrid target needs a pre-window "
                "state snapshot (see ROADMAP open items)")
        k_draft, k_verify = jax.random.split(key)
        # the lockstep round IS the three continuous steps on the full batch
        # (same dispatch shapes and key discipline -> bit-identical tokens)
        ticket = self.draft_rows(state, None, lengths, k_draft, vhat=vhat,
                                 freeze=frz_np)
        ticket = self.verify_rows(ticket, k_verify)
        new_state, _ = self.commit_rows(state, ticket)
        return new_state, ticket.res, ticket.draft

    # ------------------------------------------------------------------
    # token-tree multi-draft round (SpecInfer-style verification)
    # ------------------------------------------------------------------

    def _spin_round_tree(self, state: StreamState, lengths: np.ndarray,
                         key: jax.Array, vhat: int,
                         freeze: np.ndarray | None, J: int):
        """One multi-draft round: J drafts per stream, packed into a
        prefix-deduplicated token tree, scored in ONE ancestor-masked target
        pass, longest accepted root-to-leaf path committed.

        Cache discipline: the W+1 tree window (W = J * L) occupies target
        SLOTS [pos, pos + W] in construction order while each node keeps its
        tree DEPTH as rope position; after acceptance the winning branch's
        K/V are SCATTERED from their tree-window slots (target) and the
        winning run's window snapshot (draft) into the committed slots —
        ``tree_commit="repair"`` instead re-forwards [pending, accepted
        path] through both models (the pre-scatter reference path; committed
        tokens are identical either way, they are decided before the cache
        fix-up).  Paged engines hand every page past the accepted prefix
        (all dead branches) back to the pool.  At J = 1 the tree is a
        chain, the window IS the sequential window, and no fix-up runs:
        tokens and caches are bit-identical to ``spin_round``.
        """
        for role, cfg in (("target", self.target_cfg),
                          ("draft", self.draft_cfg)):
            # DecoderLM families only: the ancestor-masked window needs
            # pointer-rollback attention caches AND the forward_window
            # (window_mask=, window_depth=) signature — SSM/hybrid state
            # cannot be pointer-rolled, enc-dec lacks the masked window
            if cfg.family not in ("dense", "moe", "vlm"):
                raise NotImplementedError(
                    f"tree verification needs an attention decoder "
                    f"({role} family {cfg.family!r}): divergent branches "
                    f"commit by pointer rollback and one ancestor-masked "
                    f"window pass (see ROADMAP open items)")
        B = state.pending.shape[0]
        lengths = np.asarray(lengths, dtype=np.int64)
        frz_np = (np.zeros(B, dtype=bool) if freeze is None
                  else np.asarray(freeze, dtype=bool).copy())
        if self._retired:
            frz_np[list(self._retired)] = True
        L = int(lengths.max())
        W = J * L
        k_draft, k_verify = jax.random.split(key)

        paged = self.cache_kind == "paged"
        if paged:
            # the TARGET maps the whole W+1 tree window up front; the draft
            # side only ever holds one run (L+1) — repair fits under both.
            # Positions come from the host-side committed lists (target_pos
            # == draft_pos == len(committed) - 1), zero device reads.
            cap = self.pages_per_stream * self.page_size
            grown: list[tuple[int, int, int]] = []
            with _span("engine.page_alloc", {"B": B, "W": W}):
                try:
                    for b in range(B):
                        if frz_np[b]:
                            continue
                        pos_b = len(state.committed[b]) - 1
                        grown.append((b, self.t_pages.length(b),
                                      self.d_pages.length(b)))
                        self.t_pages.extend(b, min(pos_b + W + 1, cap))
                        self.d_pages.extend(b, min(pos_b + L + 1, cap))
                except PagePoolExhausted:
                    for b, t_len, d_len in grown:
                        self.t_pages.truncate(b, t_len)
                        self.d_pages.truncate(b, d_len)
                    raise
            t_cache, d_cache = self._paged_views(B)
        else:
            t_cache, d_cache = self.t_cache, self.d_cache

        # --- step 2: J drafting runs per stream (SLM) ---
        scatter = self.tree_commit == "scatter" and J > 1
        with _span("engine.draft_forest", {"B": B, "L": L, "J": J}) as sp:
            forest = generate_draft_forest(self.draft, self.d_params, d_cache,
                                           state.pending, state.draft_pos,
                                           L, J, k_draft, vhat=vhat,
                                           keep_windows=scatter)
            sp.attach(forest.tokens)
        d_cache = forest.cache

        # --- pack into the prefix-deduplicated tree (host-side) ---
        with _span("engine.tree_build", {"B": B, "L": L, "J": J}):
            # ONE batched fetch for everything the host-side trie build
            # needs, into (J, L)-bucketed scratch buffers reused across
            # rounds instead of 8 fresh allocations per call
            tok_np, p_np, qi_np, qv_np, pend_np = self._host_fetch(
                (forest.tokens, forest.probs, forest.q_idx, forest.q_val,
                 state.pending))
            ttree = build_token_tree(tok_np, p_np, qi_np, qv_np, lengths,
                                     scratch=self._tree_scratch)
            window = jax.device_put(
                ttree.window_tokens(pend_np).astype(np.int32))  # (B, W+1)
            wmask = jax.device_put(ttree.window_mask())
            wdepth = jax.device_put(ttree.window_depth().astype(np.int32))

        # --- step 4: ONE ancestor-masked target pass over the whole tree ---
        with _span("engine.target_pass", {"B": B, "W": W + 1, "J": J}) as sp:
            logits, t_cache = self.target.forward_window(
                self.t_params, window, t_cache, state.target_pos,
                window_mask=wmask, window_depth=wdepth)
            sp.attach(logits)

        with _span("engine.verify_tokens", {"B": B, "L": L, "J": J}) as sp:
            res = verify_tree(k_verify, jnp.asarray(ttree.tokens),
                              jnp.asarray(ttree.parents),
                              jnp.asarray(ttree.depth),
                              jnp.asarray(ttree.probs),
                              jnp.asarray(ttree.paths), logits,
                              jnp.asarray(ttree.q_idx),
                              jnp.asarray(ttree.q_val),
                              jnp.asarray(lengths, jnp.int32))
            sp.attach(res.accept_counts)

        # --- step 5a: land the accepted path's K/V (a J=1 chain already IS
        # the sequential window: nothing to move)
        frz = jnp.asarray(frz_np)
        if scatter:
            # scatter-commit: the ancestor-masked target pass ALREADY
            # computed the accepted path's K/V (each tree node conditions on
            # exactly its root-to-node path), so move the winning branch's
            # rows from their tree-window slots into the committed slots —
            # no repair forward, and no host sync on accept_counts
            with _span("engine.kv_commit", {"B": B, "L": L, "J": J}) as sp:
                path_w = jnp.take_along_axis(
                    jnp.asarray(ttree.paths), res.winner[:, None, None],
                    axis=1)[:, 0]                              # (B, L)
                keep = ((jnp.arange(L)[None, :] < res.accept_counts[:, None])
                        & (path_w >= 0) & ~frz[:, None])
                col = jnp.arange(L, dtype=jnp.int32)[None, :]
                src_t = state.target_pos[:, None] + 1 + jnp.maximum(path_w, 0)
                dst_t = state.target_pos[:, None] + 1 + col
                dst_d = state.draft_pos[:, None] + 1 + col
                t_pt = t_cache.get("pages")
                d_pt = d_cache.get("pages")
                for leaf in ("k", "v", "dense_k", "dense_v"):
                    if leaf in forest.windows:
                        vals = gather_kv_window(t_cache[leaf], src_t,
                                                page_table=t_pt)
                        t_cache[leaf] = scatter_kv_window(
                            t_cache[leaf], vals, dst_t, keep, page_table=t_pt)
                        win = jnp.take_along_axis(
                            forest.windows[leaf],
                            res.winner[None, :, None, None, None, None],
                            axis=2)[:, :, 0]                   # (Ln,B,L,KV,D)
                        d_cache[leaf] = scatter_kv_window(
                            d_cache[leaf], win, dst_d, keep, page_table=d_pt)
                sp.attach(t_cache["k"])
        elif J > 1:
            # repair forward (kept as the reference path, and for targets
            # whose window pass cannot donate K/V): one plain causal window
            # over [pending, accepted path] rewrites the surviving slots
            n_max = int(self._host_fetch(res.accept_counts).max())
            repair = jnp.concatenate(
                [state.pending[:, None], res.output_tokens[:, :n_max]],
                axis=1)                                        # (B, n_max+1)
            with _span("engine.cache_repair", {"B": B, "n": n_max + 1}) as sp:
                _, t_cache = self.target.forward_window(
                    self.t_params, repair, t_cache, state.target_pos)
                _, d_cache = self.draft.forward_window(
                    self.d_params, repair, d_cache, state.draft_pos)
                sp.attach(t_cache)
        self.t_cache = strip_view(t_cache)
        self.d_cache = strip_view(d_cache)

        # --- step 5b: commit + rollback (identical to the sequential round)
        adv = jnp.where(frz, 0, 1 + res.accept_counts)
        new_target_pos = state.target_pos + adv
        new_draft_pos = state.draft_pos + adv
        sampled = jnp.take_along_axis(
            res.output_tokens, res.accept_counts[:, None], axis=1)[:, 0]
        new_pending = jnp.where(frz, state.pending, sampled)

        # one batched fetch lands the commit on the host (tokens + counts;
        # positions for the page truncation ride along for free)
        out_np, n_np, ntp, ndp = self._host_fetch(
            (res.output_tokens, res.accept_counts, new_target_pos,
             new_draft_pos))
        self.last_accepted = np.where(frz_np, 0, n_np + 1).astype(np.int64)
        for b in range(B):
            if not frz_np[b]:
                state.committed[b].extend(out_np[b, :n_np[b] + 1].tolist())

        if paged:
            # every page past the accepted prefix — all dead branches of the
            # tree — returns to the pool here
            with _span("engine.page_free", {"B": B}):
                for b in range(B):
                    if not frz_np[b]:
                        self.t_pages.truncate(b, int(ntp[b]))
                        self.d_pages.truncate(b, int(ndp[b]))

        new_state = StreamState(pending=new_pending, target_pos=new_target_pos,
                                draft_pos=new_draft_pos,
                                committed=state.committed)
        return new_state, res, forest
