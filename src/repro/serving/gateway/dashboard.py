"""Minimal KPI dashboard for the Multi-SPIN gateway (one static page).

``GET /`` serves this page; it polls ``GET /v1/stats`` once a second and
renders the four serving KPIs the ROADMAP follow-up asked for — goodput
(both views), draft acceptance, page-pool occupancy, and a TTFT p50
sparkline over the poll history — with zero build step, zero external
assets, and zero new endpoints (everything it shows already rides on
``/v1/stats``).

The page follows the repo's dataviz conventions: stat tiles for single
headline numbers (a number's job is not a chart), one 2px single-hue
sparkline with a nearest-point hover tooltip (single series — no legend;
the title names it), text in text tokens rather than series colors, and a
collapsible table of the raw samples as the accessible fallback.  Light and
dark are both first-class via CSS custom properties.
"""

from __future__ import annotations

DASHBOARD_HTML = """<!DOCTYPE html>
<html lang="en">
<head>
<meta charset="utf-8">
<meta name="viewport" content="width=device-width, initial-scale=1">
<title>Multi-SPIN gateway</title>
<style>
  .viz-root {
    color-scheme: light;
    --surface-1: #fcfcfb; --surface-2: #f1f0ee;
    --text-primary: #0b0b0b; --text-secondary: #52514e;
    --grid: #d8d7d3; --series-1: #2a78d6;
    --warn: #eda100; --critical: #e34948;
  }
  @media (prefers-color-scheme: dark) {
    :root:where(:not([data-theme="light"])) .viz-root {
      color-scheme: dark;
      --surface-1: #1a1a19; --surface-2: #242423;
      --text-primary: #ffffff; --text-secondary: #c3c2b7;
      --grid: #3a3a38; --series-1: #3987e5;
      --warn: #c98500; --critical: #e66767;
    }
  }
  :root[data-theme="dark"] .viz-root {
    color-scheme: dark;
    --surface-1: #1a1a19; --surface-2: #242423;
    --text-primary: #ffffff; --text-secondary: #c3c2b7;
    --grid: #3a3a38; --series-1: #3987e5;
    --warn: #c98500; --critical: #e66767;
  }
  body { margin: 0; }
  .viz-root {
    font: 14px/1.45 system-ui, sans-serif;
    background: var(--surface-1); color: var(--text-primary);
    min-height: 100vh; padding: 24px;
  }
  h1 { font-size: 16px; font-weight: 600; margin: 0 0 2px; }
  .sub { color: var(--text-secondary); margin: 0 0 20px; font-size: 12px; }
  .tiles { display: grid; gap: 12px;
           grid-template-columns: repeat(auto-fit, minmax(170px, 1fr)); }
  .tile { background: var(--surface-2); border-radius: 8px; padding: 12px 14px; }
  .tile .label { color: var(--text-secondary); font-size: 12px; }
  .tile .value { font-size: 26px; font-weight: 600; font-variant-numeric: tabular-nums; }
  .tile .unit { font-size: 13px; color: var(--text-secondary); font-weight: 400; }
  .tile .detail { color: var(--text-secondary); font-size: 12px; }
  .meter { height: 4px; border-radius: 2px; background: var(--grid);
           margin-top: 8px; overflow: hidden; }
  .meter > div { height: 100%; border-radius: 2px; background: var(--series-1);
                 width: 0%; transition: width .3s; }
  .panel { margin-top: 20px; background: var(--surface-2);
           border-radius: 8px; padding: 12px 14px; }
  .panel h2 { font-size: 13px; font-weight: 600; margin: 0; }
  .panel .sub { margin: 0 0 8px; }
  svg text { fill: var(--text-secondary); font-size: 11px;
             font-variant-numeric: tabular-nums; }
  #tip { position: fixed; pointer-events: none; display: none;
         background: var(--surface-1); color: var(--text-primary);
         border: 1px solid var(--grid); border-radius: 6px;
         padding: 4px 8px; font-size: 12px; }
  details { margin-top: 16px; color: var(--text-secondary); font-size: 12px; }
  table { border-collapse: collapse; margin-top: 8px; }
  td, th { padding: 2px 10px 2px 0; text-align: right;
           font-variant-numeric: tabular-nums; }
  th { color: var(--text-secondary); font-weight: 500; }
  .err { color: var(--critical); }
</style>
</head>
<body>
<div class="viz-root">
  <h1>Multi-SPIN gateway</h1>
  <p class="sub" id="meta">connecting&#8230;</p>
  <div class="tiles">
    <div class="tile"><div class="label">Goodput (committed)</div>
      <div class="value" id="k-good">&#8211;<span class="unit"> tok/s</span></div>
      <div class="detail" id="k-good2">capped &#8211;</div></div>
    <div class="tile"><div class="label">Acceptance (window)</div>
      <div class="value" id="k-acc">&#8211;<span class="unit"> %</span></div>
      <div class="detail" id="k-acc2">total &#8211;</div></div>
    <div class="tile"><div class="label">Page-pool occupancy</div>
      <div class="value" id="k-pool">&#8211;<span class="unit"> %</span></div>
      <div class="meter"><div id="k-pool-bar"></div></div></div>
    <div class="tile"><div class="label">Streams</div>
      <div class="value" id="k-streams">&#8211;</div>
      <div class="detail" id="k-streams2">queued &#8211; &#183; done &#8211;</div></div>
  </div>
  <div class="panel">
    <h2>TTFT p50 (simulated seconds)</h2>
    <p class="sub">last <span id="spark-n">0</span> polls &#183; 1 Hz</p>
    <svg id="spark" width="100%" height="72" viewBox="0 0 600 72"
         preserveAspectRatio="none" role="img"
         aria-label="TTFT p50 sparkline"></svg>
  </div>
  <details><summary>Samples (table view)</summary>
    <table id="tbl"><thead><tr><th>t</th><th>ttft p50 s</th>
      <th>goodput tok/s</th><th>accept %</th></tr></thead>
      <tbody></tbody></table>
  </details>
  <div id="tip"></div>
</div>
<script>
"use strict";
const S = [];                       // poll samples, bounded
const MAXN = 120;
const $ = id => document.getElementById(id);
const fmt = (x, d=1) => (x == null || !isFinite(x)) ? "\\u2013"
  : Number(x).toFixed(d);

function draw() {
  const svg = $("spark"), W = 600, H = 72, P = 4;
  const pts = S.map(s => s.ttft).filter(v => v != null);
  $("spark-n").textContent = S.length;
  if (pts.length < 2) { svg.innerHTML = ""; return; }
  const vals = S.map(s => s.ttft ?? 0);
  const lo = Math.min(...pts), hi = Math.max(...pts), span = (hi - lo) || 1;
  const x = i => P + i * (W - 2 * P) / (S.length - 1);
  const y = v => H - P - (v - lo) * (H - 2 * P) / span;
  const d = vals.map((v, i) => (i ? "L" : "M") + x(i).toFixed(1)
                               + " " + y(v).toFixed(1)).join(" ");
  const last = vals[vals.length - 1];
  svg.innerHTML =
    `<line x1="0" y1="${y(lo)}" x2="${W}" y2="${y(lo)}"` +
    ` stroke="var(--grid)" stroke-width="1"/>` +
    `<path d="${d}" fill="none" stroke="var(--series-1)"` +
    ` stroke-width="2" vector-effect="non-scaling-stroke"/>` +
    `<circle cx="${x(S.length - 1)}" cy="${y(last)}" r="3"` +
    ` fill="var(--series-1)" stroke="var(--surface-2)" stroke-width="2"/>` +
    `<text x="${W - P}" y="12" text-anchor="end">${fmt(last, 3)}s</text>`;
}

$("spark").addEventListener("mousemove", ev => {
  if (S.length < 2) return;
  const r = ev.currentTarget.getBoundingClientRect();
  const i = Math.max(0, Math.min(S.length - 1,
    Math.round((ev.clientX - r.left) / r.width * (S.length - 1))));
  const s = S[i], tip = $("tip");
  tip.style.display = "block";
  tip.style.left = (ev.clientX + 12) + "px";
  tip.style.top = (ev.clientY - 10) + "px";
  tip.textContent = `poll ${i - S.length + 1}: ttft ${fmt(s.ttft, 3)}s, ` +
                    `goodput ${fmt(s.good)} tok/s`;
});
$("spark").addEventListener("mouseleave",
  () => { $("tip").style.display = "none"; });

function table() {
  const tb = $("tbl").tBodies[0];
  tb.innerHTML = S.slice(-12).map((s, i) =>
    `<tr><td>${i - Math.min(S.length, 12) + 1}</td>` +
    `<td>${fmt(s.ttft, 3)}</td><td>${fmt(s.good)}</td>` +
    `<td>${fmt(s.acc * 100)}</td></tr>`).join("");
}

async function poll() {
  try {
    const st = await (await fetch("/v1/stats")).json();
    const last = st.last_round || {};
    const occ = last.pool_occupancy ?? 0;
    const good = last.goodput_committed ?? 0;
    $("meta").textContent =
      `rounds ${st.rounds_total} \\u00b7 tokens ` +
      `${st.tokens_committed_total} \\u00b7 sim ` +
      `${fmt(st.sim_seconds_total, 1)}s`;
    $("k-good").innerHTML =
      `${fmt(good)}<span class="unit"> tok/s</span>`;
    $("k-good2").textContent = `capped ${fmt(last.goodput_capped)}`;
    $("k-acc").innerHTML =
      `${fmt((st.acceptance_window ?? 0) * 100)}<span class="unit"> %</span>`;
    $("k-acc2").textContent =
      `total ${fmt((st.acceptance_total ?? 0) * 100)} %`;
    $("k-pool").innerHTML =
      `${fmt(occ * 100)}<span class="unit"> %</span>`;
    const bar = $("k-pool-bar");
    bar.style.width = `${Math.min(100, occ * 100)}%`;
    bar.style.background = occ > 0.95 ? "var(--critical)"
      : occ > 0.8 ? "var(--warn)" : "var(--series-1)";
    const sch = st.scheduler || {};
    $("k-streams").textContent = sch.active ?? 0;
    $("k-streams2").textContent =
      `queued ${sch.queue_depth ?? 0} \\u00b7 done ${sch.completed ?? 0}`;
    S.push({ttft: st.ttft_sim_s ? st.ttft_sim_s.p50 : null,
            good: good, acc: st.acceptance_window ?? 0});
    if (S.length > MAXN) S.shift();
    draw(); table();
  } catch (e) {
    $("meta").innerHTML = `<span class="err">stats poll failed: ${e}</span>`;
  }
}
poll();
setInterval(poll, 1000);
</script>
</body>
</html>
"""
