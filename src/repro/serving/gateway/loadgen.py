"""Concurrent-client load generator for the Multi-SPIN gateway.

Two loop disciplines, selected by ``LoadGenConfig.mode``:

* ``"open"`` — arrivals are INDEPENDENT of service: request k is fired at
  the k-th point of a Poisson process regardless of how many earlier
  requests are still in flight, so queueing delay shows up in the measured
  TTFT/latency instead of being hidden by a feedback loop.  The right model
  for externally driven traffic (rate sweeps, overload probing).
* ``"closed"`` — ``n_clients`` PERSISTENT clients each hold one SSE session
  at a time: finish a request, think for ``think_time_s`` (exponential,
  like classic closed-loop generators), fire the next, until the shared
  budget of ``n_requests`` is spent.  Concurrency is pinned at
  ``n_clients`` by construction — the steady-state regime the
  continuous-batching engine overlaps rounds under, and the harness
  ``bench_continuous`` drives (real concurrent clients replacing the old
  one-shot burst).

Per request we draw a prompt length and a token budget from configured
choice sets, tag an optional deadline, and drive one SSE session through
``GatewayClient``.  Reported: per-request TTFT (send -> first round event,
REAL wall seconds) and end-to-end latency percentiles, sum goodput
(streamed tokens / wall), deadline hit counts, and error counts
(ROADMAP items 2-3; WISP motivates the per-stream SLO view).

Stdlib only (asyncio + random).
"""

from __future__ import annotations

import asyncio
import dataclasses
import random
import time

from repro.serving.gateway.client import GatewayClient


def percentile(xs, q: float) -> float:
    """Linear-interpolation percentile (numpy's default method) over an
    already-or-not sorted sequence; pure python so the gateway stack stays
    stdlib-only.  ``q`` in [0, 100]."""
    if not xs:
        raise ValueError("percentile of empty sequence")
    s = sorted(xs)
    if len(s) == 1:
        return float(s[0])
    rank = (q / 100.0) * (len(s) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    frac = rank - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


def summarize(xs) -> dict:
    """{p50, p90, p95, p99, mean, max, n} of a latency sample (empty-safe)."""
    if not xs:
        return {"p50": 0.0, "p90": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0,
                "max": 0.0, "n": 0}
    return {
        "p50": percentile(xs, 50), "p90": percentile(xs, 90),
        "p95": percentile(xs, 95), "p99": percentile(xs, 99),
        "mean": sum(xs) / len(xs),
        "max": float(max(xs)), "n": len(xs),
    }


@dataclasses.dataclass
class LoadGenConfig:
    mode: str = "open"                      # "open" | "closed"
    rate_per_s: float = 8.0                 # open: Poisson arrival rate
    n_clients: int = 4                      # closed: persistent SSE clients
    # closed: mean exponential think time between one client's requests
    # (0 = back-to-back)
    think_time_s: float = 0.0
    n_requests: int = 16                    # total budget, both modes
    prompt_len_choices: tuple = (8, 12, 16)
    max_new_tokens_choices: tuple = (8, 16, 32)
    alpha_choices: tuple = (0.71, 0.74, 0.86)
    T_S: float = 0.009
    T_S_jitter: tuple = (0.85, 1.15)        # uniform factor on T_S
    deadline_s: float | None = None         # per-request SLO tag (real wall)
    timeout_s: float = 120.0                # per-request hard abort
    seed: int = 0


@dataclasses.dataclass
class RequestRecord:
    idx: int
    rid: int | None
    arrival_s: float                        # scheduled arrival offset
    ttft_s: float | None
    latency_s: float | None
    tokens: int
    rounds: int
    deadline_s: float | None
    deadline_met: bool | None
    error: str | None


async def _one_request(client: GatewayClient, cfg: LoadGenConfig,
                       rng: random.Random, idx: int,
                       arrival_s: float) -> RequestRecord:
    fields = dict(
        prompt_len=rng.choice(cfg.prompt_len_choices),
        max_new_tokens=rng.choice(cfg.max_new_tokens_choices),
        alpha=rng.choice(cfg.alpha_choices),
        T_S=cfg.T_S * rng.uniform(*cfg.T_S_jitter),
        tag=f"loadgen-{idx}",
    )
    rec = RequestRecord(idx=idx, rid=None, arrival_s=arrival_s, ttft_s=None,
                        latency_s=None, tokens=0, rounds=0,
                        deadline_s=cfg.deadline_s, deadline_met=None,
                        error=None)
    try:
        res = await asyncio.wait_for(client.generate(**fields),
                                     timeout=cfg.timeout_s)
    except asyncio.TimeoutError:
        rec.error = "timeout"
        return rec
    except (OSError, ConnectionError) as e:
        rec.error = f"{type(e).__name__}: {e}"
        return rec
    rec.rid = res.rid
    rec.ttft_s = res.ttft_s
    rec.latency_s = res.latency_s
    rec.tokens = len(res.tokens)
    rec.rounds = res.n_rounds
    rec.error = res.error
    if cfg.deadline_s is not None and res.latency_s is not None:
        rec.deadline_met = res.latency_s <= cfg.deadline_s
    return rec


async def _run_open(client: GatewayClient, cfg: LoadGenConfig,
                    rng: random.Random, t0: float) -> list[RequestRecord]:
    # draw ALL arrival offsets up front (open loop: the schedule does not
    # depend on service times)
    arrivals, t = [], 0.0
    for _ in range(cfg.n_requests):
        t += rng.expovariate(cfg.rate_per_s)
        arrivals.append(t)

    async def fire(idx, arrival):
        await asyncio.sleep(max(0.0, arrival - (time.monotonic() - t0)))
        per_req_rng = random.Random(cfg.seed * 100003 + idx)
        return await _one_request(client, cfg, per_req_rng, idx, arrival)

    return list(await asyncio.gather(
        *(fire(i, a) for i, a in enumerate(arrivals))))


async def _run_closed(client: GatewayClient, cfg: LoadGenConfig,
                      rng: random.Random, t0: float) -> list[RequestRecord]:
    # n_clients persistent workers share one request counter: each holds at
    # most one SSE session, thinks, then takes the next index — fixed
    # concurrency, service-dependent arrivals (the closed-loop discipline)
    counter = {"next": 0}
    records: list[RequestRecord] = []

    async def worker(c: int):
        think_rng = random.Random(cfg.seed * 7919 + c)
        while True:
            idx = counter["next"]
            if idx >= cfg.n_requests:
                return
            counter["next"] = idx + 1
            per_req_rng = random.Random(cfg.seed * 100003 + idx)
            records.append(await _one_request(
                client, cfg, per_req_rng, idx,
                arrival_s=time.monotonic() - t0))
            if cfg.think_time_s > 0 and counter["next"] < cfg.n_requests:
                await asyncio.sleep(
                    think_rng.expovariate(1.0 / cfg.think_time_s))

    await asyncio.gather(*(worker(c)
                           for c in range(max(1, cfg.n_clients))))
    records.sort(key=lambda r: r.idx)
    return records


async def run_loadgen(host: str, port: int,
                      cfg: LoadGenConfig | None = None) -> dict:
    """Drive the configured load at a live gateway; returns the report."""
    cfg = cfg or LoadGenConfig()
    if cfg.mode not in ("open", "closed"):
        raise ValueError(f"mode must be 'open' or 'closed', got {cfg.mode!r}")
    rng = random.Random(cfg.seed)
    client = GatewayClient(host, port)
    t0 = time.monotonic()
    if cfg.mode == "closed":
        records = await _run_closed(client, cfg, rng, t0)
    else:
        records = await _run_open(client, cfg, rng, t0)
    wall = time.monotonic() - t0

    ok = [r for r in records if r.error is None]
    report = {
        "mode": cfg.mode,
        "n_clients": cfg.n_clients if cfg.mode == "closed" else None,
        "n_requests": cfg.n_requests,
        "n_ok": len(ok),
        "n_error": len(records) - len(ok),
        "errors": sorted({r.error for r in records if r.error}),
        "wall_s": wall,
        "tokens": sum(r.tokens for r in records),
        "tokens_per_s": sum(r.tokens for r in records) / wall if wall else 0.0,
        "ttft_s": summarize([r.ttft_s for r in ok if r.ttft_s is not None]),
        "latency_s": summarize(
            [r.latency_s for r in ok if r.latency_s is not None]),
        "records": [dataclasses.asdict(r) for r in records],
    }
    if cfg.deadline_s is not None:
        tagged = [r for r in ok if r.deadline_met is not None]
        report["deadline_s"] = cfg.deadline_s
        report["deadline_met"] = sum(r.deadline_met for r in tagged)
        report["deadline_missed"] = sum(not r.deadline_met for r in tagged)
    return report
