"""Live SSE serving gateway over a ``MultiSpinCell`` (stdlib asyncio only).

The missing streaming front door (ROADMAP item 5): ``launch/serve.py`` runs
a closed batch session; this server lets real clients attach to a LIVE
cell, stream committed tokens as rounds complete, and watch telemetry
evolve.  Raw ``asyncio.start_server`` HTTP/1.1 — no http.server, no
framework, no new dependencies.

Endpoints:

  * ``POST /v1/generate``       — submit a prompt; the response is a
    close-delimited ``text/event-stream``: one ``queued`` event (assigned
    ``rid``), a ``round`` event per protocol round that committed tokens
    for this stream, and a terminal ``done`` / ``error`` / ``retired``
    event.  Unservable requests get a structured ``422`` (pre-queue) or an
    ``error`` event (evicted at admission) — never silent queue eviction.
  * ``GET /metrics``            — Prometheus text (``MetricsHub``).
  * ``GET /v1/stats``           — JSON running aggregates + last round.
  * ``GET /v1/trace``           — Chrome trace-event JSON of the span
    tracer's buffer (load in Perfetto / ``chrome://tracing``); structured
    409 when the gateway runs with tracing off.
  * ``GET /``                   — minimal KPI dashboard (static HTML
    polling ``/v1/stats``).
  * ``GET /healthz``            — liveness.
  * ``DELETE /v1/streams/{rid}``— retire a stream mid-session (its pages
    return to the pool on a paged engine); the stream gets a ``retired``
    event.

Concurrency model: the cell steps on ONE background task (each round's
``cell.step()`` runs on a worker thread so client I/O keeps multiplexing
during real-model verification), and every cell mutation — submit, leave,
retire — is funneled through an action queue applied between rounds on the
event loop.  The cell itself is never touched from two threads at once.
Client disconnects are detected mid-stream (reader EOF or a failed write)
and retire the stream exactly like an explicit DELETE.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import time
import uuid
from collections import deque

from repro.obs import trace
from repro.serving.gateway.dashboard import DASHBOARD_HTML
from repro.serving.gateway.telemetry import MetricsHub
from repro.serving.scheduler import Request

_MAX_ALPHA = 0.999  # planning solvers need alpha strictly below 1


@dataclasses.dataclass
class GatewayConfig:
    host: str = "127.0.0.1"
    port: int = 8011               # 0 -> ephemeral (read back via .port)
    step_barrier: int = 0          # hold the FIRST round until N submissions
    idle_wait_s: float = 0.25      # poll interval while the cell is idle
    max_body_bytes: int = 1 << 20
    step_in_thread: bool = True    # run cell.step on a worker thread
    default_max_new_tokens: int = 32
    default_alpha: float = 0.8
    default_T_S: float = 0.009
    trace_spans: bool = False      # install a repro.obs tracer for the run
    trace_capacity: int = 65536    # span ring size (oldest spans drop)
    trace_device_sync: bool = False  # block_until_ready at span exits


class _Stream:
    """Server-side handle pairing a scheduler Request with its SSE queue."""

    def __init__(self, req: Request, tag: str | None):
        self.req = req
        self.rid = req.rid
        self.tag = tag
        # correlation id carried on every SSE event; span args record rids,
        # so a Perfetto search for this stream goes trace_id -> rid -> spans
        self.trace_id = f"{req.rid:x}-{uuid.uuid4().hex[:12]}"
        self.queue: asyncio.Queue = asyncio.Queue()
        self.streamed = 0            # capped tokens already sent
        self.rounds = 0              # rounds THIS stream committed tokens in
        self.terminal = False        # a done/error/retired event was queued
        self.created_s = time.monotonic()

    def push(self, event: str, data: dict, terminal: bool = False):
        if self.terminal:
            return
        self.terminal = terminal
        self.queue.put_nowait((event, data))


class _RejectCapture:
    """Listener recording admission-time rejections.  ``on_reject`` fires
    inside ``cell.step`` — possibly on the step worker thread — so it only
    appends to a plain list (atomic under the GIL); the gateway drains it
    on the event loop after the step returns."""

    def __init__(self):
        self._rids: list[int] = []

    def on_reject(self, req):
        self._rids.append(req.rid)

    def drain(self) -> list[int]:
        out, self._rids = self._rids, []
        return out


class MultiSpinGateway:
    def __init__(self, cell, config: GatewayConfig | None = None,
                 hub: MetricsHub | None = None):
        self.cell = cell
        self.config = config or GatewayConfig()
        self.hub = hub if hub is not None else MetricsHub()
        self.hub.attach(cell)
        self._rejects = cell.add_listener(_RejectCapture())
        self._streams: dict[int, _Stream] = {}
        self._actions: deque = deque()
        self._wake = asyncio.Event()
        self._next_rid = 0
        self._running = False
        self._stepped = False        # first round executed (barrier latch)
        self._server: asyncio.AbstractServer | None = None
        self._step_task: asyncio.Task | None = None
        self.port = self.config.port
        # span tracing: the gateway owns the process-global tracer for its
        # lifetime (installed in start, uninstalled in stop) so cell/engine/
        # kernel spans fire without any per-call plumbing.  If a tracer is
        # already installed (a test's ``tracing`` scope), reuse it instead
        # of stomping the caller's.
        self.tracer: trace.Tracer | None = None
        self._owns_tracer = False

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    async def start(self):
        if self.config.trace_spans:
            existing = trace.active()
            if existing is not None:
                self.tracer = existing
            else:
                self.tracer = trace.install(trace.Tracer(
                    capacity=self.config.trace_capacity,
                    device_sync=self.config.trace_device_sync))
                self._owns_tracer = True
        self._server = await asyncio.start_server(
            self._handle_connection, self.config.host, self.config.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._running = True
        self._step_task = asyncio.create_task(self._step_loop())
        return self

    async def serve_forever(self):
        async with self._server:
            await self._server.serve_forever()

    async def stop(self):
        self._running = False
        self._wake.set()
        if self._step_task is not None:
            await self._step_task
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        for st in list(self._streams.values()):
            st.push("error", {"rid": st.rid, "error": "gateway_shutdown"},
                    terminal=True)
        if self._owns_tracer:
            trace.uninstall()
            self._owns_tracer = False
        self.hub.close()

    # ------------------------------------------------------------------
    # the single cell-stepping task
    # ------------------------------------------------------------------

    def _enqueue(self, action: tuple):
        self._actions.append(action)
        self._wake.set()

    def _apply_actions(self):
        """Apply queued cell mutations on the event loop, between rounds."""
        while self._actions:
            kind, *rest = self._actions.popleft()
            if kind == "submit":
                (req,) = rest
                self.cell.submit(req)
            elif kind == "leave":
                rid, fut = rest
                outcome = self._do_leave(rid)
                if fut is not None and not fut.done():
                    fut.set_result(outcome)

    def _do_leave(self, rid: int) -> str:
        """Retire a stream wherever it lives: active set (pages returned),
        waiting queue (plain removal), or already finished (no-op)."""
        st = self._streams.get(rid)
        sched = self.cell.scheduler
        if any(r.rid == rid for r in sched.active):
            self.cell.leave(rid)
            if st:
                st.push("retired", {"rid": rid, "status": "retired"},
                        terminal=True)
            return "retired"
        for req in sched.queue:
            if req.rid == rid:
                sched.queue.remove(req)
                req.done = True
                if st:
                    st.push("retired", {"rid": rid, "status": "cancelled"},
                            terminal=True)
                return "cancelled"
        if st is not None or self._was_known(rid):
            return "done"
        return "not_found"

    def _was_known(self, rid: int) -> bool:
        return 0 <= rid < self._next_rid

    async def _step_loop(self):
        loop = asyncio.get_running_loop()
        while self._running:
            self._apply_actions()
            sched = self.cell.scheduler
            pending = len(sched.queue) + len(sched.active)
            barrier_held = (not self._stepped
                            and pending < self.config.step_barrier)
            if pending == 0 or barrier_held:
                self._wake.clear()
                try:
                    await asyncio.wait_for(self._wake.wait(),
                                           timeout=self.config.idle_wait_s)
                except asyncio.TimeoutError:
                    pass
                continue
            self._stepped = True
            if self.config.step_in_thread:
                rec = await loop.run_in_executor(None, self.cell.step)
            else:
                rec = self.cell.step()
            self._dispatch_round(rec)
            # yield so per-connection writers flush before the next round
            await asyncio.sleep(0)

    def _round_tokens(self, st: _Stream, produced: int) -> list[int]:
        """The tokens to stream this round: real committed ids when the
        backend exposes them (EngineBackend.stream_tokens), else positional
        surrogate ids (synthetic backends draw acceptance counts, not
        token values — the stream is still bit-exact in counts)."""
        fn = getattr(self.cell.backend, "stream_tokens", None)
        if fn is not None:
            toks = fn(st.rid)
            return toks[st.streamed:st.streamed + produced]
        return list(range(st.streamed, st.streamed + produced))

    def _dispatch_round(self, rec):
        """Fan one RoundRecord out to the per-stream SSE queues."""
        for rid in self._rejects.drain():
            st = self._streams.get(rid)
            if st:
                st.push("error",
                        {"rid": rid, "error": "unservable",
                         "detail": "evicted at admission: the backend can "
                                   "never serve this request"},
                        terminal=True)
        if rec is None:
            return
        drop = getattr(self.cell.backend, "drop_finished", None)
        for i, rid in enumerate(rec.rids.tolist()):
            st = self._streams.get(int(rid))
            if st is None:
                continue
            produced = st.req.generated - st.streamed
            if produced > 0:
                tokens = self._round_tokens(st, produced)
                st.streamed += produced
                # "round" counts THIS stream's committed rounds: under the
                # continuous schedule streams progress independently, so a
                # cell-global index would skip numbers per client;
                # "cell_round" keeps the global correlation key for traces
                st.push("round", {
                    "rid": st.rid,
                    "trace_id": st.trace_id,
                    "round": st.rounds,
                    "cell_round": len(self.cell.history) - 1,
                    "n": produced,
                    "tokens": tokens,
                    "generated": st.streamed,
                    "accepted_raw": int(rec.accepted[i]),
                    "draft_width": int(rec.draft_width),
                    "t_round": float(rec.t_round),
                })
                st.rounds += 1
            if st.req.done:
                st.push("done", {
                    "rid": st.rid,
                    "trace_id": st.trace_id,
                    "generated": st.req.generated,
                    "rounds": st.req.rounds,
                    "ttft_sim_s": float(st.req.first_token_time
                                        - st.req.submit_time),
                    "tag": st.tag,
                }, terminal=True)
                if drop is not None:
                    drop(st.rid)

    # ------------------------------------------------------------------
    # HTTP layer
    # ------------------------------------------------------------------

    async def _handle_connection(self, reader: asyncio.StreamReader,
                                 writer: asyncio.StreamWriter):
        try:
            try:
                method, path, headers, body = await self._read_request(reader)
            except (ValueError, asyncio.IncompleteReadError, ConnectionError):
                await self._respond(writer, 400, {"error": "bad_request"})
                return
            if method == "GET" and path == "/metrics":
                await self._respond(writer, 200, self.hub.prometheus_text(),
                                    content_type="text/plain; version=0.0.4")
            elif method == "GET" and path == "/v1/stats":
                await self._respond(writer, 200, self.hub.snapshot())
            elif method == "GET" and path == "/v1/trace":
                if self.tracer is None:
                    await self._respond(writer, 409, {
                        "error": "tracing_disabled",
                        "detail": "start the gateway with "
                                  "GatewayConfig(trace_spans=True) "
                                  "(launch: --trace-spans)"})
                else:
                    await self._respond(
                        writer, 200, self.tracer.export_chrome_trace())
            elif method == "GET" and path in ("/", "/dashboard"):
                await self._respond(writer, 200, DASHBOARD_HTML,
                                    content_type="text/html; charset=utf-8")
            elif method == "GET" and path == "/healthz":
                await self._respond(writer, 200, {
                    "ok": True, "active": len(self.cell.scheduler.active),
                    "queued": len(self.cell.scheduler.queue)})
            elif method == "POST" and path == "/v1/generate":
                await self._handle_generate(reader, writer, body)
            elif method == "DELETE" and path.startswith("/v1/streams/"):
                await self._handle_delete(writer, path)
            else:
                await self._respond(writer, 404, {"error": "not_found",
                                                  "path": path})
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _read_request(self, reader):
        line = await reader.readline()
        parts = line.decode("latin-1").split()
        if len(parts) < 3:
            raise ValueError("malformed request line")
        method, path = parts[0].upper(), parts[1]
        headers = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", 0) or 0)
        if n > self.config.max_body_bytes:
            raise ValueError("body too large")
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body

    async def _respond(self, writer, status: int, payload,
                       content_type: str = "application/json"):
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  409: "Conflict", 422: "Unprocessable Entity",
                  500: "Internal Server Error"}.get(status, "OK")
        if isinstance(payload, (dict, list)):
            raw = json.dumps(payload).encode()
        else:
            raw = str(payload).encode()
        head = (f"HTTP/1.1 {status} {reason}\r\n"
                f"Content-Type: {content_type}\r\n"
                f"Content-Length: {len(raw)}\r\n"
                f"Connection: close\r\n\r\n").encode("latin-1")
        writer.write(head + raw)
        await writer.drain()

    # -- POST /v1/generate ----------------------------------------------

    def _parse_generate(self, body: bytes) -> tuple[Request, str | None]:
        try:
            fields = json.loads(body.decode() or "{}")
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            raise ValueError(f"invalid JSON body: {e}") from e
        if not isinstance(fields, dict):
            raise ValueError("body must be a JSON object")
        cfg = self.config
        prompt = fields.get("prompt")
        if prompt is not None:
            if (not isinstance(prompt, list)
                    or not all(isinstance(t, int) for t in prompt)):
                raise ValueError("'prompt' must be a list of token ids")
            if not prompt:
                raise ValueError("'prompt' must be non-empty")
        prompt_len = int(fields.get(
            "prompt_len", len(prompt) if prompt else 8))
        max_new = int(fields.get("max_new_tokens",
                                 cfg.default_max_new_tokens))
        alpha = float(fields.get("alpha", cfg.default_alpha))
        T_S = float(fields.get("T_S", cfg.default_T_S))
        if prompt_len < 1:
            raise ValueError("'prompt_len' must be >= 1")
        if max_new < 1:
            raise ValueError("'max_new_tokens' must be >= 1")
        if not 0.0 < alpha <= 1.0:
            raise ValueError("'alpha' must be in (0, 1]")
        if T_S <= 0.0:
            raise ValueError("'T_S' must be > 0")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt_len=prompt_len,
                      max_new_tokens=max_new,
                      task=str(fields.get("task", "")),
                      alpha=min(alpha, _MAX_ALPHA), T_S=T_S,
                      prompt=tuple(prompt) if prompt is not None else None)
        return req, fields.get("tag")

    async def _handle_generate(self, reader, writer, body: bytes):
        try:
            req, tag = self._parse_generate(body)
        except ValueError as e:
            await self._respond(writer, 400, {"error": "bad_request",
                                              "detail": str(e)})
            return
        # unservable-forever requests are refused BEFORE queueing, as a
        # structured HTTP error (the in-queue eviction path still exists
        # for requests that become unservable later)
        servable = getattr(self.cell.backend, "servable", None)
        if servable is not None and not servable(req):
            await self._respond(writer, 422, {
                "error": "unservable", "rid": req.rid,
                "detail": "backend can never serve this request "
                          "(prompt too long for the engine, or no rows "
                          "left on a contiguous batch)"})
            return
        st = _Stream(req, tag)
        self._streams[req.rid] = st
        try:
            writer.write(
                b"HTTP/1.1 200 OK\r\n"
                b"Content-Type: text/event-stream\r\n"
                b"Cache-Control: no-cache\r\n"
                b"Connection: close\r\n\r\n")
            await writer.drain()
            self._enqueue(("submit", req))
            st.push("queued", {"rid": req.rid, "tag": tag,
                               "trace_id": st.trace_id,
                               "scheme": self.cell.config.scheme,
                               "schedule": self.cell.config.schedule,
                               "max_new_tokens": req.max_new_tokens})
            await self._pump_stream(st, reader, writer)
        finally:
            self._streams.pop(req.rid, None)

    async def _pump_stream(self, st: _Stream, reader, writer):
        """Forward the stream's events; watch the socket for disconnect."""
        monitor = asyncio.ensure_future(reader.read())
        try:
            while True:
                getter = asyncio.ensure_future(st.queue.get())
                done, _ = await asyncio.wait(
                    {getter, monitor}, return_when=asyncio.FIRST_COMPLETED)
                if getter not in done:
                    getter.cancel()
                    # client went away mid-session: retire the stream so
                    # its batch slot frees and its pages return to the pool
                    self._enqueue(("leave", st.rid, None))
                    return
                event, data = getter.result()
                payload = (f"event: {event}\r\n"
                           f"data: {json.dumps(data)}\r\n\r\n")
                try:
                    writer.write(payload.encode())
                    await writer.drain()
                except (ConnectionError, OSError):
                    self._enqueue(("leave", st.rid, None))
                    return
                if st.terminal and st.queue.empty():
                    return
        finally:
            monitor.cancel()

    # -- DELETE /v1/streams/{rid} ---------------------------------------

    async def _handle_delete(self, writer, path: str):
        try:
            rid = int(path.rsplit("/", 1)[1])
        except ValueError:
            await self._respond(writer, 400, {"error": "bad_stream_id"})
            return
        fut = asyncio.get_running_loop().create_future()
        self._enqueue(("leave", rid, fut))
        outcome = await fut
        if outcome == "not_found":
            await self._respond(writer, 404, {"error": "not_found",
                                              "rid": rid})
        else:
            await self._respond(writer, 200, {"rid": rid,
                                              "status": outcome})


async def serve(cell, config: GatewayConfig | None = None,
                hub: MetricsHub | None = None):
    """Convenience runner: start a gateway and serve until cancelled."""
    gw = MultiSpinGateway(cell, config=config, hub=hub)
    await gw.start()
    try:
        await gw.serve_forever()
    finally:
        await gw.stop()
