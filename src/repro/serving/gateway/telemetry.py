"""Real-time telemetry for the Multi-SPIN serving stack.

``MetricsHub`` attaches to a ``MultiSpinCell`` through the cell's narrow
listener surface (``cell.add_listener``) and turns every executed round —
its ``RoundRecord``, the backend's ``pool_stats()`` snapshot riding on it,
and the scheduler's running stats — into one typed ``RoundMetrics`` event:

  * acceptance rate (per-position, bonus token excluded),
  * per-device goodput and the executed multi-draft width J,
  * the DiP-SD-style round breakdown t_draft / t_upload / t_ver / t_round,
  * page-pool occupancy (paged engines; zeros for synthetic backends),
  * queue depth, admitted / rejected / completed counters,
  * BOTH running goodput views (`goodput_committed` vs `goodput_capped` —
    see ``MultiSpinCell.summary`` for why there are two).

Events land in a bounded ring buffer (``window`` rounds), feed running
aggregates, and optionally append to a JSONL trace sink.  ``/metrics`` is
served from ``prometheus_text()`` (text exposition format, stdlib only)
and ``/v1/stats`` from ``snapshot()``.

The dependency is strictly one-way: this module imports nothing from the
gateway server and the cell imports nothing from here — the WISP-style
per-stream SLO/latency telemetry is attachable to ANY cell, batch or live.

All mutating entry points take an internal lock because the gateway steps
the cell on a worker thread while scrapes run on the event loop.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque

import numpy as np


@dataclasses.dataclass
class RoundMetrics:
    """One executed round, flattened for dashboards and the JSONL trace."""

    round_idx: int
    host_time_s: float            # wall-clock since hub attach (host seconds)
    host_dt_s: float              # host seconds since the previous round
    scheme: str
    schedule: str
    n_planned: int                # requests the round planned for
    n_active: int                 # ... that survived the deadline mask
    queue_depth: int              # requests waiting for a batch slot
    draft_width: int              # executed multi-draft J
    drafted_tokens: int           # sum of planned per-device lengths
    accepted_tokens: int          # realized accepted incl. bonus
    acceptance: float             # per-position rate, bonus excluded
    t_draft: float                # phase maxima (simulated seconds)
    t_upload: float
    t_ver: float
    t_round: float
    realized_goodput: float       # this round, tokens / t_round
    predicted_goodput: float      # the plan's prediction
    per_device_goodput: dict      # rid -> accepted / t_round (participants)
    goodput_committed: float      # running, raw accepted / protocol wall
    goodput_capped: float         # running, per-request capped (scheduler)
    pool_free_pages: int          # 0 when the backend has no page pool
    pool_used_bytes: int
    pool_free_bytes: int
    pool_occupancy: float         # used / (used + free), 0.0 without a pool
    admitted_total: int
    rejected_total: int
    completed_total: int
    # verification-batch fill (participants / max_batch) — the continuous
    # assembler's dispatch-early-vs-wait trade, 1.0 for full sync cohorts
    batch_occupancy: float = 0.0
    # continuous schedule: READY streams waiting when this batch dispatched
    ready_depth: int = 0


class _Histogram:
    """Fixed-bound histogram in Prometheus exposition shape: cumulative
    ``_bucket{le=...}`` counts plus ``_sum`` / ``_count``.  Bounds are set
    at construction (Prometheus histograms cannot rebucket); the caller
    holds the hub lock around ``observe`` and ``exposition``."""

    def __init__(self, bounds):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # last slot = +Inf
        self.sum = 0.0
        self.n = 0

    def observe(self, value: float):
        v = float(value)
        self.sum += v
        self.n += 1
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def exposition(self, name: str, help_: str) -> list[str]:
        lines = [f"# HELP {name} {help_}", f"# TYPE {name} histogram"]
        cum = 0
        for b, c in zip(self.bounds, self.counts):
            cum += c
            lines.append(f'{name}_bucket{{le="{format(b, "g")}"}} {cum}')
        cum += self.counts[-1]
        lines.append(f'{name}_bucket{{le="+Inf"}} {cum}')
        lines.append(f"{name}_sum {self.sum:.6f}")
        lines.append(f"{name}_count {self.n}")
        return lines


# simulated seconds; spans TTFTs of a lightly loaded synthetic cell
# (~0.1 s) through deep-queue engine sessions (tens of seconds)
_TTFT_BOUNDS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)
_ROUND_BOUNDS = (0.025, 0.05, 0.1, 0.2, 0.4, 0.8, 1.6, 3.2)


class MetricsHub:
    """Round-granular metrics aggregator + Prometheus exporter + JSONL sink.

    Usage::

        hub = MetricsHub(window=512, trace_path="trace.jsonl")
        hub.attach(cell)          # registers as a cell listener
        cell.run(...)             # or the gateway steps it live
        print(hub.prometheus_text())
        hub.close()
    """

    def __init__(self, window: int = 512, trace_path: str | None = None):
        self._lock = threading.Lock()
        self.ring: deque[RoundMetrics] = deque(maxlen=int(window))
        self.trace_path = trace_path
        self._trace_file = None
        self._cell = None
        self._t0 = time.monotonic()
        self._last_round_t = None
        # running totals (events survive the ring's eviction)
        self.rounds_total = 0
        self.tokens_committed_total = 0
        self.drafted_total = 0
        self.accepted_positions_total = 0   # accepted minus bonus
        self.admitted_total = 0
        self.rejected_total = 0
        self.sim_seconds_total = 0.0
        # distribution families (simulated seconds)
        self.hist_round = _Histogram(_ROUND_BOUNDS)
        self.hist_ttft = _Histogram(_TTFT_BOUNDS)
        self._ttft_seen = 0   # scheduler ttft_s entries already observed

    # -- lifecycle -------------------------------------------------------

    def attach(self, cell) -> "MetricsHub":
        """Register on the cell's listener surface; keeps a reference for
        scheduler-stats and queue-depth reads at event time."""
        self._cell = cell
        cell.add_listener(self)
        self._t0 = time.monotonic()
        return self

    def close(self):
        with self._lock:
            if self._trace_file is not None:
                self._trace_file.close()
                self._trace_file = None

    # -- cell listener surface ------------------------------------------

    def on_admit(self, requests):
        with self._lock:
            self.admitted_total += len(requests)

    def on_reject(self, request):
        with self._lock:
            self.rejected_total += 1

    def on_round(self, rec):
        """Flatten one RoundRecord into a RoundMetrics event (called by the
        cell after retirement, possibly from the gateway's step thread)."""
        cell = self._cell
        lengths = np.asarray(rec.lengths, dtype=np.int64)
        accepted = np.asarray(rec.accepted, dtype=np.int64)
        active = np.asarray(rec.active, dtype=bool)
        drafted = int(lengths[active].sum())
        positions = int(np.maximum(accepted - 1, 0)[active].sum())
        pool = rec.pool_stats or {}
        used = int(pool.get("used_bytes", 0))
        free = int(pool.get("free_bytes", 0))
        now = time.monotonic()
        with self._lock:
            host_dt = (now - self._last_round_t
                       if self._last_round_t is not None else 0.0)
            self._last_round_t = now
            self.rounds_total += 1
            self.tokens_committed_total += int(accepted.sum())
            self.drafted_total += drafted
            self.accepted_positions_total += positions
            self.sim_seconds_total += float(rec.t_round)
            stats = cell.scheduler.stats if cell is not None else None
            self.hist_round.observe(float(rec.t_round))
            if stats is not None:
                # the scheduler appends a TTFT when a stream first commits;
                # observe only the entries new since the last round
                for v in stats.ttft_s[self._ttft_seen:]:
                    self.hist_ttft.observe(float(v))
                self._ttft_seen = len(stats.ttft_s)
            rm = RoundMetrics(
                round_idx=self.rounds_total - 1,
                host_time_s=now - self._t0,
                host_dt_s=host_dt,
                scheme=cell.config.scheme if cell is not None else "",
                schedule=cell.config.schedule if cell is not None else "",
                n_planned=int(len(lengths)),
                n_active=int(active.sum()),
                # the record carries the post-admission depth the round
                # actually saw; reading the live queue here raced the
                # gateway's step thread and could disagree with /v1/stats
                queue_depth=(rec.queue_depth
                             if rec.queue_depth is not None
                             else len(cell.scheduler.queue)
                             if cell is not None else 0),
                draft_width=int(rec.draft_width),
                drafted_tokens=drafted,
                accepted_tokens=int(accepted.sum()),
                acceptance=positions / drafted if drafted else 0.0,
                t_draft=float(rec.t_draft),
                t_upload=float(rec.t_upload),
                t_ver=float(rec.t_ver),
                t_round=float(rec.t_round),
                realized_goodput=float(rec.realized_goodput),
                predicted_goodput=float(rec.predicted_goodput),
                per_device_goodput={
                    int(r): float(a) / float(rec.t_round)
                    for r, a, ok in zip(rec.rids, accepted, active)
                    if ok and rec.t_round > 0},
                goodput_committed=(self.tokens_committed_total
                                   / self.sim_seconds_total
                                   if self.sim_seconds_total else 0.0),
                goodput_capped=stats.goodput if stats is not None else 0.0,
                pool_free_pages=int(pool.get("free_pages", 0)),
                pool_used_bytes=used,
                pool_free_bytes=free,
                pool_occupancy=used / (used + free) if used + free else 0.0,
                admitted_total=self.admitted_total,
                rejected_total=self.rejected_total,
                completed_total=stats.completed if stats is not None else 0,
                batch_occupancy=float(rec.batch_occupancy or 0.0),
                ready_depth=int(rec.ready_depth or 0),
            )
            self.ring.append(rm)
            self._trace(rm)

    def _trace(self, rm: RoundMetrics):
        if self.trace_path is None:
            return
        if self._trace_file is None:
            self._trace_file = open(self.trace_path, "a")
        self._trace_file.write(json.dumps(dataclasses.asdict(rm)) + "\n")
        self._trace_file.flush()

    # -- read side -------------------------------------------------------

    @property
    def latest(self) -> RoundMetrics | None:
        with self._lock:
            return self.ring[-1] if self.ring else None

    def window_acceptance(self) -> float:
        """Acceptance rate over the ring window (per position, no bonus)."""
        with self._lock:
            drafted = sum(m.drafted_tokens for m in self.ring)
            positions = sum(
                m.accepted_tokens - m.n_active for m in self.ring)
            return max(positions, 0) / drafted if drafted else 0.0

    def snapshot(self) -> dict:
        """The ``/v1/stats`` payload: running aggregates + the last round +
        simulated-time TTFT percentiles from the scheduler."""
        last = self.latest
        with self._lock:
            out = {
                "rounds_total": self.rounds_total,
                "tokens_committed_total": self.tokens_committed_total,
                "admitted_total": self.admitted_total,
                "rejected_total": self.rejected_total,
                "acceptance_total": (self.accepted_positions_total
                                     / self.drafted_total
                                     if self.drafted_total else 0.0),
                "sim_seconds_total": self.sim_seconds_total,
                "window": len(self.ring),
            }
        out["acceptance_window"] = self.window_acceptance()
        out["last_round"] = dataclasses.asdict(last) if last else None
        cell = self._cell
        if cell is not None:
            out["scheduler"] = {
                "completed": cell.scheduler.stats.completed,
                "total_tokens": cell.scheduler.stats.total_tokens,
                "total_rounds": cell.scheduler.stats.total_rounds,
                "wall_time": cell.scheduler.stats.wall_time,
                "goodput_capped": cell.scheduler.stats.goodput,
                "queue_depth": len(cell.scheduler.queue),
                "active": len(cell.scheduler.active),
                "hol_wait_max": cell.scheduler.stats.hol_wait_max,
            }
            ttfts = sorted(cell.scheduler.stats.ttft_s)
            if ttfts:
                from repro.serving.gateway.loadgen import percentile
                out["ttft_sim_s"] = {"p50": percentile(ttfts, 50),
                                     "p95": percentile(ttfts, 95),
                                     "p99": percentile(ttfts, 99),
                                     "n": len(ttfts)}
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition of the current state (the `/metrics`
        endpoint).  Gauges reflect the LAST round; counters are running."""
        last = self.latest
        cell = self._cell
        lines = []

        def metric(name, value, help_, type_="gauge", labels=None):
            lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {type_}")
            if labels is None:
                lines.append(f"{name} {value}")
            else:
                for lab, v in labels:
                    lines.append(f"{name}{{{lab}}} {v}")

        with self._lock:
            rounds = self.rounds_total
            tokens = self.tokens_committed_total
            admitted = self.admitted_total
            rejected = self.rejected_total
            hist_lines = (
                self.hist_ttft.exposition(
                    "multispin_ttft_seconds",
                    "simulated time-to-first-token per stream")
                + self.hist_round.exposition(
                    "multispin_round_seconds",
                    "simulated wall seconds per protocol round"))
        metric("multispin_rounds_total", rounds,
               "executed protocol rounds", "counter")
        metric("multispin_tokens_committed_total", tokens,
               "committed tokens incl. bonus (uncapped)", "counter")
        metric("multispin_requests_admitted_total", admitted,
               "requests admitted into the active set", "counter")
        metric("multispin_requests_rejected_total", rejected,
               "permanently-unservable requests evicted", "counter")
        if cell is not None:
            metric("multispin_requests_completed_total",
                   cell.scheduler.stats.completed,
                   "requests that reached their token budget", "counter")
            metric("multispin_tokens_capped_total",
                   cell.scheduler.stats.total_tokens,
                   "committed tokens capped at per-request budgets",
                   "counter")
            metric("multispin_queue_depth", len(cell.scheduler.queue),
                   "requests waiting for a batch slot")
            metric("multispin_active_streams", len(cell.scheduler.active),
                   "requests in the verification batch")
        metric("multispin_acceptance_rate",
               f"{self.window_acceptance():.6f}",
               "per-position draft acceptance over the ring window")
        lines.extend(hist_lines)
        if last is not None:
            metric("multispin_draft_width", last.draft_width,
                   "multi-draft J executed by the last round")
            metric("multispin_goodput_committed_tokens_per_s",
                   f"{last.goodput_committed:.6f}",
                   "running raw-committed goodput (protocol view)")
            metric("multispin_goodput_capped_tokens_per_s",
                   f"{last.goodput_capped:.6f}",
                   "running budget-capped goodput (serving view)")
            metric("multispin_round_phase_seconds", None,
                   "last round's simulated phase breakdown",
                   labels=[(f'phase="{p}"', f"{v:.6f}") for p, v in (
                       ("draft", last.t_draft), ("upload", last.t_upload),
                       ("verify", last.t_ver), ("total", last.t_round))])
            metric("multispin_batch_occupancy",
                   f"{last.batch_occupancy:.6f}",
                   "last verification batch's fill: participants / max_batch")
            metric("multispin_ready_queue_depth", last.ready_depth,
                   "drafted streams awaiting batch assembly (continuous)")
            metric("multispin_pool_free_pages", last.pool_free_pages,
                   "KV page-pool free pages (0 without a paged engine)")
            metric("multispin_pool_occupancy",
                   f"{last.pool_occupancy:.6f}",
                   "KV page-pool used fraction (0 without a paged engine)")
            if last.per_device_goodput:
                metric("multispin_device_goodput_tokens_per_s", None,
                       "last round's per-device goodput",
                       labels=[(f'rid="{rid}"', f"{g:.6f}")
                               for rid, g in
                               sorted(last.per_device_goodput.items())])
        return "\n".join(lines) + "\n"
