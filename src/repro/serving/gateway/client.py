"""Stdlib SSE client for the Multi-SPIN gateway.

Raw ``asyncio.open_connection`` HTTP/1.1 — no requests/aiohttp dependency —
mirroring the server's close-delimited SSE framing:

    client = GatewayClient("127.0.0.1", 8011)
    res = await client.generate(prompt_len=8, max_new_tokens=32)
    print(res.rid, res.tokens, res.ttft_s)

    async for ev in client.stream_generate(prompt_len=8, max_new_tokens=32):
        print(ev.event, ev.data)

    text = await client.metrics()          # GET /metrics
    stats = await client.stats()           # GET /v1/stats
    await client.delete_stream(rid)        # DELETE /v1/streams/{rid}
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import time


class GatewayError(RuntimeError):
    """Non-2xx HTTP response from the gateway (structured body attached)."""

    def __init__(self, status: int, body):
        super().__init__(f"gateway returned {status}: {body}")
        self.status = status
        self.body = body


@dataclasses.dataclass
class SSEEvent:
    event: str
    data: dict


@dataclasses.dataclass
class GenerateResult:
    rid: int | None
    tokens: list
    n_rounds: int
    per_round: list            # [(n_new_tokens, generated_so_far), ...]
    ttft_s: float | None       # send -> first round event (real wall)
    latency_s: float | None    # send -> terminal event
    done: bool
    error: str | None
    events: list               # every SSEEvent, in order


def _encode_request(method: str, path: str, host: str,
                    body: bytes | None) -> bytes:
    lines = [f"{method} {path} HTTP/1.1", f"Host: {host}",
             "Connection: close", "Accept: */*"]
    if body:
        lines += ["Content-Type: application/json",
                  f"Content-Length: {len(body)}"]
    head = ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1")
    return head + (body or b"")


async def _read_head(reader: asyncio.StreamReader):
    """(status_code, headers) — consumes up to the blank line."""
    status_line = await reader.readline()
    if not status_line:
        raise ConnectionError("empty response from gateway")
    parts = status_line.decode("latin-1").split(None, 2)
    status = int(parts[1])
    headers = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        k, _, v = line.decode("latin-1").partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers


async def _read_body(reader, headers) -> bytes:
    n = headers.get("content-length")
    if n is not None:
        return await reader.readexactly(int(n))
    return await reader.read()


class GatewayClient:
    def __init__(self, host: str = "127.0.0.1", port: int = 8011):
        self.host = host
        self.port = port

    # -- plain endpoints -------------------------------------------------

    async def _call(self, method: str, path: str,
                    body: dict | None = None):
        reader, writer = await asyncio.open_connection(self.host, self.port)
        try:
            payload = (json.dumps(body).encode() if body is not None
                       else None)
            writer.write(_encode_request(method, path, self.host, payload))
            await writer.drain()
            status, headers = await _read_head(reader)
            raw = await _read_body(reader, headers)
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass
        ctype = headers.get("content-type", "")
        data = (json.loads(raw.decode() or "null")
                if "json" in ctype else raw.decode())
        if status >= 300:
            raise GatewayError(status, data)
        return data

    async def metrics(self) -> str:
        return await self._call("GET", "/metrics")

    async def stats(self) -> dict:
        return await self._call("GET", "/v1/stats")

    async def trace(self) -> dict:
        """Chrome trace-event JSON (``GET /v1/trace``); raises
        ``GatewayError`` (409) when the gateway runs with tracing off."""
        return await self._call("GET", "/v1/trace")

    async def health(self) -> dict:
        return await self._call("GET", "/healthz")

    async def delete_stream(self, rid: int) -> dict:
        return await self._call("DELETE", f"/v1/streams/{rid}")

    # -- streaming generation -------------------------------------------

    async def stream_generate(self, **fields):
        """Async generator of ``SSEEvent``s for one generation request.
        Raises ``GatewayError`` on a non-SSE (error) response.  Closing the
        generator closes the connection (mid-stream disconnect)."""
        reader, writer = await asyncio.open_connection(self.host, self.port)
        try:
            body = json.dumps(fields).encode()
            writer.write(_encode_request("POST", "/v1/generate", self.host,
                                         body))
            await writer.drain()
            status, headers = await _read_head(reader)
            if status >= 300 or "text/event-stream" not in headers.get(
                    "content-type", ""):
                raw = await _read_body(reader, headers)
                data = raw.decode()
                if "json" in headers.get("content-type", ""):
                    data = json.loads(data or "null")
                raise GatewayError(status, data)
            async for ev in _parse_sse(reader):
                yield ev
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def generate(self, disconnect_after_rounds: int | None = None,
                       **fields) -> GenerateResult:
        """Run one generation to completion, collecting streamed tokens and
        timing.  ``disconnect_after_rounds`` abandons the stream (abrupt
        close) after that many round events — the gateway must then retire
        the stream server-side."""
        t_send = time.monotonic()
        res = GenerateResult(rid=None, tokens=[], n_rounds=0, per_round=[],
                             ttft_s=None, latency_s=None, done=False,
                             error=None, events=[])
        gen = self.stream_generate(**fields)
        try:
            async for ev in gen:
                res.events.append(ev)
                if ev.event == "queued":
                    res.rid = ev.data.get("rid")
                elif ev.event == "round":
                    if res.ttft_s is None:
                        res.ttft_s = time.monotonic() - t_send
                    res.n_rounds += 1
                    res.tokens.extend(ev.data.get("tokens", []))
                    res.per_round.append((ev.data.get("n"),
                                          ev.data.get("generated")))
                    if (disconnect_after_rounds is not None
                            and res.n_rounds >= disconnect_after_rounds):
                        break
                elif ev.event == "done":
                    res.done = True
                    break
                elif ev.event in ("error", "retired"):
                    res.error = ev.data.get("error", ev.event)
                    break
        finally:
            await gen.aclose()
        res.latency_s = time.monotonic() - t_send
        return res


async def _parse_sse(reader: asyncio.StreamReader):
    """Yield SSEEvents until EOF (the server closes to end the stream)."""
    event, data_lines = "message", []
    while True:
        line = await reader.readline()
        if not line:
            return
        text = line.decode("utf-8").rstrip("\r\n")
        if not text:
            if data_lines:
                try:
                    data = json.loads("\n".join(data_lines))
                except json.JSONDecodeError:
                    data = {"raw": "\n".join(data_lines)}
                yield SSEEvent(event=event, data=data)
            event, data_lines = "message", []
            continue
        if text.startswith(":"):
            continue                       # SSE comment / keepalive
        field, _, value = text.partition(":")
        value = value.removeprefix(" ")
        if field == "event":
            event = value
        elif field == "data":
            data_lines.append(value)
