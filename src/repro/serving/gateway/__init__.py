"""Live serving gateway + telemetry for Multi-SPIN cells (stdlib-only).

``MultiSpinGateway`` serves a live ``MultiSpinCell`` over HTTP/1.1 with
SSE token streaming; ``MetricsHub`` turns round records into Prometheus
metrics and JSON stats; ``GatewayClient`` / ``run_loadgen`` drive it.
Everything in this package is importable without JAX.
"""

from repro.serving.gateway.client import (
    GatewayClient,
    GatewayError,
    GenerateResult,
    SSEEvent,
)
from repro.serving.gateway.dashboard import DASHBOARD_HTML
from repro.serving.gateway.loadgen import (
    LoadGenConfig,
    RequestRecord,
    percentile,
    run_loadgen,
    summarize,
)
from repro.serving.gateway.server import (
    GatewayConfig,
    MultiSpinGateway,
    serve,
)
from repro.serving.gateway.telemetry import MetricsHub, RoundMetrics

__all__ = [
    "DASHBOARD_HTML",
    "GatewayClient",
    "GatewayError",
    "GenerateResult",
    "SSEEvent",
    "LoadGenConfig",
    "RequestRecord",
    "percentile",
    "run_loadgen",
    "summarize",
    "GatewayConfig",
    "MultiSpinGateway",
    "serve",
    "MetricsHub",
    "RoundMetrics",
]
