"""Cache utilities for the serving runtime.

Models own their cache layout (``init_cache`` / ``CACHE_BATCH_AXES``); this
module adds the serving-level operations:

  * snapshot selection — SSM-state rollback after speculative verification
  * byte accounting — admission control / placement decisions
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def select_snapshots(snapshots, idx: jax.Array, batch_axes: dict):
    """Per-row snapshot selection.

    snapshots: pytree whose leaves have a leading step axis T (stacked caches
    from ``forward_window(..., return_snapshots=True)``).
    idx: (B,) step index to keep for each row (clamped to [0, T-1]).
    batch_axes: leaf-key -> batch axis in the UNSTACKED cache layout.

    Returns a cache pytree (no leading T) where row b carries the state after
    step idx[b].
    """
    T = jax.tree.leaves(snapshots)[0].shape[0]
    idx = jnp.clip(idx, 0, T - 1)

    def _select(key, leaf):
        ba = batch_axes[key]
        # leaf: (T, ..., B at ba+1, ...); vmap over the batch axis and pick
        # the per-row step.
        return jax.vmap(lambda s, i: s[i], in_axes=(ba + 1, 0), out_axes=ba)(
            leaf, idx)

    return {k: _select(k, v) for k, v in snapshots.items()}


def merge_snapshot_into_cache(cache, selected, keys=("ssm", "conv")):
    """Overwrite the recurrent-state leaves of ``cache`` with rolled-back
    versions, keeping attention KV leaves (mask-managed) as-is."""
    out = dict(cache)
    for k in keys:
        if k in selected:
            out[k] = selected[k]
    return out


def cache_bytes(cache) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(cache))


def needs_state_rollback(cfg) -> bool:
    """Whether the family carries recurrent state that speculative rejection
    must roll back (attention KV is rollback-free under position masking)."""
    return cfg.family in ("ssm", "hybrid")
