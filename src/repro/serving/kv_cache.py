"""Cache utilities for the serving runtime.

Models own their cache layout (``init_cache`` / ``init_paged_cache`` /
``CACHE_BATCH_AXES``); this module adds the serving-level operations:

  * snapshot selection — SSM-state rollback after speculative verification
  * byte accounting — admission control / placement decisions
  * ``PagedKVCache`` — page allocator + per-stream page tables for serving a
    *changing* stream population out of one preallocated pool
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def select_snapshots(snapshots, idx: jax.Array, batch_axes: dict):
    """Per-row snapshot selection.

    snapshots: pytree whose leaves have a leading step axis T (stacked caches
    from ``forward_window(..., return_snapshots=True)``).
    idx: (B,) step index to keep for each row (clamped to [0, T-1]).
    batch_axes: leaf-key -> batch axis in the UNSTACKED cache layout.

    Returns a cache pytree (no leading T) where row b carries the state after
    step idx[b].
    """
    T = jax.tree.leaves(snapshots)[0].shape[0]
    idx = jnp.clip(idx, 0, T - 1)

    def _select(key, leaf):
        ba = batch_axes[key]
        # leaf: (T, ..., B at ba+1, ...); vmap over the batch axis and pick
        # the per-row step.
        return jax.vmap(lambda s, i: s[i], in_axes=(ba + 1, 0), out_axes=ba)(
            leaf, idx)

    return {k: _select(k, v) for k, v in snapshots.items()}


def merge_snapshot_into_cache(cache, selected, keys=("ssm", "conv")):
    """Overwrite the recurrent-state leaves of ``cache`` with rolled-back
    versions, keeping attention KV leaves (mask-managed) as-is."""
    out = dict(cache)
    for k in keys:
        if k in selected:
            out[k] = selected[k]
    return out


def cache_bytes(cache) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(cache))


def needs_state_rollback(cfg) -> bool:
    """Whether the family carries recurrent state that speculative rejection
    must roll back (attention KV is rollback-free under position masking)."""
    return cfg.family in ("ssm", "hybrid")


# ---------------------------------------------------------------------------
# Paged KV-cache management
# ---------------------------------------------------------------------------

class PagePoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied from the page pool.

    Admission control is expected to query ``can_allocate`` / ``free_bytes``
    BEFORE committing a stream, so in a well-behaved cell this only fires on
    mid-round growth past the reservation headroom."""


class PagedKVCache:
    """Free-list page allocator with per-stream page tables.

    The model owns the page *pool* (``init_paged_cache``: every attention
    leaf shaped ``(layers, num_pages, page_size, KV, D)``); this manager owns
    the *mapping*: which physical pages back which logical positions of which
    stream.  All state is host-side numpy — the device-side view handed to
    ``forward_window`` is just the ``(B, pages_per_stream)`` int32 page-table
    slice for the rows in the batch (``-1`` marks unmapped slots; model-side
    writes there are dropped and reads are masked).

    Page-size tradeoff: small pages waste fewer slots per stream tail
    (internal fragmentation ~ page_size/2 tokens per stream) but widen the
    page table and the gather; large pages amortize gather indices but strand
    more of the pool when streams are short.  Serving shapes here default to
    16 tokens/page.
    """

    def __init__(self, num_pages: int, page_size: int,
                 pages_per_stream: int, bytes_per_page: int = 0):
        if num_pages <= 0 or page_size <= 0 or pages_per_stream <= 0:
            raise ValueError("num_pages, page_size, pages_per_stream must be "
                             "positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.pages_per_stream = int(pages_per_stream)
        self.bytes_per_page = int(bytes_per_page)
        # LIFO free list: recently-returned (hot) pages are reused first
        self._free: list[int] = list(range(num_pages - 1, -1, -1))
        self._tables: dict[int, list[int]] = {}   # stream -> physical pages
        self._lengths: dict[int, int] = {}        # stream -> valid token count
        # device-side page-table mirror: (rows, pages_per_stream) int32 on
        # device, flushed incrementally — mutations mark their row dirty and
        # ``device_table`` uploads ONLY the dirty rows (one explicit
        # device_put + scatter per flush) instead of re-uploading the whole
        # table several times per round
        self._dev = None                          # jax.Array | None
        self._dev_rows = 0                        # row capacity of _dev
        self._dirty: set[int] = set()

    # -- capacity queries ----------------------------------------------------

    @property
    def num_free_pages(self) -> int:
        return len(self._free)

    @property
    def num_allocated_pages(self) -> int:
        return self.num_pages - len(self._free)

    def pages_for(self, length: int) -> int:
        """Pages needed to hold ``length`` tokens (0 tokens -> 0 pages)."""
        return -(-max(int(length), 0) // self.page_size)

    def can_allocate(self, length: int) -> bool:
        """Whether a NEW stream of ``length`` tokens fits right now."""
        need = self.pages_for(length)
        return need <= min(len(self._free), self.pages_per_stream)

    def free_bytes(self) -> int:
        return len(self._free) * self.bytes_per_page

    def used_bytes(self) -> int:
        return self.num_allocated_pages * self.bytes_per_page

    # -- stream lifecycle ----------------------------------------------------

    def alloc_stream(self, stream: int, length: int) -> None:
        """Map a new stream and reserve pages for its first ``length`` tokens."""
        if stream in self._tables:
            raise ValueError(f"stream {stream} already allocated")
        self._tables[stream] = []
        self._lengths[stream] = 0
        try:
            self.extend(stream, length)
        except PagePoolExhausted:
            self.free_stream(stream)
            raise

    def extend(self, stream: int, new_length: int) -> None:
        """Grow ``stream``'s mapping to cover ``new_length`` tokens."""
        table = self._tables[stream]
        need = self.pages_for(new_length)
        if need > self.pages_per_stream:
            raise PagePoolExhausted(
                f"stream {stream}: {new_length} tokens need {need} pages > "
                f"pages_per_stream={self.pages_per_stream} (max_len)")
        grow = need - len(table)
        if grow > len(self._free):
            raise PagePoolExhausted(
                f"stream {stream}: need {grow} pages, pool has "
                f"{len(self._free)} free of {self.num_pages}")
        for _ in range(max(grow, 0)):
            table.append(self._free.pop())
        if grow > 0:
            self._dirty.add(int(stream))
        self._lengths[stream] = max(self._lengths[stream], int(new_length))

    def truncate(self, stream: int, new_length: int) -> int:
        """Shrink ``stream`` to ``new_length`` tokens, returning whole pages
        past the new tail to the pool (speculative rejection: unused draft
        pages simply come back).  Returns the number of pages freed."""
        table = self._tables[stream]
        keep = self.pages_for(new_length)
        freed = 0
        while len(table) > keep:
            self._free.append(table.pop())
            freed += 1
        if freed > 0:
            self._dirty.add(int(stream))
        self._lengths[stream] = int(new_length)
        return freed

    def free_stream(self, stream: int) -> int:
        """Unmap a stream entirely; every page returns to the pool."""
        table = self._tables.pop(stream)
        self._lengths.pop(stream)
        self._free.extend(reversed(table))
        if table:
            self._dirty.add(int(stream))
        return len(table)

    # -- views ---------------------------------------------------------------

    def streams(self) -> list[int]:
        return sorted(self._tables)

    def length(self, stream: int) -> int:
        return self._lengths[stream]

    def page_table(self, streams) -> np.ndarray:
        """(len(streams), pages_per_stream) int32 physical-page table; -1
        marks unmapped slots (writes dropped, reads masked).  Unknown streams
        (retired rows still riding the batch) map to an all--1 row."""
        out = np.full((len(streams), self.pages_per_stream), -1, np.int32)
        for i, s in enumerate(streams):
            pages = self._tables.get(s, ())
            out[i, :len(pages)] = pages
        return out

    def device_table(self, streams) -> "jax.Array":
        """Device-resident page table for ``streams``, maintained
        incrementally.

        A persistent ``(rows, pages_per_stream)`` int32 mirror lives on
        device; each call flushes the rows dirtied since the last flush with
        ONE explicit ``jax.device_put`` + row scatter, then gathers the
        requested rows on device.  This replaces the per-call host rebuild +
        full re-upload of ``page_table(streams)`` on the round hot path —
        every transfer here is explicit, so dispatch stays legal under
        ``jax.transfer_guard("disallow")``.

        ``streams`` may contain ``-1`` padding entries (and rows the mirror
        has never seen): they gather as all--1 rows — cache writes dropped,
        reads masked — exactly like ``page_table``.
        """
        hi = max((int(s) for s in streams if int(s) >= 0), default=-1)
        hi = max(hi, max(self._tables, default=-1))
        need_rows = hi + 1
        if self._dev is None or need_rows > self._dev_rows:
            # (re)build the whole mirror at a doubled row capacity — rare
            # (stream population growth), and O(rows) like one host rebuild
            cap = max(8, self._dev_rows)
            while cap < need_rows:
                cap *= 2
            full = self.page_table(range(cap))
            self._dev = jax.device_put(full)
            self._dev_rows = cap
            self._dirty.clear()
        elif self._dirty:
            rows = sorted(r for r in self._dirty if r < self._dev_rows)
            if rows:
                vals = jax.device_put(self.page_table(rows))
                idx = jax.device_put(np.asarray(rows, np.int32))
                self._dev = self._dev.at[idx].set(vals)
            self._dirty.clear()
        # -1 padding / unknown rows -> out-of-bounds under mode="fill" so
        # they gather the all--1 sentinel row
        sel = np.asarray([int(s) if 0 <= int(s) < self._dev_rows
                          else self._dev_rows for s in streams], np.int32)
        sel_dev = jax.device_put(sel)
        return jnp.take(self._dev, sel_dev, axis=0, mode="fill",
                        fill_value=-1)

    def check_invariants(self) -> None:
        """Every page is either free or mapped exactly once (leak/double-free
        detector for the allocator property tests)."""
        mapped = [p for t in self._tables.values() for p in t]
        seen = set(mapped) | set(self._free)
        assert len(mapped) + len(self._free) == self.num_pages, \
            f"leak: {len(mapped)} mapped + {len(self._free)} free != " \
            f"{self.num_pages}"
        assert len(seen) == self.num_pages, "page mapped twice or lost"


def paged_pool_bytes_per_page(pool) -> int:
    """Bytes one physical page costs across every leaf/layer of a paged pool
    (leaves shaped (layers, num_pages, page_size, ...))."""
    total = 0
    for leaf in jax.tree.leaves(pool):
        num_pages = leaf.shape[1]
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // num_pages
    return total
