"""The Multi-SPIN cell: one session object for the whole serving stack.

``MultiSpinCell`` owns the paper's full loop (Sec. III-A, Fig. 2) — plan,
draft, upload, batch-verify, feedback — plus the request lifecycle around
it: admission from a queue, per-request channel state, online acceptance
estimation, deadline-based straggler masking, retirement, and device
join/leave with automatic re-planning.  Compute is pluggable through
``repro.serving.backends`` (synthetic Bernoulli draws or a real JAX
``SpecEngine``), and the round schedule is selectable (``sync`` — the
paper's synchronized rounds — or ``pipelined`` — half-batches overlapping
draft/upload with verification, backend-agnostic).

Construction is one ``CellConfig`` (JSON-serializable) and one call::

    cfg = CellConfig(scheme="hete", max_batch=8)
    cell = MultiSpinCell(cfg)
    cell.submit(Request(rid=0, prompt_len=8, max_new_tokens=64,
                        alpha=0.8, T_S=0.009))
    rec = cell.step()          # one protocol round
    print(cell.summary())

The device list is never frozen: every round plans against the
scheduler's CURRENT active set, so retirements, joins, and drops can
never diverge from the controller's view.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.channel import (
    ChannelConfig,
    ChannelState,
    sample_average_gains,
    sample_rayleigh_gains,
    spectrum_efficiency,
)
from repro.core.controller import (
    AcceptanceEstimator,
    MultiSpinController,
    VerificationLatencyModel,
)
from repro.core.schemes import available_schemes, get_scheme
from repro.obs import trace
from repro.serving.backends import SyntheticBackend, VerificationBackend
from repro.serving.scheduler import Request, RoundScheduler

SCHEDULES = ("sync", "pipelined", "continuous")


@dataclasses.dataclass
class RoundRecord:
    """Full bookkeeping of one executed round (sync or pipelined half)."""

    lengths: np.ndarray
    bandwidth: np.ndarray
    accepted: np.ndarray          # realized accepted tokens (incl. bonus)
    t_ma: float
    t_ver: float
    t_round: float
    predicted_goodput: float
    realized_goodput: float
    active: np.ndarray            # device participation mask
    rids: np.ndarray | None = None  # request ids, scheduler order
    draft_width: int = 1          # multi-draft J the plan executed with
    # per-phase breakdown of the multi-access phase (telemetry satellite):
    # each is the MAX over deadline survivors of that phase alone, so the
    # phases overlap across devices and t_draft + t_upload >= t_ma in
    # general (equality when one straggler dominates both phases).
    # Server-drafting schemes fold their whole latency into t_draft.
    t_draft: float = 0.0
    t_upload: float = 0.0
    # backend memory snapshot taken AFTER this round retired its finished
    # requests (the occupancy the next admission decision sees); None for
    # backends without a pool_stats hook (synthetic draws)
    pool_stats: dict | None = None
    # POST-admission scheduler queue depth at record time — the depth the
    # next admission decision actually sees, matching /v1/stats (telemetry
    # previously re-read the live queue off-thread)
    queue_depth: int | None = None
    # verification-batch fill: participating devices / max_batch (continuous
    # batches are assembled from whichever streams are READY, so this is
    # the direct cost signal of dispatching early vs waiting for stragglers)
    batch_occupancy: float | None = None
    # continuous schedule only: streams drafted-and-waiting when this batch
    # dispatched (depth of the READY queue the assembler packs from)
    ready_depth: int | None = None
    # blocking device->host fetches the engine performed landing this round
    # (the compiled round path commits with exactly ONE — the packed token
    # emission); None for backends without host-transfer accounting
    n_host_syncs: int | None = None


@dataclasses.dataclass
class CellConfig:
    """Everything needed to stand up a Multi-SPIN cell, in one JSON-able
    record: scheme + controller search settings, wireless channel, the
    verification latency model, scheduler capacity, and lifecycle knobs."""

    scheme: str = "hete"
    scheme_params: dict = dataclasses.field(default_factory=dict)
    channel: ChannelConfig = dataclasses.field(default_factory=ChannelConfig)
    t_ver_fix: float = 0.035              # T_ver(K) = t_fix + K t_lin (eq. 7)
    t_ver_lin: float = 0.0177
    t_draft_fix: float | None = None      # Cen-SPIN server drafting per token:
    t_draft_lin: float | None = None      # None -> 0.15*t_ver_fix / 0.6*t_ver_lin
                                          # (A100-class SLM, Fig.-6 convention)
    L_max: int = 25
    L_fixed: int = 8
    n_phi: int = 40
    n_lam: int = 40
    max_batch: int = 8
    use_estimator: bool = False
    deadline_factor: float | None = None  # straggler deadline x median latency
    schedule: str = "sync"                # "sync" | "pipelined" | "continuous"
    # continuous schedule: verification batches allowed in flight at once
    # (1 forces the lockstep barrier; 2+ overlaps drafting with verify)
    max_inflight: int = 2
    seed: int = 0

    def __post_init__(self):
        if self.scheme not in available_schemes():
            raise ValueError(f"unknown scheme {self.scheme!r}; available: "
                             f"{', '.join(available_schemes())}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be one of {SCHEDULES}, "
                             f"got {self.schedule!r}")
        cls = get_scheme(self.scheme)
        if cls.capabilities.single_user_only and self.max_batch != 1:
            raise ValueError(
                f"scheme {self.scheme!r} is single-user (capability "
                f"'single_user_only'): it serves exactly one device, so "
                f"max_batch must be 1, got {self.max_batch}")
        if cls.capabilities.server_drafting and self.schedule == "pipelined":
            raise ValueError(
                f"scheme {self.scheme!r} drafts on the server (capability "
                f"'server_drafting'): the pipelined schedule would overlap "
                f"the server's own drafting with its own verification — "
                f"use schedule='sync'")
        if self.schedule == "continuous":
            if cls.capabilities.server_drafting:
                raise ValueError(
                    f"scheme {self.scheme!r} drafts on the server: continuous "
                    f"batching overlaps device drafting with in-flight "
                    f"verification, which a server-drafting scheme cannot — "
                    f"use schedule='sync'")
            if cls.capabilities.multi_draft:
                raise ValueError(
                    f"scheme {self.scheme!r} is multi-draft: token-tree "
                    f"verification runs lockstep rounds — use "
                    f"schedule='sync'")
            if self.deadline_factor is not None:
                raise ValueError(
                    "continuous batching makes deadline_factor redundant: "
                    "stragglers no longer block a cohort (batches are "
                    "assembled from whichever streams are ready), so "
                    "straggler masking must be None")
        if self.max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, "
                             f"got {self.max_inflight}")
        # validate scheme_params against the scheme's declared schema now,
        # not at first plan() (build_controller repeats this cheaply)
        self.build_controller()

    # -- serialization ---------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CellConfig":
        d = dict(d)
        if isinstance(d.get("channel"), dict):
            d["channel"] = ChannelConfig(**d["channel"])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "CellConfig":
        return cls.from_dict(json.loads(s))

    # -- factories -------------------------------------------------------

    def build_controller(self) -> MultiSpinController:
        t_draft = VerificationLatencyModel(
            self.t_draft_fix if self.t_draft_fix is not None
            else 0.15 * self.t_ver_fix,
            self.t_draft_lin if self.t_draft_lin is not None
            else 0.6 * self.t_ver_lin)
        return MultiSpinController(
            scheme=self.scheme, scheme_params=dict(self.scheme_params),
            q_tok_bits=self.channel.q_tok_bits,
            bandwidth_hz=self.channel.total_bandwidth_hz,
            t_ver_model=VerificationLatencyModel(self.t_ver_fix,
                                                 self.t_ver_lin),
            t_draft_model=t_draft,
            L_max=self.L_max, L_fixed=self.L_fixed,
            n_phi=self.n_phi, n_lam=self.n_lam,
            deadline_factor=self.deadline_factor)


class MultiSpinCell:
    """Session object running the Multi-SPIN protocol over a live request
    set with a pluggable verification backend.

    Each ``step()`` is one protocol round: assemble a ``CellObservation``
    over the scheduler's current active set, let the configured scheme
    plan it into a ``RoundPlan`` (draft lengths, bandwidth shares, draft
    width J), execute the round through the backend, and fold the results
    into the online acceptance estimator, channel state, and per-request
    accounting.  ``submit``/``leave`` mutate the live set between rounds;
    admission is gated by the backend's ``can_admit``.  Telemetry attaches
    through ``add_listener`` (``on_admit``/``on_reject``/``on_round``)
    without the cell importing it.  See docs/architecture.md for the full
    request lifecycle."""

    def __init__(self, config: CellConfig,
                 backend: VerificationBackend | None = None,
                 rng: np.random.Generator | None = None):
        self.config = config
        self.rng = rng if rng is not None else np.random.default_rng(config.seed)
        self.controller = config.build_controller()
        self.backend = backend if backend is not None else SyntheticBackend()
        self.scheduler = RoundScheduler(max_batch=config.max_batch)
        self.estimator = (AcceptanceEstimator(0) if config.use_estimator
                          else None)
        # Per-request channel state, row-aligned with scheduler.active.
        # Kept as explicit arrays (not one ChannelState) so rows can be
        # spliced on join/leave without redrawing surviving devices' fading
        # — which also preserves the legacy protocol's exact draw order.
        self.avg_gains = np.zeros(0)
        self.gains = np.zeros(0)
        self.rates = np.zeros(0)
        self.history: list[RoundRecord] = []
        self.rejected: list[Request] = []   # permanently-unservable requests
        # narrow observer surface (telemetry/gateway attach from outside;
        # the cell never imports them): objects with any of the optional
        # methods on_admit(requests) / on_reject(request) / on_round(record)
        self._listeners: list = []
        self._round_idx = 0
        self._pending_ver = 0.0      # pipelined: verification still in flight
        self._pending_rids: set[int] = set()   # whose tokens it verifies
        self._drained_ver = 0.0      # trailing in-flight work already drained
        self._pipe_parity = 0
        # continuous schedule: the event-driven simulated timeline.
        # _cont_ready maps rid -> draft bookkeeping for streams that have
        # dispatched drafting and become READY at ready_at; _cont_inflight
        # holds dispatched verification batches until their done_at.
        self._cont_now = 0.0
        self._cont_last_commit = 0.0
        self._cont_server_free = 0.0
        self._cont_ready: dict[int, dict] = {}
        self._cont_inflight: list[dict] = []

    # ------------------------------------------------------------------
    # observers (telemetry hook surface)
    # ------------------------------------------------------------------

    def add_listener(self, listener):
        """Attach an observer.  The cell calls the observer's OPTIONAL
        methods at lifecycle points — ``on_admit(requests)`` when requests
        enter the active set, ``on_reject(request)`` when a permanently
        unservable request is evicted, ``on_round(record)`` after every
        executed round (post-retirement, so scheduler stats are current).
        This keeps the dependency one-way: ``MetricsHub``/the gateway
        import the cell, never the reverse.  Returns the listener."""
        self._listeners.append(listener)
        return listener

    def remove_listener(self, listener):
        self._listeners.remove(listener)

    def _emit(self, event: str, *args):
        for listener in self._listeners:
            fn = getattr(listener, event, None)
            if fn is not None:
                fn(*args)

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> int:
        """Queue a request (device asking to join the cell)."""
        self.scheduler.submit(req)
        return req.rid

    def admit(self) -> list[Request]:
        """Fill free batch slots; provision channel + estimator rows for the
        devices that just joined.  Called automatically by ``step``.

        Backends with an ``can_admit`` hook (the paged engine) gate
        admission on true memory capacity: a join is refused only when the
        page pool cannot hold the request — it then waits in the queue."""
        # config.max_batch is the single source of truth for capacity (it can
        # be resized at runtime); the scheduler just mirrors it
        self.scheduler.max_batch = self.config.max_batch
        before = len(self.scheduler.active)
        # bind as each request is admitted (not after the loop) so every
        # can_admit query sees the capacity its predecessors consumed;
        # requests that can NEVER be served are evicted into self.rejected
        # rather than wedging the FIFO
        bind = getattr(self.backend, "bind", None)
        active = self.scheduler.admit(
            can_admit=getattr(self.backend, "can_admit", None),
            on_admit=(lambda r: bind([r])) if bind is not None else None,
            servable=getattr(self.backend, "servable", None),
            on_reject=self._reject)
        n_new = len(active) - before
        if n_new:
            new_avg = sample_average_gains(self.config.channel, n_new, self.rng)
            self.avg_gains = np.concatenate([self.avg_gains, new_avg])
            self.gains = np.concatenate(
                [self.gains, sample_rayleigh_gains(new_avg, self.rng)])
            self.rates = spectrum_efficiency(self.config.channel, self.gains)
            if self.estimator is not None:
                self.estimator.extend(n_new)
            self._emit("on_admit", active[before:])
        return active

    def _reject(self, req: Request):
        """Evict a permanently-unservable request (loudly: recorded AND
        surfaced to listeners, so the gateway can answer the client)."""
        self.rejected.append(req)
        self._emit("on_reject", req)

    def leave(self, rid: int) -> Request:
        """Permanent device failure / disconnect: drop the request and its
        channel + estimator rows; the next round re-plans for survivors."""
        idx = next((i for i, r in enumerate(self.scheduler.active)
                    if r.rid == rid), None)
        if idx is None:
            raise KeyError(f"rid {rid} not in the active set")
        req = self.scheduler.active.pop(idx)
        req.done = True
        keep = np.ones(len(self.scheduler.active) + 1, dtype=bool)
        keep[idx] = False
        self._drop_rows(keep)
        self._release([req])
        return req

    def _release(self, done: list[Request]):
        """Hand retired/departed requests back to the backend (paged engines
        return their streams' pages to the pool)."""
        release = getattr(self.backend, "release", None)
        if done and release is not None:
            release(done)

    def _drop_rows(self, keep: np.ndarray):
        """Splice out the channel + estimator rows of departing devices."""
        self.avg_gains = self.avg_gains[keep]
        self.gains = self.gains[keep]
        self.rates = self.rates[keep]
        if self.estimator is not None:
            self.estimator.keep(keep)

    def _retire(self, active_reqs: list[Request], accepted: np.ndarray,
                round_time: float, participated: np.ndarray | None = None):
        self.scheduler.complete_round(accepted, round_time,
                                      participated=participated)
        keep = np.array([not r.done for r in active_reqs], dtype=bool)
        if not keep.all():
            self._drop_rows(keep)
            self._release([r for r in active_reqs if r.done])

    # ------------------------------------------------------------------
    # channel + planning view
    # ------------------------------------------------------------------

    def _refade(self):
        """New small-scale block-fading realization, same large-scale gains."""
        self.gains = sample_rayleigh_gains(self.avg_gains, self.rng)
        self.rates = spectrum_efficiency(self.config.channel, self.gains)

    @property
    def channel(self) -> ChannelState:
        """Current fading block as a ``ChannelState`` view."""
        return ChannelState(cfg=self.config.channel, avg_gains=self.avg_gains,
                            gains=self.gains, rates=self.rates)

    def load_channel(self, state: ChannelState):
        """Install an externally measured fading block for the active set
        (row-aligned).  Benchmarks replay a recorded ``ChannelState`` so a
        cell-planned round sees bit-identical rates to a direct solve."""
        active = self.admit()
        rates = np.asarray(state.rates, dtype=np.float64)
        if len(rates) != len(active):
            raise ValueError(f"channel state holds {len(rates)} devices, "
                             f"cell has {len(active)} active")
        self.avg_gains = np.asarray(state.avg_gains, dtype=np.float64).copy()
        self.gains = np.asarray(state.gains, dtype=np.float64).copy()
        self.rates = rates.copy()

    def planning_alphas(self, active_reqs: list[Request]) -> np.ndarray:
        """Acceptance rates the controller plans with: online estimates when
        enabled, else the requests' declared task profiles."""
        if self.estimator is not None:
            return self.estimator.alpha_hat
        return np.array([r.alpha for r in active_reqs])

    def _planning_view(self, refade: bool):
        active_reqs = self.admit()
        if not active_reqs:
            raise RuntimeError("plan() with no active requests")
        if refade:
            self._refade()
        t_slm = np.array([r.T_S for r in active_reqs])
        return self.planning_alphas(active_reqs), t_slm

    def plan(self, refade: bool = True):
        """Admit + refade + solve draft control for the current active set
        WITHOUT executing the round.  Analytic benchmarks and sweeps use
        this to query the configured scheme at a live channel realization
        (``refade=False`` plans at the installed fading block — see
        ``load_channel``)."""
        alphas, t_slm = self._planning_view(refade)
        return self.controller.plan(alphas, t_slm, self.rates)

    def plan_pipelined(self, refade: bool = True) -> dict:
        """Two-half-batch pipelined plan for the current active set:
        ``{goodput, period, halves: [RoundPlan]}`` (steady-state period
        ``max(T_ma, T_ver)`` per half — see ``core.beyond.pipelined_plan``)."""
        alphas, t_slm = self._planning_view(refade)
        return self.controller.plan_pipelined(alphas, t_slm, self.rates)

    # ------------------------------------------------------------------
    # the round loop
    # ------------------------------------------------------------------

    def _deadline_mask(self, per_dev_lat: np.ndarray) -> np.ndarray:
        """Straggler masking, identical for both schedules: devices whose
        draft+upload exceeds ``deadline_factor`` x the (participating-set)
        median are dropped from this round's verification.  All-dropped
        degenerates to all-kept (the round must produce something)."""
        active = np.ones(len(per_dev_lat), dtype=bool)
        if self.config.deadline_factor is not None:
            deadline = self.config.deadline_factor * np.median(per_dev_lat)
            active = per_dev_lat <= deadline
            if not active.any():
                active[:] = True
        return active

    def step(self, key=None) -> RoundRecord | None:
        """Run one protocol round (or one pipelined half-round).  Returns
        ``None`` when the cell is idle (no queued or active requests).

        With a tracer installed (``repro.obs.trace``) each round executes
        under a ``cell.step`` span whose args carry the round index, the
        participating rids, and the SIMULATED phase breakdown
        (t_draft/t_upload/t_ver/t_round) — per-request trace correlation
        keys off the rids, and summing the phase args across spans
        reproduces ``summary()``'s seconds_draft/upload/verify."""
        active_reqs = self.admit()
        if not active_reqs:
            # idle: in-flight work (pipelined trailing verification, or
            # continuous batches whose streams all departed) completes while
            # nothing overlaps it — drain it so a later resume does not
            # overlap work that already finished
            if self._pending_ver:
                # bill the drain to the scheduler too, so stats.goodput
                # agrees with summary() once the session completes
                self.scheduler.stats.wall_time += self._pending_ver
                self.scheduler.clock += self._pending_ver
            self._drained_ver += self._pending_ver
            self._pending_ver = 0.0
            self._pending_rids = set()
            if self._cont_inflight:
                self._drain_continuous()
            return None
        args = None if trace.active() is None else {
            "schedule": self.config.schedule, "scheme": self.config.scheme}
        with trace.span("cell.step", cat="cell", args=args) as sp:
            if self.config.schedule == "pipelined":
                rec = self._step_pipelined(active_reqs, key)
            elif self.config.schedule == "continuous":
                rec = self._step_continuous(active_reqs, key)
            else:
                rec = self._step_sync(active_reqs, key)
            if sp is not trace.NULL_SPAN:
                sp.set(round=len(self.history) - 1,
                       rids=[int(r) for r in rec.rids],
                       t_draft=rec.t_draft, t_upload=rec.t_upload,
                       t_ver=rec.t_ver, t_round=rec.t_round)
        return rec

    def _latency_components(self, plan, lengths: np.ndarray,
                            t_slm: np.ndarray, rates: np.ndarray):
        """``(draft, upload)`` per-device latency split: L_k T_k^S on-device
        drafting and L_k Q/(B_k r_k) uplink.  Server-drafting schemes
        (Cen-SPIN) provide their own per-device model and have no uplink to
        straggle on — their whole latency counts as the draft phase.
        Telemetry wants the phases separately (DiP-SD-style round
        breakdowns); the round loop sums them."""
        if plan.per_device_latency is not None:
            draft = np.asarray(plan.per_device_latency, dtype=np.float64)
            return draft, np.zeros_like(draft)
        bandwidth = np.asarray(plan.bandwidth, dtype=np.float64)
        lengths = np.asarray(lengths, dtype=np.float64)
        draft = lengths * np.asarray(t_slm, dtype=np.float64)
        upload = lengths * self.controller.q_tok_bits \
            / np.maximum(bandwidth * rates, 1e-9)
        return draft, upload

    def _per_device_latency(self, plan, lengths: np.ndarray,
                            t_slm: np.ndarray,
                            rates: np.ndarray) -> np.ndarray:
        """Total draft+upload latency per device (deadline masking input)."""
        draft, upload = self._latency_components(plan, lengths, t_slm, rates)
        return draft + upload

    def _pool_stats(self) -> dict | None:
        """Backend memory snapshot (paged engines: page-pool occupancy),
        None when the backend has no ``pool_stats`` hook."""
        ps = getattr(self.backend, "pool_stats", None)
        return ps() if callable(ps) else None

    def _host_syncs(self) -> int | None:
        """Blocking device->host fetches the backend's engine performed for
        the round just landed; None without host-transfer accounting."""
        v = getattr(self.backend, "last_round_host_syncs", None)
        return int(v) if v is not None else None

    def _verify(self, plan, lengths, requests, key, mask) -> np.ndarray:
        """Backend verification call; the multi-draft width J rides along
        only when the plan asks for it (custom single-draft backends keep
        the narrow signature)."""
        kw = {} if plan.draft_width == 1 else {"draft_width": plan.draft_width}
        return np.asarray(
            self.backend.verify(lengths, requests, self.rng, key=key,
                                mask=mask, **kw), dtype=np.int64)

    def _step_sync(self, active_reqs: list[Request], key=None) -> RoundRecord:
        # --- step 1: system configuration ---
        self._refade()
        t_slm = np.array([r.T_S for r in active_reqs])
        with trace.span("cell.plan", cat="cell"):
            plan = self.controller.plan(self.planning_alphas(active_reqs),
                                        t_slm, self.rates)
        lengths = np.asarray(plan.lengths, dtype=np.int64)
        bandwidth = np.asarray(plan.bandwidth, dtype=np.float64)

        # --- steps 2-3: drafting + upload latency (straggler-limited) ---
        draft_lat, upload_lat = self._latency_components(plan, lengths, t_slm,
                                                         self.rates)
        per_dev_lat = draft_lat + upload_lat
        active = self._deadline_mask(per_dev_lat)
        t_ma = float(np.max(per_dev_lat[active]))

        # --- step 4: batched verification (pluggable backend) ---
        K_active = int(active.sum())
        t_ver = (float(plan.t_ver) if plan.t_ver is not None
                 else self.controller.t_ver_model(K_active))
        with trace.span("cell.verify", cat="cell"):
            accepted = self._verify(plan, lengths, active_reqs, key, active)
        accepted = np.where(active, accepted, 0)

        # --- step 5: feedback / estimator update (active devices only:
        # a deadline-dropped device reported nothing, not a rejection) ---
        if self.estimator is not None:
            self.estimator.update(np.maximum(accepted - 1, 0), lengths,
                                  mask=active)

        t_round = t_ma + t_ver
        rec = RoundRecord(
            lengths=lengths, bandwidth=bandwidth, accepted=accepted,
            t_ma=t_ma, t_ver=t_ver, t_round=t_round,
            predicted_goodput=plan.goodput,
            realized_goodput=float(np.sum(accepted) / t_round),
            active=active,
            rids=np.array([r.rid for r in active_reqs]),
            draft_width=int(plan.draft_width),
            t_draft=float(np.max(draft_lat[active])),
            t_upload=float(np.max(upload_lat[active])),
            queue_depth=len(self.scheduler.queue),
            batch_occupancy=float(active.sum()) / self.config.max_batch,
        )
        self.history.append(rec)
        self._round_idx += 1
        self._retire(active_reqs, accepted, t_round)
        rec.pool_stats = self._pool_stats()
        rec.n_host_syncs = self._host_syncs()
        self._emit("on_round", rec)
        return rec

    def _step_pipelined(self, active_reqs: list[Request],
                        key=None) -> RoundRecord:
        """Beyond-paper pipelined schedule: while one half-batch drafts and
        uploads, the server verifies the other half; wall-clock per half-round
        is max(T_ma(current half), T_ver(other half)).  Works with any
        backend (the legacy ``run_pipelined`` was a synthetic-only fork)."""
        K = len(active_reqs)
        self._refade()
        alphas_all = self.planning_alphas(active_reqs)
        t_slm_all = np.array([r.T_S for r in active_reqs])
        order = np.argsort([r.alpha for r in active_reqs], kind="stable")
        halves = [order[0::2], order[1::2]]
        h = halves[self._pipe_parity % 2]
        if len(h) == 0:
            h = halves[0]
        self._pipe_parity += 1

        with trace.span("cell.plan", cat="cell"):
            plan = self.controller.plan(alphas_all[h], t_slm_all[h],
                                        self.rates[h])
        lengths_h = np.asarray(plan.lengths, dtype=np.int64)
        bandwidth_h = np.asarray(plan.bandwidth, dtype=np.float64)
        draft_h, upload_h = self._latency_components(plan, lengths_h,
                                                     t_slm_all[h],
                                                     self.rates[h])
        per_dev = draft_h + upload_h
        # straggler masking within the half — same policy as the sync
        # schedule (this previously ignored deadline_factor entirely)
        ok_h = self._deadline_mask(per_dev)
        t_ma = float(np.max(per_dev[ok_h]))
        h_rids = {active_reqs[j].rid for j in h}
        if self._pending_rids & h_rids:
            # a device in this half still awaits its own verification
            # (K == 1, or churn reshuffled the halves): it cannot draft
            # before that result returns, so this step runs serial
            step_time = t_ma + self._pending_ver
        else:
            # overlap with the OTHER half's verification still in flight
            step_time = max(t_ma, self._pending_ver)
        # like the sync schedule, verification is billed for the deadline
        # SURVIVORS only (dropped devices uploaded nothing to verify)
        t_ver = (float(plan.t_ver) if plan.t_ver is not None
                 else self.controller.t_ver_model(int(ok_h.sum())))
        self._pending_ver = t_ver
        self._pending_rids = h_rids

        with trace.span("cell.verify", cat="cell"):
            accepted_h = self._verify(plan, lengths_h,
                                      [active_reqs[j] for j in h], key, ok_h)
        accepted_h = np.where(ok_h, accepted_h, 0)

        participated = np.zeros(K, dtype=bool)
        participated[h] = True                 # drafted this half-round
        mask = np.zeros(K, dtype=bool)
        mask[h] = ok_h                         # ... and met the deadline
        accepted = np.zeros(K, dtype=np.int64)
        accepted[h] = accepted_h
        lengths = np.zeros(K, dtype=np.int64)
        lengths[h] = lengths_h
        bandwidth = np.zeros(K, dtype=np.float64)
        bandwidth[h] = bandwidth_h
        if self.estimator is not None:
            self.estimator.update(np.maximum(accepted - 1, 0),
                                  np.maximum(lengths, 1), mask=mask)

        rec = RoundRecord(
            lengths=lengths, bandwidth=bandwidth, accepted=accepted,
            t_ma=t_ma, t_ver=t_ver, t_round=step_time,
            predicted_goodput=plan.goodput,
            realized_goodput=float(np.sum(accepted) / step_time),
            active=mask,
            rids=np.array([r.rid for r in active_reqs]),
            draft_width=int(plan.draft_width),
            t_draft=float(np.max(draft_h[ok_h])),
            t_upload=float(np.max(upload_h[ok_h])),
            queue_depth=len(self.scheduler.queue),
            batch_occupancy=float(mask.sum()) / self.config.max_batch,
        )
        self.history.append(rec)
        self._round_idx += 1
        self._retire(active_reqs, accepted, step_time,
                     participated=participated)
        rec.pool_stats = self._pool_stats()
        rec.n_host_syncs = self._host_syncs()
        self._emit("on_round", rec)
        return rec

    def _step_continuous(self, active_reqs: list[Request],
                         key=None) -> RoundRecord:
        """Continuous batching: per-stream rounds with no cohort barrier.

        Event-driven simulated timeline, one committed verification batch
        per ``step``:

          1. every stream not already drafting/in-flight dispatches its next
             draft NOW (planned by the configured scheme over exactly that
             subset) and becomes READY at ``now + draft + upload``;
          2. while fewer than ``max_inflight`` batches are in flight, the
             (sequential) verification server packs a batch from whichever
             streams are READY when it frees up — at most ``max_batch``,
             earliest-ready first — and dispatches it; with a
             ``ContinuousBackend`` the dispatch is genuinely asynchronous
             (``verify_async``; results land in step 3);
          3. the earliest-finishing batch commits: the clock jumps to its
             completion, its streams' tokens are accepted, and those streams
             re-enter drafting on the next step.

        ``t_round`` is the inter-commit gap, so ``summary()`` wall-clock
        telescopes to the timeline's end exactly like the other schedules.
        A slow drafter now delays only its own stream — the batch occupancy
        / goodput trade is visible per record (``batch_occupancy``,
        ``ready_depth``)."""
        rid_to_i = {r.rid: i for i, r in enumerate(active_reqs)}
        # drop READY bookkeeping of departed streams (leave() mid-draft)
        self._cont_ready = {rid: e for rid, e in self._cont_ready.items()
                            if rid in rid_to_i}
        busy = set(self._cont_ready)
        for b in self._cont_inflight:
            busy.update(b["rids"])

        # --- 1. dispatch drafting for every idle stream -----------------
        starters = [i for i, r in enumerate(active_reqs) if r.rid not in busy]
        if starters:
            self._refade()
            alphas = self.planning_alphas(active_reqs)
            t_slm = np.array([r.T_S for r in active_reqs])
            sub = np.asarray(starters)
            with trace.span("cell.plan", cat="cell"):
                plan = self.controller.plan(alphas[sub], t_slm[sub],
                                            self.rates[sub])
            lengths = np.asarray(plan.lengths, dtype=np.int64)
            bw = np.asarray(plan.bandwidth, dtype=np.float64)
            draft, upload = self._latency_components(
                plan, lengths, t_slm[sub], self.rates[sub])
            for j, i in enumerate(starters):
                self._cont_ready[active_reqs[i].rid] = {
                    "ready_at": self._cont_now + draft[j] + upload[j],
                    "length": int(lengths[j]), "bw": float(bw[j]),
                    "draft": float(draft[j]), "upload": float(upload[j]),
                    "predicted": float(plan.goodput),
                }

        # --- 2. assemble + dispatch verification batches ----------------
        while (self._cont_ready
               and len(self._cont_inflight) < self.config.max_inflight):
            t_start = max(min(e["ready_at"]
                              for e in self._cont_ready.values()),
                          self._cont_server_free)
            members = sorted(
                (rid for rid, e in self._cont_ready.items()
                 if e["ready_at"] <= t_start),
                key=lambda rid: (self._cont_ready[rid]["ready_at"], rid),
            )[:self.config.max_batch]
            entries = [self._cont_ready.pop(rid) for rid in members]
            reqs = [active_reqs[rid_to_i[rid]] for rid in members]
            lens = np.array([e["length"] for e in entries], dtype=np.int64)
            t_ver = self.controller.t_ver_model(len(members))
            args = None if trace.active() is None else {
                "K": len(members), "rids": [int(r) for r in members],
                "ready_depth": len(self._cont_ready)}
            with trace.span("cell.dispatch", cat="cell", args=args):
                verify_async = getattr(self.backend, "verify_async", None)
                if verify_async is not None:
                    handle, accepted = verify_async(lens, reqs, self.rng,
                                                    key=key), None
                else:
                    handle, accepted = None, np.asarray(
                        self.backend.verify(lens, reqs, self.rng, key=key),
                        dtype=np.int64)
            self._cont_server_free = t_start + t_ver
            self._cont_inflight.append({
                "rids": list(members), "lengths": lens,
                "bw": np.array([e["bw"] for e in entries]),
                "t_ver": float(t_ver), "done_at": t_start + t_ver,
                "t_draft": max(e["draft"] for e in entries),
                "t_upload": max(e["upload"] for e in entries),
                "t_ma": max(e["draft"] + e["upload"] for e in entries),
                "predicted": float(np.mean([e["predicted"]
                                            for e in entries])),
                "ready_depth": len(self._cont_ready),
                "handle": handle, "accepted": accepted,
            })

        # --- 3. commit the earliest-finishing batch ---------------------
        batch = min(self._cont_inflight, key=lambda b: b["done_at"])
        self._cont_inflight.remove(batch)
        self._cont_now = batch["done_at"]
        t_round = self._cont_now - self._cont_last_commit
        self._cont_last_commit = self._cont_now
        if batch["accepted"] is None:
            with trace.span("cell.verify", cat="cell"):
                acc_members = np.asarray(self.backend.collect(batch["handle"]),
                                         dtype=np.int64)
        else:
            acc_members = batch["accepted"]

        K = len(active_reqs)
        accepted = np.zeros(K, dtype=np.int64)
        lengths = np.zeros(K, dtype=np.int64)
        bandwidth = np.zeros(K, dtype=np.float64)
        participated = np.zeros(K, dtype=bool)
        for j, rid in enumerate(batch["rids"]):
            i = rid_to_i.get(rid)
            if i is None:           # departed mid-verify: tokens discarded
                continue
            accepted[i] = acc_members[j]
            lengths[i] = batch["lengths"][j]
            bandwidth[i] = batch["bw"][j]
            participated[i] = True
        if self.estimator is not None:
            self.estimator.update(np.maximum(accepted - 1, 0),
                                  np.maximum(lengths, 1), mask=participated)

        rec = RoundRecord(
            lengths=lengths, bandwidth=bandwidth, accepted=accepted,
            t_ma=float(batch["t_ma"]), t_ver=batch["t_ver"],
            t_round=float(t_round),
            predicted_goodput=batch["predicted"],
            realized_goodput=float(np.sum(accepted) / t_round)
            if t_round > 0 else 0.0,
            active=participated,
            rids=np.array([r.rid for r in active_reqs]),
            t_draft=float(batch["t_draft"]),
            t_upload=float(batch["t_upload"]),
            queue_depth=len(self.scheduler.queue),
            batch_occupancy=len(batch["rids"]) / self.config.max_batch,
            ready_depth=int(batch["ready_depth"]),
        )
        self.history.append(rec)
        self._round_idx += 1
        self._retire(active_reqs, accepted, float(t_round),
                     participated=participated)
        rec.pool_stats = self._pool_stats()
        rec.n_host_syncs = self._host_syncs()
        self._emit("on_round", rec)
        return rec

    def _drain_continuous(self):
        """Every stream departed with verification batches still in flight:
        land them (returning engine results to the host) and bill the
        trailing timeline so ``summary()`` and the scheduler agree."""
        t_end = max(b["done_at"] for b in self._cont_inflight)
        for b in self._cont_inflight:
            if b["accepted"] is None:
                self.backend.collect(b["handle"])
        extra = max(0.0, t_end - self._cont_last_commit)
        self.scheduler.stats.wall_time += extra
        self.scheduler.clock += extra
        self._drained_ver += extra
        self._cont_inflight = []
        self._cont_ready = {}
        self._cont_now = max(self._cont_now, t_end)
        self._cont_last_commit = self._cont_now

    # ------------------------------------------------------------------
    # driving loops
    # ------------------------------------------------------------------

    def run(self, n_rounds: int | None = None) -> dict:
        """Run up to ``n_rounds`` rounds (or until idle when ``None``)."""
        i = 0
        while n_rounds is None or i < n_rounds:
            if self.step() is None:
                break
            i += 1
        return self.summary()

    def drain(self) -> dict:
        """Run until every submitted request has retired."""
        return self.run(None)

    @property
    def idle(self) -> bool:
        return self.scheduler.idle

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------

    def summary(self) -> dict:
        """Protocol-level accounting over all executed rounds.

        Goodput has TWO legitimate views and this is the one place exposing
        both (telemetry reports them side by side rather than two subtly
        different numbers from two code paths):

        * ``goodput_committed`` (alias ``goodput``) — every token the
          protocol committed (``RoundRecord.accepted``, bonus included,
          even past a request's ``max_new_tokens`` budget in its final
          round) over the protocol wall-clock INCLUDING the pipelined
          trailing-verification drain.  The paper's protocol-efficiency
          view.
        * ``goodput_capped`` — ``scheduler.stats``' per-request view: each
          request stops counting at its ``max_new_tokens`` budget, over the
          scheduler's billed wall time (idle drains are billed there too,
          so for a completed session the denominators agree and any gap is
          purely the final-round overshoot in the numerator).  The
          user-visible serving throughput.

        ``seconds_draft``/``seconds_upload``/``seconds_verify`` sum the
        per-phase maxima across rounds (phases overlap across devices, so
        draft+upload >= the multi-access wall share)."""
        total_tokens = float(sum(np.sum(r.accepted) for r in self.history))
        total_time = float(sum(r.t_round for r in self.history))
        total_time += self._pending_ver + self._drained_ver
        goodput = total_tokens / total_time if total_time else 0.0
        out = {
            "rounds": len(self.history),
            "tokens": total_tokens,
            "seconds": total_time,
            "goodput": goodput,
            "goodput_committed": goodput,
            "goodput_capped": self.scheduler.stats.goodput,
            "seconds_draft": float(sum(r.t_draft for r in self.history)),
            "seconds_upload": float(sum(r.t_upload for r in self.history)),
            "seconds_verify": float(sum(r.t_ver for r in self.history)),
        }
        if self.history:
            out["mean_predicted_goodput"] = float(np.mean(
                [r.predicted_goodput for r in self.history]))
        return out

    # ------------------------------------------------------------------
    # fault tolerance: checkpoint/restore of the protocol state
    # ------------------------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "round_idx": self._round_idx,
            "avg_gains": np.asarray(self.avg_gains).copy(),
            "alpha_hat": (self.estimator.alpha_hat
                          if self.estimator is not None else None),
        }

    def load_state_dict(self, state: dict):
        self.admit()
        avg = np.asarray(state["avg_gains"], dtype=np.float64)
        if len(avg) != len(self.scheduler.active):
            raise ValueError(
                f"checkpoint holds {len(avg)} devices, cell has "
                f"{len(self.scheduler.active)} active")
        self._round_idx = state["round_idx"]
        self.avg_gains = avg.copy()
        self._refade()
        if state.get("alpha_hat") is not None and self.estimator is not None:
            self.estimator.alpha_hat = state["alpha_hat"]
