"""Single-model serving engine (non-speculative baseline).

Used for (a) the Cen-SPIN / vanilla-AR baselines of Fig. 6, (b) decode-path
benchmarking, and (c) as the verification-only server facade when devices
draft remotely.  The speculative engine composes two of these in
``spec_engine.py``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model


@dataclasses.dataclass
class EngineState:
    pending: jax.Array       # (B,) last committed token not yet in cache
    pos: jax.Array           # (B,) cache fill level
    committed: list


class ServingEngine:
    def __init__(self, cfg: ModelConfig, max_len: int = 512,
                 cache_dtype=jnp.float32):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.params = None
        self.cache = None

    def init_params(self, key):
        self.params = self.model.init(key)
        return self.params

    def start(self, prompts: jax.Array) -> EngineState:
        B, M = prompts.shape
        self.cache = self.model.init_cache(B, self.max_len, self.cache_dtype)
        _, self.cache, _ = self.model.prefill(self.params, prompts[:, :-1],
                                              self.cache)
        return EngineState(pending=prompts[:, -1],
                           pos=jnp.full((B,), M - 1, jnp.int32),
                           committed=[list(np.asarray(prompts[b]))
                                      for b in range(B)])

    def decode_step(self, state: EngineState, key, temperature: float = 1.0):
        """One autoregressive token per stream."""
        logits, self.cache = self.model.forward_window(
            self.params, state.pending[:, None], self.cache, state.pos)
        if temperature == 0:
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                key, logits[:, 0].astype(jnp.float32) / temperature,
                axis=-1).astype(jnp.int32)
        out = np.asarray(nxt)
        for b in range(len(out)):
            state.committed[b].append(int(out[b]))
        return EngineState(pending=nxt, pos=state.pos + 1,
                           committed=state.committed), nxt

    def generate(self, prompts: jax.Array, n_tokens: int, key,
                 temperature: float = 1.0) -> list:
        state = self.start(prompts)
        keys = jax.random.split(key, n_tokens)
        for t in range(n_tokens):
            state, _ = self.decode_step(state, keys[t], temperature)
        return state.committed
