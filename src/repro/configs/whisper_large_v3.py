"""whisper-large-v3 — encoder-decoder audio model [arXiv:2212.04356].

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (batch, encoder_seq_len, d_model).  Decode shapes
exercise the text decoder (self-attn KV cache + fixed cross-attn KV).
"""

from .base import ModelConfig, register

WHISPER_LARGE_V3 = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,            # decoder layers
    num_encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    activation="gelu",
    norm="layernorm",
    rope_theta=None,          # whisper uses learned positions
    encoder_seq_len=1500,
))
