"""qwen2.5-3b — dense, GQA kv=2, QKV bias [hf:Qwen/Qwen2.5; hf]."""

from .base import ModelConfig, register

QWEN25_3B = register(ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1000000.0,
))
