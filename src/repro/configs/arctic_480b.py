"""arctic-480b — MoE 128e top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base]."""

from .base import ModelConfig, register

ARCTIC_480B = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,              # dense-residual path
    vocab_size=32000,
    activation="swiglu",
    rope_theta=10000.0,
    num_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
))
