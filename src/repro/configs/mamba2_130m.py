"""mamba2-130m — attention-free SSD (state-space duality) [arXiv:2405.21060]."""

from .base import ModelConfig, register

MAMBA2_130M = register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,             # SSD heads: d_inner / ssm_head_dim
    num_kv_heads=24,
    d_ff=0,                   # attention-free, no FFN (per assignment)
    vocab_size=50280,
    activation="swiglu",
    rope_theta=None,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
))
