"""paligemma-3b — VLM: SigLIP patch frontend (stub) + Gemma-2B backbone
[arXiv:2407.07726; hf].

The modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings of shape (batch, num_patches, d_model) that the
backbone prepends to the text sequence.
"""

from .base import ModelConfig, register

PALIGEMMA_3B = register(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    activation="geglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    num_patches=256,
))
