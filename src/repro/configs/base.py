"""Architecture config system and the assigned input-shape sets.

Every assigned architecture (plus the paper's own SLM/LLM pairs) is expressed
as a ``ModelConfig``; ``repro.models.model_zoo.build_model`` dispatches on
``family``.  ``smoke()`` derives a CPU-runnable reduced config of the same
family for tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

_REGISTRY: dict[str, "ModelConfig"] = {}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # defaults to d_model // num_heads
    activation: str = "swiglu"
    norm: str = "rmsnorm"
    qkv_bias: bool = False
    rope_theta: Optional[float] = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    dense_residual: bool = False
    first_k_dense: int = 0            # leading dense layers in a MoE stack
    capacity_factor: float = 1.25
    # --- SSM (Mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 64
    # --- hybrid (Zamba2): shared attn block every `hybrid_group` ssm layers ---
    hybrid_group: int = 6
    # --- encoder-decoder (Whisper) ---
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500       # precomputed frame embeddings (stub frontend)
    # --- VLM (PaliGemma) ---
    num_patches: int = 0              # prepended patch embeddings (stub frontend)
    # --- numerics / lowering ---
    scan_unroll: bool = False      # unroll layer scans (cost-probe lowering)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_sub_quadratic(self) -> bool:
        """Whether the arch supports the long_500k shape (SSM state instead of
        quadratic-cost full-attention KV growth in compute)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are (or contain) autoregressive decoders

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4 if self.family == "hybrid" else 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=257,
            encoder_seq_len=12 if self.num_encoder_layers else 1500,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            num_patches=8 if self.num_patches else 0,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
        )
        if self.num_experts:
            kw.update(num_experts=4, top_k=min(self.top_k, 2), moe_d_ff=32,
                      shared_d_ff=64 if self.num_shared_experts else 0)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
        if self.family == "hybrid":
            kw.update(hybrid_group=2)
        return self.replace(**kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_architectures() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = [
    "phi4-mini-3.8b", "gemma-7b", "qwen2.5-3b", "deepseek-7b", "paligemma-3b",
    "zamba2-2.7b", "moonshot-v1-16b-a3b", "arctic-480b", "whisper-large-v3",
    "mamba2-130m",
]

PAPER_ARCHS = ["tinyllama-1.1b", "llama2-7b", "qwen3.5-0.8b", "qwen3.5-27b"]


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """The assigned shape cells that actually lower for this arch.

    long_500k requires sub-quadratic attention (DESIGN.md §Arch-applicability);
    full-attention archs record the cell as skipped.
    """
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_sub_quadratic:
        out.append("long_500k")
    return out


def _ensure_loaded():
    # importing the config modules populates the registry
    from . import archs  # noqa: F401
