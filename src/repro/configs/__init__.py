"""Architecture configs: 10 assigned archs + the paper's model pairs."""

from .base import (  # noqa: F401
    ASSIGNED_ARCHS,
    PAPER_ARCHS,
    SHAPES,
    InputShape,
    ModelConfig,
    applicable_shapes,
    get_config,
    list_architectures,
)
