"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

Simplification (DESIGN.md §Arch-applicability): the shared transformer block
(full attention + MLP, one set of weights) is applied after every
``hybrid_group`` Mamba2 layers; each invocation owns its KV cache.  Zamba2's
per-invocation LoRA adapters are folded into the shared weights.
"""

from .base import ModelConfig, register

ZAMBA2_2P7B = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    activation="swiglu",
    rope_theta=10000.0,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_group=6,
))
