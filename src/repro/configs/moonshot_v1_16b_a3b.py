"""moonshot-v1-16b-a3b — MoE 64e top-6 (Moonlight / kimi)
[hf:moonshotai/Moonlight-16B-A3B; hf]."""

from .base import ModelConfig, register

MOONSHOT_16B = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=11264,             # dense layers / shared path
    vocab_size=163840,
    activation="swiglu",
    rope_theta=50000.0,
    num_experts=64,
    top_k=6,
    moe_d_ff=1408,
    num_shared_experts=2,
    shared_d_ff=2816,
    first_k_dense=1,
))
