"""The paper's own SLM/LLM pairs (Sec. VI-A1).

TinyLlama-1.1B + Llama-2-7B, and Qwen3.5-0.8B + Qwen3.5-27B.  The Qwen3.5
checkpoints are not publicly released; dimensions follow Qwen-family scaling
(DESIGN.md §Assumptions).  llama2-7b is dimension-identical to the original.
"""

from .base import ModelConfig, register

TINYLLAMA_1P1B = register(ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    activation="swiglu",
))

LLAMA2_7B = register(ModelConfig(
    name="llama2-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    activation="swiglu",
))

QWEN35_0P8B = register(ModelConfig(
    name="qwen3.5-0.8b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1000000.0,
))

QWEN35_27B = register(ModelConfig(
    name="qwen3.5-27b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1000000.0,
))
