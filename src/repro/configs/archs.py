"""Import side-effect module: populates the architecture registry."""

from . import (  # noqa: F401
    arctic_480b,
    deepseek_7b,
    gemma_7b,
    mamba2_130m,
    moonshot_v1_16b_a3b,
    paligemma_3b,
    paper_pairs,
    phi4_mini_3p8b,
    qwen25_3b,
    whisper_large_v3,
    zamba2_2p7b,
)
