"""Training driver CLI.

Local mode (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50

Production mode lowers the full config under the production mesh and (on a
real pod) executes; on this CPU container use --dry-run, which delegates to
launch.dryrun for lower+compile only.
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, local devices")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile under the production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch.dryrun import run_cell
        res = run_cell(args.arch, "train_4k", args.multi_pod, force=True)
        print(res.get("status"), res.get("roofline", res.get("error")))
        return

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.models import build_model, has_prefix_embeds
    from repro.training import (
        DataConfig,
        OptimizerConfig,
        SyntheticLMDataset,
        init_optimizer,
        make_train_step,
    )

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: {model.num_params(params) / 1e6:.1f}M params")

    opt_cfg = OptimizerConfig(warmup_steps=10, decay_steps=args.steps)
    opt_state = init_optimizer(opt_cfg, params)
    data = SyntheticLMDataset(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=args.seq_len,
                                         global_batch=args.batch))
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches,
                                      has_prefix=has_prefix_embeds(cfg)))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.time()
    for step in range(args.steps):
        batch = {"tokens": jnp.asarray(data.batch(step)["tokens"])}
        if has_prefix_embeds(cfg):
            from repro.models.model_zoo import prefix_len
            batch["prefix_embeds"] = jnp.zeros(
                (args.batch, prefix_len(cfg), cfg.d_model))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.0f}s)")
        if mgr and step and step % 50 == 0:
            mgr.save_async(step, {"params": params, "opt": opt_state})
    if mgr:
        mgr.wait()
        mgr.save(args.steps, {"params": params, "opt": opt_state})


if __name__ == "__main__":
    main()
