"""Multi-SPIN live serving gateway CLI.

  PYTHONPATH=src python -m repro.launch.gateway --port 8011         # synthetic
  PYTHONPATH=src python -m repro.launch.gateway --backend engine \
      --arch qwen2.5-3b --smoke-arch --scheme hete

Stands up a ``MultiSpinCell`` and serves it live over HTTP/1.1 + SSE
(``POST /v1/generate`` streams committed tokens per round; ``GET /metrics``
is Prometheus; see ``repro.serving.gateway``).  The synthetic backend needs
no JAX and starts instantly; ``--backend engine`` builds a real paged
``SpecEngine`` and streams actual committed token ids.

``--smoke`` does not serve: it runs an in-process loadgen burst against the
configured cell and prints the report — the same path as
``benchmarks/bench_gateway.py`` — so the full client->server->cell loop can
be exercised from one command with no open port.
"""

from __future__ import annotations

import argparse
import asyncio
import json

from repro.core.schemes import (
    available_schemes,
    parse_scheme_args,
    scheme_help_text,
)
from repro.serving.cell import SCHEDULES


def build_cell(args):
    import numpy as np

    from repro.api import CellConfig, MultiSpinCell
    from repro.core.channel import ChannelConfig

    scheme_params = parse_scheme_args(args.scheme, args.scheme_arg)
    if args.backend == "synthetic":
        cfg = CellConfig(scheme=args.scheme, scheme_params=scheme_params,
                         schedule=args.schedule, max_batch=args.max_batch,
                         t_ver_fix=0.035, t_ver_lin=0.0177, L_max=args.L_max,
                         seed=args.seed)
        return MultiSpinCell(cfg)

    import jax

    from repro.api import EngineBackend, SpecEngine
    from repro.configs import get_config

    tcfg = get_config(args.arch)
    if args.smoke_arch:
        tcfg = tcfg.smoke()
    dcfg = tcfg.smoke().replace(num_layers=1, d_model=64, num_heads=2,
                                num_kv_heads=1, head_dim=32, d_ff=128,
                                vocab_size=tcfg.vocab_size, name="draft")
    engine = SpecEngine(tcfg, dcfg, max_len=args.max_len, cache_kind="paged",
                        num_pages=args.max_batch * 2 * (args.max_len // 16),
                        compile_mode=args.compile,
                        compile_cache=args.compile_cache)
    engine.init_params(jax.random.PRNGKey(args.seed))
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.max_batch, 8), 0, tcfg.vocab_size)
    state = engine.start(prompts)
    if args.compile != "eager":
        # pre-trace the jitted round steps so the first live requests do not
        # pay compiles; the cell dispatches full-batch rounds at the exact
        # draft depth the scheme picks, so warm (max_batch, L) per length.
        lengths = ([int(x) for x in args.warmup_lengths.split(",") if x]
                   if args.warmup_lengths else [args.L_max])
        state, info = engine.warmup(
            state, sorted({(args.max_batch, L) for L in lengths}))
        print(f"warmup: traced {len(info)} bucket(s) in "
              f"{sum(info.values()):.1f}s "
              f"(compile cache: {args.compile_cache or 'off'})")
    backend = EngineBackend(engine, state, keep_finished_tokens=True)
    cfg = CellConfig(scheme=args.scheme, scheme_params=scheme_params,
                     schedule=args.schedule, max_batch=args.max_batch,
                     channel=ChannelConfig(vocab_size=tcfg.vocab_size),
                     t_ver_fix=0.035, t_ver_lin=0.0177, L_max=args.L_max,
                     seed=args.seed)
    return MultiSpinCell(cfg, backend=backend,
                         rng=np.random.default_rng(args.seed))


async def _serve(args):
    from repro.serving.gateway import GatewayConfig, MetricsHub, serve

    cell = build_cell(args)
    hub = MetricsHub(trace_path=args.trace)
    gcfg = GatewayConfig(host=args.host, port=args.port,
                         trace_spans=args.trace_spans,
                         trace_device_sync=args.trace_device_sync)
    print(f"multi-spin gateway: scheme={args.scheme} backend={args.backend} "
          f"max_batch={args.max_batch}")
    print(f"  GET  http://{args.host}:{args.port}/              (dashboard)")
    print(f"  POST http://{args.host}:{args.port}/v1/generate   (SSE)")
    print(f"  GET  http://{args.host}:{args.port}/metrics       (Prometheus)")
    print(f"  GET  http://{args.host}:{args.port}/v1/stats      (JSON)")
    if args.trace_spans:
        print(f"  GET  http://{args.host}:{args.port}/v1/trace      "
              "(Perfetto JSON)")
    await serve(cell, config=gcfg, hub=hub)


async def _smoke(args):
    from repro.serving.gateway import (
        GatewayConfig,
        LoadGenConfig,
        MultiSpinGateway,
        run_loadgen,
    )

    cell = build_cell(args)
    gw = MultiSpinGateway(cell, GatewayConfig(port=0, idle_wait_s=0.02))
    await gw.start()
    try:
        report = await run_loadgen(
            "127.0.0.1", gw.port,
            LoadGenConfig(rate_per_s=32.0, n_requests=args.smoke_requests,
                          max_new_tokens_choices=(4, 8), seed=args.seed))
        from repro.serving.gateway import GatewayClient
        stats = await GatewayClient(port=gw.port).stats()
    finally:
        await gw.stop()
    report.pop("records", None)
    report["rounds_total"] = stats["rounds_total"]
    print(json.dumps(report, indent=2, sort_keys=True))
    if report["n_error"]:
        raise SystemExit(f"gateway smoke FAILED: {report['errors']}")


def main():
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=scheme_help_text())
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8011,
                    help="0 picks an ephemeral port")
    ap.add_argument("--backend", default="synthetic",
                    choices=("synthetic", "engine"))
    ap.add_argument("--arch", default="qwen2.5-3b",
                    help="target architecture (engine backend)")
    ap.add_argument("--smoke-arch", action="store_true",
                    help="shrink the engine arch to smoke scale")
    ap.add_argument("--max-len", type=int, default=256,
                    help="engine stream length ceiling")
    ap.add_argument("--compile", default="eager",
                    choices=("eager", "jit", "jit+donate"),
                    help="engine round-path compile mode (engine backend)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory "
                         "(also: REPRO_COMPILE_CACHE)")
    ap.add_argument("--warmup-lengths", default="", metavar="L1,L2,...",
                    help="draft depths to pre-trace at startup when "
                         "--compile != eager (default: L-max only)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--scheme", default="hete", choices=available_schemes())
    ap.add_argument("--scheme-arg", action="append", default=[],
                    metavar="KEY=VAL",
                    help="scheme parameter (repeatable); valid keys below")
    ap.add_argument("--schedule", default="sync", choices=SCHEDULES)
    ap.add_argument("--L-max", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="append per-round RoundMetrics JSONL here")
    ap.add_argument("--trace-spans", action="store_true",
                    help="install the span tracer; GET /v1/trace serves "
                         "Chrome trace-event JSON for Perfetto")
    ap.add_argument("--trace-device-sync", action="store_true",
                    help="block_until_ready at span exits so device time "
                         "lands in the enclosing span (slower rounds)")
    ap.add_argument("--smoke", action="store_true",
                    help="no server: in-process loadgen burst, print report")
    ap.add_argument("--smoke-requests", type=int, default=8)
    args = ap.parse_args()
    try:
        asyncio.run(_smoke(args) if args.smoke else _serve(args))
    except KeyboardInterrupt:
        print("\ngateway stopped")


if __name__ == "__main__":
    main()
