"""Multi-SPIN serving driver CLI.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --devices 4 --rounds 6 --scheme hete

Runs the full protocol (controller + channel + real-model engine) with the
request scheduler keeping the verification batch full.  --dry-run lowers the
serve_step under the production mesh instead.
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--scheme", default="hete",
                    choices=["hete", "homo", "uni-bw", "fixed"])
    ap.add_argument("--max-new-tokens", type=int, default=32)
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch.dryrun import run_cell
        res = run_cell(args.arch, args.shape, args.multi_pod, force=True)
        print(res.get("status"), res.get("roofline", res.get("error")))
        return

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.channel import ChannelConfig
    from repro.core.controller import MultiSpinController, VerificationLatencyModel
    from repro.core.protocol import DeviceProfile, MultiSpinProtocol
    from repro.serving import SpecEngine
    from repro.serving.scheduler import Request, RoundScheduler

    rng = np.random.default_rng(0)
    tcfg = get_config(args.arch)
    if args.smoke:
        tcfg = tcfg.smoke()
    dcfg = tcfg.smoke().replace(num_layers=1, d_model=64, num_heads=2,
                                num_kv_heads=1, head_dim=32, d_ff=128,
                                vocab_size=tcfg.vocab_size, name="draft")
    engine = SpecEngine(tcfg, dcfg, max_len=512)
    engine.init_params(jax.random.PRNGKey(0))

    K = args.devices
    sched = RoundScheduler(max_batch=K)
    for i in range(K):
        sched.submit(Request(rid=i, prompt_len=8,
                             max_new_tokens=args.max_new_tokens,
                             alpha=float(rng.choice([0.71, 0.74, 0.86])),
                             T_S=0.009 * float(rng.uniform(0.85, 1.15))))
    sched.admit()

    prompts = jax.random.randint(jax.random.PRNGKey(1), (K, 8), 0,
                                 tcfg.vocab_size)
    state = engine.start(prompts)

    channel = ChannelConfig(vocab_size=tcfg.vocab_size)
    ctrl = MultiSpinController(
        scheme=args.scheme, q_tok_bits=channel.q_tok_bits,
        bandwidth_hz=channel.total_bandwidth_hz,
        t_ver_model=VerificationLatencyModel(0.035, 0.0177), L_max=8)
    alphas, t_s = sched.device_profiles()
    devices = [DeviceProfile(T_S=float(t), alpha=float(a))
               for a, t in zip(alphas, t_s)]
    proto = MultiSpinProtocol(ctrl, channel, devices, rng, engine=engine,
                              engine_state=state)

    for i in range(args.rounds):
        rec = proto.run_round()
        sched.complete_round(rec.accepted, rec.t_round)
        print(f"round {i}: L={rec.lengths} accepted={rec.accepted} "
              f"goodput={rec.realized_goodput:.1f} tok/s "
              f"active={len(sched.active)}")
        if sched.idle:
            break
    s = sched.stats
    print(f"\ncompleted={s.completed} tokens={s.total_tokens} "
          f"goodput={s.goodput:.1f} tok/s over {s.wall_time:.2f}s simulated")


if __name__ == "__main__":
    main()
