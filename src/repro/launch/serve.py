"""Multi-SPIN serving driver CLI.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --devices 4 --rounds 6 --scheme hete

Stands up a ``MultiSpinCell`` (controller + channel + scheduler) with a
real-model ``EngineBackend`` and drives the session loop; the scheduler
keeps the verification batch full and retires finished requests.  Scheme
choices, their ``--scheme-arg key=val`` parameters, and the help text below
are all derived from the scheme registry's declared schemas.  --dry-run
lowers the serve_step under the production mesh instead.
"""

from __future__ import annotations

import argparse

from repro.core.schemes import (
    available_schemes,
    parse_scheme_args,
    scheme_help_text,
)
from repro.serving.cell import SCHEDULES


def main():
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=scheme_help_text())
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--scheme", default="hete", choices=available_schemes())
    ap.add_argument("--scheme-arg", action="append", default=[],
                    metavar="KEY=VAL",
                    help="scheme parameter (repeatable); the valid keys per "
                         "scheme are listed below, from each scheme's "
                         "declared Params schema")
    ap.add_argument("--schedule", default="sync", choices=SCHEDULES)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    args = ap.parse_args()
    scheme_params = parse_scheme_args(args.scheme, args.scheme_arg)

    if args.dry_run:
        from repro.launch.dryrun import run_cell
        res = run_cell(args.arch, args.shape, args.multi_pod, force=True)
        print(res.get("status"), res.get("roofline", res.get("error")))
        return

    import jax
    import numpy as np

    from repro.api import (
        CellConfig,
        ChannelConfig,
        EngineBackend,
        MultiSpinCell,
        Request,
        SpecEngine,
    )
    from repro.configs import get_config

    rng = np.random.default_rng(0)
    tcfg = get_config(args.arch)
    if args.smoke:
        tcfg = tcfg.smoke()
    dcfg = tcfg.smoke().replace(num_layers=1, d_model=64, num_heads=2,
                                num_kv_heads=1, head_dim=32, d_ff=128,
                                vocab_size=tcfg.vocab_size, name="draft")
    engine = SpecEngine(tcfg, dcfg, max_len=512)
    engine.init_params(jax.random.PRNGKey(0))

    K = args.devices
    prompts = jax.random.randint(jax.random.PRNGKey(1), (K, 8), 0,
                                 tcfg.vocab_size)
    backend = EngineBackend(engine, engine.start(prompts))

    cfg = CellConfig(
        scheme=args.scheme, scheme_params=scheme_params,
        schedule=args.schedule,
        channel=ChannelConfig(vocab_size=tcfg.vocab_size),
        t_ver_fix=0.035, t_ver_lin=0.0177, L_max=8, max_batch=K)
    cell = MultiSpinCell(cfg, backend=backend, rng=rng)
    for i in range(K):
        cell.submit(Request(rid=i, prompt_len=8,
                            max_new_tokens=args.max_new_tokens,
                            alpha=float(rng.choice([0.71, 0.74, 0.86])),
                            T_S=0.009 * float(rng.uniform(0.85, 1.15))))

    for i in range(args.rounds):
        rec = cell.step()
        if rec is None:
            break
        print(f"round {i}: L={rec.lengths} accepted={rec.accepted} "
              f"goodput={rec.realized_goodput:.1f} tok/s "
              f"active={len(cell.scheduler.active)}")
    s = cell.scheduler.stats
    print(f"\ncompleted={s.completed} tokens={s.total_tokens} "
          f"goodput={s.goodput:.1f} tok/s over {s.wall_time:.2f}s simulated")


if __name__ == "__main__":
    main()
