import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each assigned architecture and each applicable input shape, the matching
step function (train_step / prefill_step / serve_step) is jitted under the
production mesh with the repo's sharding rules, lowered from
ShapeDtypeStructs (no allocation), and compiled.  memory_analysis() proves
per-device fit; cost_analysis() + the partitioned HLO feed the roofline
table (EXPERIMENTS.md §Dry-run / §Roofline).

Results are cached per cell in experiments/dryrun/<arch>__<shape>__<mesh>.json
so interrupted sweeps resume where they stopped.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, SHAPES, applicable_shapes, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, has_prefix_embeds, input_specs
from repro.models.model_zoo import prefix_len
from repro.roofline.analysis import count_params, model_flops, roofline
from repro.training import OptimizerConfig, init_optimizer, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# Per-cell overrides discovered during the perf pass live here; the baseline
# run uses the defaults.
TRAIN_MICROBATCHES = 4
# arctic-480b: params+opt already take 11.3 GB/chip at 256 chips; deep
# microbatching is the only way to approach fit (EXPERIMENTS.md §Dry-run)
ARCH_MICROBATCHES = {"arctic-480b": 16}


VOCAB_PAD = 2048  # 128 lanes x 16-way tensor parallelism


def _cfg_for_dryrun(arch: str, training: bool):
    cfg = get_config(arch)
    # pad vocab so the "vocab" logical axis shards over model (MaxText-style);
    # logits shrink 16x per chip and the embedding-grad transpose stays local.
    padded_vocab = -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD
    return cfg.replace(param_dtype="bfloat16", compute_dtype="bfloat16",
                       remat=training, vocab_size=padded_vocab)


def _sds(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               microbatches: int | None = None, fsdp: bool = True,
               moe_capacity: float | None = None, draft_window: int = 0,
               cache_dtype=None):
    """Returns (lowered, meta) for one dry-run cell."""
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    training = shape.kind == "train"
    cfg = _cfg_for_dryrun(arch, training)
    model = build_model(cfg)

    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = shd.param_shardings(mesh, params_shape, fsdp=fsdp)
    specs = input_specs(cfg, shape)
    b_sh = shd.batch_shardings(mesh, specs)

    if shape.kind == "train":
        ocfg = OptimizerConfig(
            state_dtype="bfloat16" if arch == "arctic-480b" else "float32")
        opt_shape = jax.eval_shape(
            lambda: init_optimizer(ocfg, params_shape))
        o_sh = shd.opt_shardings(mesh, p_sh)
        mb = microbatches or ARCH_MICROBATCHES.get(arch, TRAIN_MICROBATCHES)
        step = make_train_step(model, ocfg, microbatches=mb,
                               has_prefix=has_prefix_embeds(cfg))
        fn = jax.jit(step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(params_shape, opt_shape, specs)
    elif shape.kind == "prefill":
        max_len = shape.seq_len + prefix_len(cfg)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, max_len, jnp.bfloat16))
        c_sh = shd.cache_shardings(mesh, cache_shape, model.CACHE_BATCH_AXES)

        cap = moe_capacity if moe_capacity is not None else \
            (cfg.capacity_factor if cfg.num_experts else None)

        def prefill_step(params, tokens, cache, prefix_embeds=None):
            kw = {}
            if cfg.num_experts:
                kw["moe_capacity"] = cap
            logits, cache, _ = model.prefill(params, tokens, cache,
                                             prefix_embeds=prefix_embeds, **kw)
            # return only the last-position logits (sampling seed), not the
            # full (B, S, V) tensor
            return logits[:, -1], cache

        fn = jax.jit(prefill_step,
                     in_shardings=(p_sh, b_sh["tokens"], c_sh) +
                     ((b_sh["prefix_embeds"],) if "prefix_embeds" in specs else ()),
                     donate_argnums=(2,))
        with mesh:
            args = [params_shape, specs["tokens"], cache_shape]
            if "prefix_embeds" in specs:
                args.append(specs["prefix_embeds"])
            lowered = fn.lower(*args)
    else:  # decode
        max_len = shape.seq_len + prefix_len(cfg)
        cdt = cache_dtype or jnp.bfloat16
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, max_len, cdt))
        c_sh = shd.cache_shardings(mesh, cache_shape, model.CACHE_BATCH_AXES)
        specs = input_specs(cfg, shape, draft_window=draft_window)
        b_sh = shd.batch_shardings(mesh, specs)

        def serve_step(params, tokens, cache, pos):
            logits, cache = model.forward_window(params, tokens, cache, pos)
            return logits, cache

        fn = jax.jit(serve_step,
                     in_shardings=(p_sh, b_sh["tokens"], c_sh, b_sh["pos"]),
                     donate_argnums=(2,))
        with mesh:
            lowered = fn.lower(params_shape, specs["tokens"], cache_shape,
                               specs["pos"])

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "pod2x16x16" if multi_pod else "pod16x16",
            "chips": 512 if multi_pod else 256,
            "fsdp": fsdp, "microbatches": microbatches}
    return lowered, meta, cfg, shape


def probe_pair(cfg):
    """Two reduced-depth, scan-UNROLLED configs and the repeating-unit count.

    XLA's cost analysis counts a while-loop body once regardless of trip
    count, so scanned-layer programs under-report FLOPs/bytes/collectives.
    We therefore lower two shallow unrolled variants (n and n+1 repeating
    units), whose cost DIFFERENCE is the exact per-unit cost, and scale:

        cost_full = cost(n) + (cost(n+1) - cost(n)) * (units - n_units(n))

    This is exact for homogeneous stacks (all assigned archs) and keeps probe
    compile times low.
    """
    if cfg.family == "hybrid":
        g = cfg.hybrid_group
        return (cfg.replace(num_layers=g, scan_unroll=True),
                cfg.replace(num_layers=2 * g, scan_unroll=True),
                cfg.num_layers // g)
    if cfg.family == "audio":
        return (cfg.replace(num_layers=1, num_encoder_layers=1, scan_unroll=True),
                cfg.replace(num_layers=2, num_encoder_layers=2, scan_unroll=True),
                cfg.num_layers)
    if cfg.num_experts and cfg.first_k_dense:
        fkd = cfg.first_k_dense
        return (cfg.replace(num_layers=fkd + 1, scan_unroll=True),
                cfg.replace(num_layers=fkd + 2, scan_unroll=True),
                cfg.num_layers - fkd)
    return (cfg.replace(num_layers=1, scan_unroll=True),
            cfg.replace(num_layers=2, scan_unroll=True),
            cfg.num_layers)


def _cell_costs(lowered) -> dict:
    compiled = lowered.compile()
    cost = dict(compiled.cost_analysis() or {})
    from repro.roofline.analysis import parse_collectives
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(coll.total_bytes),
        "collective_counts": coll.counts,
    }


def probe_costs(arch: str, shape_name: str, multi_pod: bool,
                microbatches: int | None = None, fsdp: bool = True,
                moe_capacity: float | None = None) -> dict:
    """Scan-corrected per-device costs for the full-depth program."""
    cfg_full = get_config(arch)
    c1_cfg, c2_cfg, units = probe_pair(cfg_full)

    def lower_with(cfg_probe):
        import repro.configs.base as cb
        # temporarily register the probe config under the arch name
        orig = cb._REGISTRY[arch]
        cb._REGISTRY[arch] = cfg_probe
        try:
            lowered, *_ = lower_cell(arch, shape_name, multi_pod,
                                     microbatches=1, fsdp=fsdp,
                                     moe_capacity=moe_capacity)
        finally:
            cb._REGISTRY[arch] = orig
        return lowered

    c1 = _cell_costs(lower_with(c1_cfg))
    c2 = _cell_costs(lower_with(c2_cfg))
    scale = units - 1  # c2 has exactly one more repeating unit than c1
    out = {}
    for key in ("flops", "bytes", "collective_bytes"):
        delta = c2[key] - c1[key]
        out[key] = c1[key] + delta * scale
        out[f"{key}_per_unit"] = delta
    out["collective_counts"] = {
        op: c1["collective_counts"][op]
        + (c2["collective_counts"][op] - c1["collective_counts"][op]) * scale
        for op in c1["collective_counts"]
    }
    out["units"] = units
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, force: bool = False,
             **kw) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "status": "error"}
    try:
        lowered, meta, cfg, shape = lower_cell(arch, shape_name, multi_pod, **kw)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        cost = dict(cost) if cost else {}
        total, active = count_params(get_config(arch))
        mflops = model_flops(cfg, shape, total, active)

        # HLO collective inventory (structural cross-check: while-loop bodies
        # appear once — see EXPERIMENTS.md §Methodology)
        from repro.roofline.analysis import parse_collectives
        hlo_coll = parse_collectives(compiled.as_text())

        # analytic roofline terms (exact closed forms; the CPU backend's
        # cost_analysis over SPMD modules is unstable — evidence kept below)
        from repro.roofline.analytic import MeshInfo, roofline_terms, summarize
        mesh_info = MeshInfo(chips=meta["chips"],
                             dp=meta["chips"] // 16, mp=16)
        tb = roofline_terms(cfg, shape, mesh_info,
                            flash=kw.get("flash", False),
                            microbatches=kw.get("microbatches")
                            or ARCH_MICROBATCHES.get(arch, TRAIN_MICROBATCHES),
                            fsdp=kw.get("fsdp", True))
        rf = summarize(tb, mflops, meta["chips"])
        rf.update(arch=arch, shape=shape_name, mesh=mesh_name,
                  chips=meta["chips"])

        result = {
            **meta,
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "params_total": total,
            "params_active": active,
            "memory_analysis": _mem_dict(mem),
            "cost_flops_scanned_raw": cost.get("flops"),
            "cost_bytes_scanned_raw": cost.get("bytes accessed"),
            "hlo_collective_counts_per_scan_body": hlo_coll.counts,
            "hlo_collective_bytes_per_scan_body": hlo_coll.bytes_by_op,
            "roofline": rf,
        }
    except Exception as e:  # noqa: BLE001 — a failing cell is a recorded bug
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2, default=str)
    return result


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out


def all_cells():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape_name in applicable_shapes(cfg):
            for multi_pod in (False, True):
                yield arch, shape_name, multi_pod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = list(all_cells())
    else:
        archs = [args.arch] if args.arch else ASSIGNED_ARCHS
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
        cells = []
        for arch in archs:
            shapes = ([args.shape] if args.shape
                      else applicable_shapes(get_config(arch)))
            for s in shapes:
                for m in meshes:
                    cells.append((arch, s, m))

    n_ok = 0
    for arch, shape_name, multi_pod in cells:
        res = run_cell(arch, shape_name, multi_pod, force=args.force)
        ok = res.get("status") == "ok"
        n_ok += ok
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        if ok:
            r = res["roofline"]
            print(f"[OK ] {arch:22s} {shape_name:12s} {mesh_name:10s} "
                  f"compile={res['compile_s']:>6.1f}s "
                  f"bottleneck={r['bottleneck']:10s} "
                  f"frac={r['peak_fraction']:.3f} "
                  f"terms(c/m/n)={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
                  f"{r['collective_s']:.2e}")
            mem = res["memory_analysis"]
            print(f"      temp={mem.get('temp_size_in_bytes', 0)/1e9:.1f}GB "
                  f"args={mem.get('argument_size_in_bytes', 0)/1e9:.2f}GB")
        else:
            print(f"[ERR] {arch:22s} {shape_name:12s} {mesh_name:10s} "
                  f"{res.get('error', '?')}")
    print(f"\n{n_ok}/{len(cells)} cells OK")


if __name__ == "__main__":
    main()
