"""Span tracer for the Multi-SPIN serving stack (stdlib only).

Design goals, in priority order:

1. **Free when off.**  Every instrumented call site goes through the
   module-level ``span(...)`` helper; with no tracer installed it returns
   the shared ``NULL_SPAN`` singleton — one function call, no allocation,
   no lock.  Call sites that would build an ``args`` dict guard on
   ``active()`` first so even the dict is never constructed.
2. **Thread-correct nesting.**  The gateway steps the cell on a worker
   thread while scrapes run on the event loop; each thread keeps its own
   span stack (``threading.local``), so parent/child links never cross
   threads and concurrent spans cannot corrupt each other.
3. **Bounded memory.**  Finished spans land in a ``deque(maxlen=capacity)``
   ring: a long-lived gateway with tracing left on degrades to "last N
   spans", never to OOM.
4. **Honest device timing, opt-in.**  JAX dispatch is asynchronous — the
   wall-clock around an ``ops.*`` call measures dispatch, not compute.
   A ``Tracer(device_sync=True)`` calls ``jax.block_until_ready`` on the
   value attached to each span (``sp.attach(out)``) before closing it, so
   span durations become device-true.  The import of jax is lazy and only
   happens when device sync is actually enabled, keeping this module (and
   the gateway importing it) jax-free.

Usage::

    from repro.obs import trace

    tracer = trace.install(trace.Tracer())
    with trace.span("cell.step", cat="cell") as sp:
        ...
        sp.set(rounds=3)          # attach args at exit
    json_dict = tracer.export_chrome_trace()   # load in Perfetto
    trace.uninstall()

The exported dict follows the Chrome trace-event format: complete ("X")
events with microsecond ``ts``/``dur``, one ``tid`` per python thread, so
nesting renders as flame stacks in Perfetto / chrome://tracing.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque

__all__ = [
    "NULL_SPAN",
    "Span",
    "Tracer",
    "active",
    "incr",
    "install",
    "span",
    "tracing",
    "uninstall",
]


class _NullSpan:
    """Shared no-op span: the disabled-tracing fast path.  A single module
    lifetime instance is ever created (identity-tested by the no-op guard
    test), so instrumented code costs zero allocations when tracing is
    off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        pass

    def attach(self, value):
        pass


NULL_SPAN = _NullSpan()


class Span:
    """One live span.  Use as a context manager; the tracer records it on
    exit.  ``set(**args)`` merges key/values into the exported ``args``;
    ``attach(value)`` hands the tracer a jax value to block on at exit when
    device sync is enabled (no-op otherwise)."""

    __slots__ = ("tracer", "name", "cat", "args", "sid", "parent_sid",
                 "tid", "t0_ns", "dur_ns", "_sync")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: dict | None):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.sid = -1
        self.parent_sid = -1
        self.tid = 0
        self.t0_ns = 0
        self.dur_ns = 0
        self._sync = None

    def set(self, **args):
        if self.args is None:
            self.args = {}
        self.args.update(args)

    def attach(self, value):
        self._sync = value

    def __enter__(self):
        self.tracer._enter(self)
        return self

    def __exit__(self, *exc):
        self.tracer._exit(self)
        return False


class Tracer:
    """Bounded, thread-safe span recorder with Chrome-trace export.

    ``capacity`` bounds the retained finished spans (a ring — oldest spans
    fall off first).  ``device_sync=True`` makes span exits call
    ``jax.block_until_ready`` on each span's attached value, turning
    dispatch timings into device timings (lazy jax import; only pay for it
    if you ask)."""

    def __init__(self, capacity: int = 65536, device_sync: bool = False):
        self.capacity = int(capacity)
        self.device_sync = bool(device_sync)
        self.spans: deque = deque(maxlen=self.capacity)
        self.dropped = 0
        self._ids = itertools.count()
        self._tls = threading.local()
        self._lock = threading.Lock()
        self.t0_ns = time.perf_counter_ns()
        self._block = None
        # monotonically increasing named counters (host-transfer accounting:
        # the engine bumps ``engine.host_sync`` at every blocking device
        # fetch) — unbounded only in name count, which instrumentation fixes
        self._counters: dict[str, int] = {}

    # -- span lifecycle (called by Span.__enter__/__exit__) --------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _enter(self, sp: Span):
        st = self._stack()
        sp.sid = next(self._ids)
        sp.parent_sid = st[-1].sid if st else -1
        sp.tid = threading.get_ident()
        st.append(sp)
        sp.t0_ns = time.perf_counter_ns()

    def _exit(self, sp: Span):
        if self.device_sync and sp._sync is not None:
            if self._block is None:
                import jax
                self._block = jax.block_until_ready
            self._block(sp._sync)
            sp._sync = None
        sp.dur_ns = time.perf_counter_ns() - sp.t0_ns
        st = self._stack()
        if st and st[-1] is sp:
            st.pop()
        elif sp in st:          # tolerate out-of-order exits, never corrupt
            st.remove(sp)
        with self._lock:
            if len(self.spans) == self.capacity:
                self.dropped += 1
            self.spans.append(sp)

    # -- public API ------------------------------------------------------

    def span(self, name: str, cat: str = "repro",
             args: dict | None = None) -> Span:
        return Span(self, name, cat, args)

    def incr(self, name: str, n: int = 1) -> None:
        """Bump a named counter (thread-safe).  Counters ride the tracer so
        host-transfer accounting is free when tracing is off — the module
        level ``incr`` is a no-op without an installed tracer."""
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + int(n)

    def counters(self) -> dict[str, int]:
        """Snapshot of the named counters."""
        with self._lock:
            return dict(self._counters)

    def clear(self):
        with self._lock:
            self.spans.clear()
            self.dropped = 0
            self.t0_ns = time.perf_counter_ns()
            self._counters.clear()

    def snapshot(self) -> list[Span]:
        """Finished spans, oldest first (thread-safe copy)."""
        with self._lock:
            return list(self.spans)

    def totals(self) -> dict[str, dict]:
        """Per-name aggregate: count and summed duration (seconds) over the
        retained ring."""
        out: dict[str, dict] = {}
        for sp in self.snapshot():
            t = out.setdefault(sp.name, {"count": 0, "seconds": 0.0})
            t["count"] += 1
            t["seconds"] += sp.dur_ns * 1e-9
        return out

    def export_chrome_trace(self, process_name: str = "multi-spin") -> dict:
        """The trace as a Chrome trace-event JSON object (Perfetto /
        chrome://tracing load it directly).  Spans become complete ("X")
        events with microsecond timestamps relative to tracer start; each
        python thread is a ``tid`` so nesting renders as flame stacks."""
        events = [{
            "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
            "args": {"name": process_name},
        }]
        tids: dict[int, int] = {}
        for sp in self.snapshot():
            tid = tids.setdefault(sp.tid, len(tids) + 1)
            ev = {
                "name": sp.name,
                "cat": sp.cat,
                "ph": "X",
                "pid": 1,
                "tid": tid,
                "ts": (sp.t0_ns - self.t0_ns) / 1e3,
                "dur": sp.dur_ns / 1e3,
            }
            args = dict(sp.args) if sp.args else {}
            args["sid"] = sp.sid
            if sp.parent_sid >= 0:
                args["parent_sid"] = sp.parent_sid
            ev["args"] = args
            events.append(ev)
        for thread_ident, tid in tids.items():
            events.append({
                "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                "args": {"name": f"thread-{thread_ident}"},
            })
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_spans": self.dropped,
                          "counters": self.counters()},
        }

    def export_chrome_trace_json(self, **kw) -> str:
        return json.dumps(self.export_chrome_trace(**kw))


# ---------------------------------------------------------------------------
# module-level tracer (what instrumented call sites use)
# ---------------------------------------------------------------------------

_tracer: Tracer | None = None


def install(tracer: Tracer | None = None) -> Tracer:
    """Install ``tracer`` (or a fresh default one) as the process-wide
    tracer and return it.  Instrumented call sites pick it up on their next
    ``span()`` call — no re-wiring."""
    global _tracer
    _tracer = tracer if tracer is not None else Tracer()
    return _tracer


def uninstall() -> None:
    """Remove the process-wide tracer: every ``span()`` call reverts to the
    free ``NULL_SPAN`` path."""
    global _tracer
    _tracer = None


def active() -> Tracer | None:
    """The installed tracer, or None.  Hot paths that would build an args
    dict should guard on this so the dict is never constructed when
    tracing is off."""
    return _tracer


def span(name: str, cat: str = "repro", args: dict | None = None):
    """Open a span on the installed tracer; the shared no-op singleton when
    tracing is off (zero allocations)."""
    t = _tracer
    if t is None:
        return NULL_SPAN
    return t.span(name, cat=cat, args=args)


def incr(name: str, n: int = 1) -> None:
    """Bump a named counter on the installed tracer; free no-op when tracing
    is off (one global read, no allocation)."""
    t = _tracer
    if t is not None:
        t.incr(name, n)


class tracing:
    """Scoped install: ``with tracing() as tracer: ...`` installs a tracer
    for the block and restores the previous one after (tests and benches
    use this so they cannot leak a tracer into later code)."""

    def __init__(self, tracer: Tracer | None = None):
        self.tracer = tracer if tracer is not None else Tracer()
        self._prev: Tracer | None = None

    def __enter__(self) -> Tracer:
        global _tracer
        self._prev = _tracer
        _tracer = self.tracer
        return self.tracer

    def __exit__(self, *exc):
        global _tracer
        _tracer = self._prev
        return False
