"""Observability primitives for the Multi-SPIN serving stack.

``repro.obs`` is dependency-free (stdlib only — no jax, no numpy) so the
gateway and telemetry layers stay importable without an accelerator stack,
and so instrumented hot paths pay nothing when tracing is off.

The one subsystem here today is the span tracer (``repro.obs.trace``):
nested wall-clock spans with optional device-sync boundaries, exported as
Chrome trace-event JSON that loads directly in Perfetto / chrome://tracing.
"""

from repro.obs.trace import (
    NULL_SPAN,
    Span,
    Tracer,
    active,
    install,
    span,
    tracing,
    uninstall,
)

__all__ = [
    "NULL_SPAN",
    "Span",
    "Tracer",
    "active",
    "install",
    "span",
    "tracing",
    "uninstall",
]
