"""Shared pure-JAX building blocks for the model zoo.

All modules follow the functional convention:
    init_*(key, cfg, ...) -> params (pytree of jnp arrays)
    *_apply(params, x, ...) -> output

Parameters are plain nested dicts so they compose with jax.lax.scan
(stacked leading layer axis), pjit shardings, and our checkpoint layer
without any framework dependency.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict pytree


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype), "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return init_rmsnorm, rmsnorm
    if kind == "layernorm":
        return init_layernorm, layernorm
    raise ValueError(f"unknown norm {kind}")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Grouped-query attention
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False, dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype=dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype=dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype=dtype)
    return p


def _project_qkv(params: Params, x: jax.Array, num_heads: int, num_kv_heads: int,
                 head_dim: int):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, num_heads, head_dim)
    k = k.reshape(B, S, num_kv_heads, head_dim)
    v = v.reshape(B, S, num_kv_heads, head_dim)
    return q, k, v


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array | None, scale: float | None = None) -> jax.Array:
    """Grouped-query attention core (XLA path).

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D); mask broadcastable to
    (B, KV, G, Sq, Skv) or None.  Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, Sq, KV, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    # keep the O(S^2) score tensor sharded: batch over data axes, kv-heads
    # (or query-heads / query-seq fallback) over model; no-op without a mesh
    from repro.distributed.sharding import constrain_attention_scores
    logits = constrain_attention_scores(logits)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, Sq, H, D)


def update_kv_cache(cache: jax.Array, new: jax.Array, offset) -> jax.Array:
    """Write ``new`` (B, T, KV, D) into ``cache`` (B, S, KV, D) at ``offset``.

    ``offset`` may be a scalar (all rows aligned: prefill) or per-row (B,)
    (decode / speculative verification with heterogeneous prefix lengths —
    lowered by XLA to a scatter).
    """
    new = new.astype(cache.dtype)
    offset = jnp.asarray(offset)
    if offset.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, new, offset, axis=1)
    zero = jnp.zeros((), jnp.int32)
    return jax.vmap(
        lambda c, n, o: jax.lax.dynamic_update_slice(c, n, (o, zero, zero))
    )(cache, new, offset.astype(jnp.int32))


def paged_update_kv_cache(pool: jax.Array, new: jax.Array, offset,
                          page_table: jax.Array) -> jax.Array:
    """Write ``new`` (B, T, KV, D) into a page pool (P, ps, KV, D).

    Logical position ``p`` of row ``b`` lives at physical page
    ``page_table[b, p // ps]``, in-page slot ``p % ps``.  Writes through
    unmapped table entries (-1) or past the table width are dropped — that is
    exactly the contract frozen/retired engine rows rely on (their stale
    window writes either land in slack slots that the row's next live round
    overwrites, or vanish).

    Two other layers lean on the same drop semantics: the page allocator's
    device table maps unknown streams to an all-(-1) row, and the engine's
    ``warmup`` traces the jitted draft/verify steps against the REAL pools
    under an all-(-1) table — every write drops, so the donated pool comes
    back bit-identical and can be adopted.  Note the drop bin ``P * ps`` is
    shared by every dropped write, so this scatter must NOT be annotated
    ``unique_indices=True``.
    """
    B, T = new.shape[:2]
    P, ps = pool.shape[:2]
    n_slots = page_table.shape[1]
    offset = jnp.asarray(offset)
    if offset.ndim == 0:
        offset = jnp.broadcast_to(offset, (B,))
    pos = offset[:, None].astype(jnp.int32) + jnp.arange(T, dtype=jnp.int32)
    slot = pos // ps
    phys = jnp.take_along_axis(page_table,
                               jnp.clip(slot, 0, n_slots - 1), axis=1)
    ok = (phys >= 0) & (slot < n_slots)
    flat = jnp.where(ok, phys * ps + pos % ps, P * ps)    # P*ps = drop bin
    flat_pool = pool.reshape((P * ps,) + pool.shape[2:])
    flat_pool = flat_pool.at[flat.reshape(-1)].set(
        new.astype(pool.dtype).reshape((B * T,) + new.shape[2:]), mode="drop")
    return flat_pool.reshape(pool.shape)


def paged_gather_kv(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Materialize the logical (B, n_slots * ps, KV, D) view of a page pool.

    Unmapped slots (-1) clamp to page 0; callers mask them by position (the
    valid prefix of a stream is always fully mapped).  This is the exact XLA
    reference path — the Pallas ``paged_attention`` kernel streams the same
    tiles through the page table without materializing the view.
    """
    B, n_slots = page_table.shape
    ps = pool.shape[1]
    gathered = pool[jnp.maximum(page_table, 0)]     # (B, n_slots, ps, KV, D)
    return gathered.reshape((B, n_slots * ps) + pool.shape[2:])


def gather_kv_window(leaf: jax.Array, pos: jax.Array,
                     page_table: jax.Array | None = None) -> jax.Array:
    """Gather K/V rows at logical positions ``pos`` (B, T) from a stacked
    cache leaf: (Ln, B, S, KV, D) contiguous, or (Ln, P, ps, KV, D) pools
    with ``page_table`` (B, n_slots).  Out-of-range / unmapped positions
    clamp to a valid slot — callers mask by validity (the accepted prefix
    of a live stream is always fully mapped).  Returns (Ln, B, T, KV, D)."""
    if page_table is None:
        S = leaf.shape[2]
        idx = jnp.clip(pos, 0, S - 1)[None, :, :, None, None]
        return jnp.take_along_axis(leaf, idx, axis=2)
    P, ps = leaf.shape[1], leaf.shape[2]
    n_slots = page_table.shape[1]
    slot = jnp.clip(pos // ps, 0, n_slots - 1)
    phys = jnp.take_along_axis(page_table, slot, axis=1)          # (B, T)
    flat = jnp.maximum(phys, 0) * ps + pos % ps
    flat_leaf = leaf.reshape(leaf.shape[:1] + (P * ps,) + leaf.shape[3:])
    return flat_leaf[:, flat]


def scatter_kv_window(leaf: jax.Array, values: jax.Array, pos: jax.Array,
                      valid: jax.Array,
                      page_table: jax.Array | None = None) -> jax.Array:
    """Write ``values`` (Ln, B, T, KV, D) into a stacked cache leaf at
    logical positions ``pos`` (B, T) where ``valid`` (B, T); invalid,
    out-of-range, and unmapped positions are dropped (the same drop-bin
    contract as ``paged_update_kv_cache``).  This is the K/V scatter-commit
    primitive: the engine moves the accepted tree branch's already-computed
    K/V into its committed slots instead of re-forwarding the path."""
    values = values.astype(leaf.dtype)
    if page_table is None:
        S = leaf.shape[2]
        idx = jnp.where(valid, pos, S)                            # S = drop
        b = jnp.arange(leaf.shape[1])[:, None]
        return leaf.at[:, b, idx].set(values, mode="drop")
    P, ps = leaf.shape[1], leaf.shape[2]
    n_slots = page_table.shape[1]
    slot = pos // ps
    phys = jnp.take_along_axis(page_table,
                               jnp.clip(slot, 0, n_slots - 1), axis=1)
    ok = valid & (phys >= 0) & (slot < n_slots)
    flat = jnp.where(ok, phys * ps + pos % ps, P * ps)            # drop bin
    flat_leaf = leaf.reshape(leaf.shape[:1] + (P * ps,) + leaf.shape[3:])
    flat_leaf = flat_leaf.at[:, flat].set(values, mode="drop")
    return flat_leaf.reshape(leaf.shape)


def causal_mask(Sq: int, Skv: int, offset: int = 0) -> jax.Array:
    """(1, 1, 1, Sq, Skv) boolean mask: query i attends to kv j <= i+offset."""
    qi = jnp.arange(Sq)[:, None] + offset
    kj = jnp.arange(Skv)[None, :]
    return (kj <= qi)[None, None, None]


def tree_window_mask(pos: jax.Array, window_mask: jax.Array,
                     S_max: int) -> jax.Array:
    """(B, 1, 1, T, S_max) attention mask for a token-tree verification
    window written at cache SLOTS [pos_b, pos_b + T).

    ``window_mask`` (B, T, T) is the tree's ancestor-or-self matrix: query
    row t attends every committed slot [0, pos_b) plus window slot t' iff
    ``window_mask[b, t, t']``.  With a lower-triangular matrix this equals
    the plain causal window mask bit-for-bit (the sequential special case).
    """
    B, T = window_mask.shape[:2]
    kj = jnp.arange(S_max)
    committed = kj[None, None, :] < pos[:, None, None]            # (B, 1, S)
    w = kj[None, :] - pos[:, None]                                # (B, S)
    in_win = (w >= 0) & (w < T)
    idx = jnp.broadcast_to(jnp.clip(w, 0, T - 1)[:, None, :], (B, T, S_max))
    allow = jnp.take_along_axis(window_mask, idx, axis=2)         # (B, T, S)
    return (committed | (allow & in_win[:, None, :]))[:, None, None]


def attention_apply(params: Params, x: jax.Array, *, num_heads: int,
                    num_kv_heads: int, head_dim: int, positions: jax.Array,
                    mask: jax.Array | None, rope_theta: float | None,
                    kv_cache: tuple[jax.Array, jax.Array] | None = None,
                    cache_offset: jax.Array | int | None = None,
                    page_table: jax.Array | None = None,
                    window_mask: jax.Array | None = None,
                    causal_window: bool = False):
    """Full attention layer. If kv_cache=(k_cache, v_cache) is given, new keys
    and values are written at ``cache_offset`` and attention runs over the
    whole cache (decode / chunked-prefill path). Returns (out, (k, v)) where
    (k, v) is the updated cache (or the fresh keys/values when no cache).

    With ``page_table`` (B, n_slots), ``kv_cache`` holds page POOLS
    (P, ps, KV, D): writes route through the table.

    Kernel dispatch (the serving hot path): when ``kernel_mode()`` is
    ``pallas``/``interpret`` and the call is a cache window — sequential
    (``causal_window=True``: query row t attends [0, cache_offset + t]) or
    token-tree (``window_mask`` (B, T, T) ancestor-or-self, window written
    at slots [cache_offset, cache_offset + T)) — attention runs through
    ``ops.paged_attention`` / ``ops.tree_attention`` /
    ``ops.paged_tree_attention`` directly on the cache layout.  On the
    paged path this skips the per-layer ``paged_gather_kv``
    materialization of the (B, n_slots * ps, KV, D) logical view entirely.
    ``REPRO_KERNELS=ref`` (the CPU default) keeps the gather + masked
    ``gqa_attention`` jnp path, which the kernel tests assert parity
    against; callers always pass ``mask`` so the fallback never depends on
    the dispatch flags."""
    from repro.kernels import ops as kops

    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, num_heads, num_kv_heads, head_dim)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if kv_cache is None:
        out = gqa_attention(q, k, v, mask)
        out = out.reshape(B, S, num_heads * head_dim) @ params["wo"]
        return out, (k, v)

    k_cache, v_cache = kv_cache
    dispatch = (kops.kernel_mode() in ("pallas", "interpret")
                and (window_mask is not None or causal_window))
    if page_table is not None:
        k_cache = paged_update_kv_cache(k_cache, k, cache_offset, page_table)
        v_cache = paged_update_kv_cache(v_cache, v, cache_offset, page_table)
        if dispatch:
            lengths = jnp.broadcast_to(jnp.asarray(cache_offset), (B,))
            if window_mask is not None:
                ctx = kops.paged_tree_attention(q, k_cache, v_cache,
                                                page_table, lengths,
                                                window_mask)
            else:
                ctx = kops.paged_attention(q, k_cache, v_cache, page_table,
                                           lengths + 1)
        else:
            kg = paged_gather_kv(k_cache, page_table)
            vg = paged_gather_kv(v_cache, page_table)
            ctx = gqa_attention(q, kg, vg, mask)
    else:
        k_cache = update_kv_cache(k_cache, k, cache_offset)
        v_cache = update_kv_cache(v_cache, v, cache_offset)
        if dispatch and window_mask is not None:
            lengths = jnp.broadcast_to(jnp.asarray(cache_offset), (B,))
            ctx = kops.tree_attention(q, k_cache, v_cache, lengths,
                                      window_mask)
        else:
            # contiguous sequential windows have no materialization to skip:
            # the cache IS the attention operand, so the jnp path stays.
            ctx = gqa_attention(q, k_cache, v_cache, mask)
    out = ctx.reshape(B, S, num_heads * head_dim) @ params["wo"]
    return out, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {"w_gate": dense_init(k1, d_model, d_ff, dtype),
                "w_up": dense_init(k2, d_model, d_ff, dtype),
                "w_down": dense_init(k3, d_ff, d_model, dtype)}
    return {"w_up": dense_init(k1, d_model, d_ff, dtype),
            "w_down": dense_init(k2, d_ff, d_model, dtype)}


def mlp_apply(params: Params, x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]
    if activation == "geglu":
        return (jax.nn.gelu(x @ params["w_gate"], approximate=True)
                * (x @ params["w_up"])) @ params["w_down"]
    if activation == "gelu":
        return jax.nn.gelu(x @ params["w_up"], approximate=True) @ params["w_down"]
    raise ValueError(f"unknown activation {activation}")


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based dispatch with capacity)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    activation: str = "swiglu"
    capacity_factor: float = 1.25
    num_shared_experts: int = 0    # always-on shared experts (DeepSeek style)
    shared_d_ff: int = 0
    dense_residual: bool = False   # Arctic-style parallel dense MLP
    dense_d_ff: int = 0


def init_moe(key, d_model: int, mcfg: MoEConfig, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 6)
    E, F = mcfg.num_experts, mcfg.d_ff
    scale = 1.0 / np.sqrt(d_model)
    p = {
        "router": dense_init(keys[0], d_model, E, dtype=jnp.float32),
        "w_gate": (jax.random.normal(keys[1], (E, d_model, F)) * scale).astype(dtype),
        "w_up": (jax.random.normal(keys[2], (E, d_model, F)) * scale).astype(dtype),
        "w_down": (jax.random.normal(keys[3], (E, F, d_model)) / np.sqrt(F)).astype(dtype),
    }
    if mcfg.num_shared_experts > 0:
        p["shared"] = init_mlp(keys[4], d_model,
                               mcfg.shared_d_ff or F * mcfg.num_shared_experts,
                               mcfg.activation, dtype)
    if mcfg.dense_residual:
        p["dense"] = init_mlp(keys[5], d_model, mcfg.dense_d_ff or F,
                              mcfg.activation, dtype)
    return p


def moe_apply(params: Params, x: jax.Array, mcfg: MoEConfig,
              capacity_factor: float | None = None) -> tuple[jax.Array, jax.Array]:
    """Sort-based top-k MoE dispatch (capacity-dropped, GShard-style).

    x: (B, S, d). Returns (out, aux_loss) where aux_loss is the load-balancing
    loss of Switch Transformers.
    """
    B, S, d = x.shape
    E, K = mcfg.num_experts, mcfg.top_k
    cf = capacity_factor if capacity_factor is not None else mcfg.capacity_factor
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balancing aux loss (Switch eq. 4).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch, gather-only on the (.., d) tensors ---
    # SPMD partitions gathers far better than scatters (a scatter into a
    # sharded (E*C, d) buffer makes GSPMD replicate one-hot u32 machinery of
    # the same size); the scatters below touch only O(E*C) int32/bool rows.
    C = int(np.ceil(T * K / E * cf))
    flat_expert = expert_idx.reshape(-1)                        # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # position of each routed item within its expert's run
    first_occurrence = jnp.searchsorted(sorted_expert, sorted_expert, side="left")
    pos_in_expert = jnp.arange(T * K) - first_occurrence
    keep = pos_in_expert < C
    slot = jnp.where(keep, sorted_expert * C + pos_in_expert, E * C)  # E*C = drop bin

    # NOTE(§Perf log): three dispatch variants were measured on the arctic
    # train cell — plain scatter (81.7 GB/chip), scatter+expert-constraint
    # (113.6 GB), gather-only+constraint (280 GB).  GSPMD replicates scatter
    # one-hot machinery either way; plain scatter without constraints is the
    # best current baseline, ragged/shard_map dispatch is future work.
    buf = jnp.zeros((E * C + 1, d), dtype=x.dtype).at[slot].set(xt[sorted_token])
    buf = buf[:-1].reshape(E, C, d)

    if mcfg.activation in ("swiglu", "geglu"):
        act = jax.nn.silu if mcfg.activation == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, params["w_up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(E * C, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), out_buf.dtype)], axis=0)

    contrib = out_buf[slot] * (sorted_gate * keep)[:, None].astype(x.dtype)
    yt = jax.ops.segment_sum(contrib, sorted_token, num_segments=T)

    y = yt.reshape(B, S, d)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], x, mcfg.activation)
    if "dense" in params:
        y = y + mlp_apply(params["dense"], x, mcfg.activation)
    return y, aux
