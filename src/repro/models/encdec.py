"""Whisper-style encoder-decoder (audio backbone, conv frontend stubbed).

Per the assignment the modality frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, T_frames, d_model).  The decoder is a causal
transformer with cross-attention into the encoder output; decode shapes
exercise it with a self-attention KV cache plus fixed cross-attention KV.
Positions are learned embeddings (whisper has no RoPE).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint

from .layers import (
    attention_apply,
    dense_init,
    embed_init,
    gqa_attention,
    init_attention,
    init_mlp,
    make_norm,
    mlp_apply,
)
from .transformer import _dtype, _stack

Params = Any

_MAX_DECODER_POS = 33024  # covers the decode_32k cell (+ draft window)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.init_norm, self.norm = make_norm(cfg.norm)

    # ------------------------------------------------------------------

    def _init_enc_block(self, key) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        ka, km = jax.random.split(key)
        return {
            "ln_attn": self.init_norm(cfg.d_model, dtype),
            "attn": init_attention(ka, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim, dtype=dtype),
            "ln_mlp": self.init_norm(cfg.d_model, dtype),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
        }

    def _init_dec_block(self, key) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        ka, kx, km = jax.random.split(key, 3)
        return {
            "ln_self": self.init_norm(cfg.d_model, dtype),
            "self_attn": init_attention(ka, cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, cfg.head_dim, dtype=dtype),
            "ln_cross": self.init_norm(cfg.d_model, dtype),
            "cross_attn": init_attention(kx, cfg.d_model, cfg.num_heads,
                                         cfg.num_kv_heads, cfg.head_dim, dtype=dtype),
            "ln_mlp": self.init_norm(cfg.d_model, dtype),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
        }

    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        keys = jax.random.split(key, 6)
        enc_keys = jax.random.split(keys[0], cfg.num_encoder_layers)
        dec_keys = jax.random.split(keys[1], cfg.num_layers)
        return {
            "embed": embed_init(keys[2], cfg.vocab_size, cfg.d_model, dtype),
            "pos_embed": (jax.random.normal(keys[3], (_MAX_DECODER_POS, cfg.d_model))
                          * 0.02).astype(dtype),
            "enc_pos_embed": (jax.random.normal(keys[4], (cfg.encoder_seq_len, cfg.d_model))
                              * 0.02).astype(dtype),
            "enc_blocks": _stack([self._init_enc_block(k) for k in enc_keys]),
            "dec_blocks": _stack([self._init_dec_block(k) for k in dec_keys]),
            "ln_enc": self.init_norm(cfg.d_model, dtype),
            "ln_f": self.init_norm(cfg.d_model, dtype),
            "unembed": dense_init(keys[5], cfg.d_model, cfg.vocab_size, dtype),
        }

    # ------------------------------------------------------------------

    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: (B, T_frames, d_model) stub embeddings -> encoder output."""
        cfg = self.cfg
        x = frames.astype(_dtype(cfg.compute_dtype))
        x = x + params["enc_pos_embed"][None, :x.shape[1]].astype(x.dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def enc_block(p, x):
            h = self.norm(p["ln_attn"], x)
            attn, _ = attention_apply(
                p["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.head_dim, positions=positions, mask=None,
                rope_theta=None)
            x = x + attn
            h = self.norm(p["ln_mlp"], x)
            return x + mlp_apply(p["mlp"], h, cfg.activation)

        if cfg.remat:
            enc_block = jax.checkpoint(enc_block)

        def body(carry, p):
            return enc_block(p, carry), None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                            unroll=self.cfg.scan_unroll)
        return self.norm(params["ln_enc"], x)

    def _cross_kv(self, params, enc_out: jax.Array):
        """Precompute per-layer cross-attention K/V from the encoder output."""
        cfg = self.cfg
        B, S, _ = enc_out.shape

        def body(_, p):
            k = (enc_out @ p["cross_attn"]["wk"]).reshape(
                B, S, cfg.num_kv_heads, cfg.head_dim)
            v = (enc_out @ p["cross_attn"]["wv"]).reshape(
                B, S, cfg.num_kv_heads, cfg.head_dim)
            return None, (k, v)

        _, (ck, cv) = jax.lax.scan(body, None, params["dec_blocks"],
                                   unroll=self.cfg.scan_unroll)
        return ck, cv  # (L, B, S_enc, KV, D)

    def _dec_block(self, p, x, positions, mask, cross_k, cross_v,
                   kv_cache=None, offset=None):
        cfg = self.cfg
        h = self.norm(p["ln_self"], x)
        attn, kv = attention_apply(
            p["self_attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, positions=positions, mask=mask,
            rope_theta=None, kv_cache=kv_cache, cache_offset=offset)
        x = x + attn
        # cross attention: no mask (all encoder frames valid), no rope
        h = self.norm(p["ln_cross"], x)
        B, T, _ = h.shape
        q = (h @ p["cross_attn"]["wq"]).reshape(B, T, cfg.num_heads, cfg.head_dim)
        cross = gqa_attention(q, cross_k.astype(h.dtype), cross_v.astype(h.dtype), None)
        x = x + cross.reshape(B, T, -1) @ p["cross_attn"]["wo"]
        h = self.norm(p["ln_mlp"], x)
        return x + mlp_apply(p["mlp"], h, cfg.activation), kv

    def _decoder(self, params, tokens, cross_k, cross_v, positions, mask,
                 cache=None, offset=None):
        cfg = self.cfg
        x = params["embed"][tokens].astype(_dtype(cfg.compute_dtype))
        pos_idx = jnp.clip(positions, 0, _MAX_DECODER_POS - 1)
        x = x + params["pos_embed"][pos_idx].astype(x.dtype)
        use_cache = cache is not None

        def dec_block(p, x, ck, cv, kv_in):
            return self._dec_block(p, x, positions, mask, ck, cv,
                                   kv_cache=kv_in, offset=offset)

        if cfg.remat:
            dec_block = jax.checkpoint(dec_block)

        def body(carry, xs):
            x = carry
            if use_cache:
                p, ck, cv, kc, vc = xs
                x, kv = dec_block(p, x, ck, cv, (kc, vc))
                return x, (kv[0], kv[1])
            p, ck, cv = xs
            x, _ = dec_block(p, x, ck, cv, None)
            return x, None

        if use_cache:
            xs = (params["dec_blocks"], cross_k, cross_v, cache["k"], cache["v"])
            x, (k_new, v_new) = jax.lax.scan(body, x, xs,
                                             unroll=self.cfg.scan_unroll)
            new_cache = dict(cache)
            new_cache["k"], new_cache["v"] = k_new, v_new
        else:
            x, _ = jax.lax.scan(body, x, (params["dec_blocks"], cross_k, cross_v),
                                unroll=self.cfg.scan_unroll)
            new_cache = None
        x = self.norm(params["ln_f"], x)
        logits = (x @ params["unembed"].astype(x.dtype)).astype(jnp.float32)
        logits = logical_constraint(logits, "batch", None, "vocab")
        return logits, new_cache

    # ------------------------------------------------------------------
    # Unified API (frames go through ``prefix_embeds``)
    # ------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        L = cfg.num_layers
        shape = (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        enc_shape = (L, batch, cfg.encoder_seq_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "cross_k": jnp.zeros(enc_shape, dtype),
                "cross_v": jnp.zeros(enc_shape, dtype)}

    CACHE_BATCH_AXES = {"k": 1, "v": 1, "cross_k": 1, "cross_v": 1}

    def concat_caches(self, caches: list) -> Params:
        return {key: jnp.concatenate([c[key] for c in caches],
                                     axis=self.CACHE_BATCH_AXES[key])
                for key in caches[0]}

    def apply(self, params, tokens, prefix_embeds=None):
        """Training forward: frames (prefix_embeds) + decoder tokens."""
        assert prefix_embeds is not None, "whisper training needs frame embeddings"
        enc_out = self.encode(params, prefix_embeds)
        ck, cv = self._cross_kv(params, enc_out)
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        mask = (jnp.arange(S)[None, :] <= jnp.arange(S)[:, None])[None, None, None]
        logits, _ = self._decoder(params, tokens, ck, cv, positions, mask)
        return logits, jnp.zeros((), jnp.float32)

    def prefill(self, params, tokens, cache, prefix_embeds=None):
        assert prefix_embeds is not None
        enc_out = self.encode(params, prefix_embeds)
        ck, cv = self._cross_kv(params, enc_out)
        cache = dict(cache)
        cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
        cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
        B, S = tokens.shape
        S_max = cache["k"].shape[2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        mask = (jnp.arange(S_max)[None, :] <= jnp.arange(S)[:, None])[None, None, None]
        logits, cache = self._decoder(params, tokens, ck, cv, positions, mask,
                                      cache=cache, offset=jnp.zeros((), jnp.int32))
        return logits, cache, jnp.zeros((), jnp.float32)

    def forward_window(self, params, tokens, cache, pos):
        B, T = tokens.shape
        S_max = cache["k"].shape[2]
        positions = pos[:, None] + jnp.arange(T)[None, :]
        kj = jnp.arange(S_max)[None, None, :]
        mask = (kj <= positions[:, :, None])[:, None, None]
        logits, cache = self._decoder(
            params, tokens, cache["cross_k"], cache["cross_v"], positions, mask,
            cache=cache, offset=pos)
        return logits, cache

    def num_params(self, params) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
