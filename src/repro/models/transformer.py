"""Decoder-only transformer LM (dense / MoE / VLM-backbone).

One unified forward covers all serving modes:

  * ``apply``        — full causal forward (training / scoring), no cache
  * ``prefill``      — forward that also writes the KV cache
  * ``forward_window`` — T new tokens against an existing cache at per-row
    offsets: T=1 is decode, T=L+1 is batched speculative verification (the
    paper's server-side op)

Layers are stacked on a leading axis and traversed with ``jax.lax.scan`` so
the lowered HLO stays O(1) in depth (fast multi-pod compiles, clean remat).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from repro.distributed.sharding import logical_constraint

from .layers import (
    MoEConfig,
    attention_apply,
    dense_init,
    embed_init,
    init_attention,
    init_mlp,
    init_moe,
    make_norm,
    mlp_apply,
    moe_apply,
    tree_window_mask,
)

Params = Any


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def strip_view(cache: Params) -> Params:
    """Drop the ``"pages"`` page-table entry from a paged cache view,
    leaving only the pool leaves.  The serving engine adopts the cache a
    forward returns and must never retain the table inside a cache it later
    passes to a DONATING jitted step: the page allocator's device mirror
    owns the live table, and a stale copy riding in the cache would either
    leak or alias a donated buffer.  No-op for contiguous caches."""
    if "pages" not in cache:
        return cache
    return {k: v for k, v in cache.items() if k != "pages"}


class DecoderLM:
    """Functional decoder-only LM parameterized by ``ModelConfig``."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.init_norm, self.norm = make_norm(cfg.norm)
        self.moe_cfg = None
        if cfg.num_experts:
            self.moe_cfg = MoEConfig(
                num_experts=cfg.num_experts, top_k=cfg.top_k, d_ff=cfg.moe_d_ff,
                activation=cfg.activation, capacity_factor=cfg.capacity_factor,
                num_shared_experts=cfg.num_shared_experts,
                shared_d_ff=cfg.shared_d_ff, dense_residual=cfg.dense_residual,
                dense_d_ff=cfg.d_ff,
            )

    @property
    def no_drop_capacity(self) -> float:
        """Capacity factor at which dropping is impossible (C = T tokens):
        cf = E / k since C = ceil(T k / E * cf)."""
        assert self.moe_cfg is not None
        return self.moe_cfg.num_experts / self.moe_cfg.top_k

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------

    def _init_block(self, key, moe: bool) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        k_attn, k_mlp = jax.random.split(key)
        p = {
            "ln_attn": self.init_norm(cfg.d_model, dtype),
            "attn": init_attention(k_attn, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim,
                                   qkv_bias=cfg.qkv_bias, dtype=dtype),
            "ln_mlp": self.init_norm(cfg.d_model, dtype),
        }
        if moe:
            p["moe"] = init_moe(k_mlp, cfg.d_model, self.moe_cfg, dtype)
        else:
            p["mlp"] = init_mlp(k_mlp, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
        return p

    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        k_embed, k_blocks, k_head, k_extra = jax.random.split(key, 4)
        n_dense = cfg.first_k_dense if cfg.num_experts else cfg.num_layers
        n_moe = cfg.num_layers - n_dense if cfg.num_experts else 0
        if not cfg.num_experts:
            n_dense, n_moe = 0, 0  # all layers homogeneous, stacked below

        params: Params = {
            "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
            "ln_f": self.init_norm(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)

        if cfg.num_experts:
            if n_dense:
                keys = jax.random.split(k_extra, n_dense)
                params["dense_blocks"] = _stack([self._init_block(k, moe=False)
                                                 for k in keys])
            keys = jax.random.split(k_blocks, n_moe)
            params["blocks"] = _stack([self._init_block(k, moe=True) for k in keys])
        else:
            keys = jax.random.split(k_blocks, cfg.num_layers)
            params["blocks"] = _stack([self._init_block(k, moe=False) for k in keys])
        return params

    # ------------------------------------------------------------------
    # Cache
    # ------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        n_scan = cfg.num_layers - (cfg.first_k_dense if cfg.num_experts else 0)
        n_dense = cfg.first_k_dense if cfg.num_experts else 0
        shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        cache = {"k": jnp.zeros((n_scan,) + shape, dtype),
                 "v": jnp.zeros((n_scan,) + shape, dtype)}
        if n_dense:
            cache["dense_k"] = jnp.zeros((n_dense,) + shape, dtype)
            cache["dense_v"] = jnp.zeros((n_dense,) + shape, dtype)
        return cache

    def cache_spec(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len, dtype))

    def init_paged_cache(self, num_pages: int, page_size: int,
                         dtype=jnp.bfloat16) -> Params:
        """Paged KV pool: every attention leaf is (layers, P, ps, KV, D) with
        NO batch axis — streams own pages, not rows.  Pair it with a
        ``"pages"`` (B, n_slots) int32 page table (``serving.PagedKVCache``)
        to form a per-batch cache view accepted by ``prefill`` /
        ``forward_window``."""
        cfg = self.cfg
        n_scan = cfg.num_layers - (cfg.first_k_dense if cfg.num_experts else 0)
        n_dense = cfg.first_k_dense if cfg.num_experts else 0
        shape = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
        cache = {"k": jnp.zeros((n_scan,) + shape, dtype),
                 "v": jnp.zeros((n_scan,) + shape, dtype)}
        if n_dense:
            cache["dense_k"] = jnp.zeros((n_dense,) + shape, dtype)
            cache["dense_v"] = jnp.zeros((n_dense,) + shape, dtype)
        return cache

    CACHE_BATCH_AXES = {"k": 1, "v": 1, "dense_k": 1, "dense_v": 1}

    strip_view = staticmethod(strip_view)

    @staticmethod
    def _cache_kv_capacity(cache: Params) -> int:
        """Logical KV positions per row: S for contiguous (B, S, KV, D)
        leaves, n_slots * page_size for a paged view."""
        if "pages" in cache:
            return cache["pages"].shape[1] * cache["k"].shape[2]
        return cache["k"].shape[2]

    def concat_caches(self, caches: list) -> Params:
        """Stack per-row caches (ragged prefill) into one batch."""
        return {key: jnp.concatenate([c[key] for c in caches],
                                     axis=self.CACHE_BATCH_AXES[key])
                for key in caches[0]}

    # ------------------------------------------------------------------
    # Forward
    # ------------------------------------------------------------------

    def _block_apply(self, p: Params, x, *, moe: bool, positions, mask,
                     kv_cache=None, offset=None, moe_capacity=None,
                     page_table=None, window_mask=None, causal_window=False):
        cfg = self.cfg
        h = self.norm(p["ln_attn"], x)
        attn_out, kv = attention_apply(
            p["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, positions=positions, mask=mask,
            rope_theta=cfg.rope_theta, kv_cache=kv_cache, cache_offset=offset,
            page_table=page_table, window_mask=window_mask,
            causal_window=causal_window)
        x = x + attn_out
        h = self.norm(p["ln_mlp"], x)
        if moe:
            mlp_out, aux = moe_apply(p["moe"], h, self.moe_cfg,
                                     capacity_factor=moe_capacity)
        else:
            mlp_out, aux = mlp_apply(p["mlp"], h, cfg.activation), jnp.zeros((), jnp.float32)
        return x + mlp_out, kv, aux

    def _stack_forward(self, params, x, positions, mask, cache=None, offset=None,
                       moe_capacity=None, window_mask=None,
                       causal_window=False):
        """Run all blocks; returns (hidden, new_cache, aux_sum)."""
        cfg = self.cfg
        use_cache = cache is not None
        page_table = cache.get("pages") if use_cache else None

        def block_fn(p, x, kv_in):
            # positions/mask/offset/page_table are closure-captured: they
            # carry no gradient, and jax.checkpoint must not trace the
            # python-bool configuration kwargs.
            return self._block_apply(p, x, moe=self.moe_cfg is not None,
                                     positions=positions, mask=mask,
                                     kv_cache=kv_in, offset=offset,
                                     moe_capacity=moe_capacity,
                                     page_table=page_table,
                                     window_mask=window_mask,
                                     causal_window=causal_window)

        if cfg.remat:
            block_fn = jax.checkpoint(block_fn)

        def scan_body(carry, xs):
            x = carry
            if use_cache:
                p, kc, vc = xs
                kv_in = (kc, vc)
            else:
                p = xs
                kv_in = None
            x, kv, aux = block_fn(p, x, kv_in)
            return x, (kv[0], kv[1], aux)

        new_cache = dict(cache) if use_cache else None
        aux_total = jnp.zeros((), jnp.float32)

        # Leading dense blocks (MoE stacks with first_k_dense > 0).
        if "dense_blocks" in params:
            def dense_body(carry, xs):
                x = carry
                if use_cache:
                    p, kc, vc = xs
                    kv_in = (kc, vc)
                else:
                    p = xs
                    kv_in = None
                x, kv, aux = self._block_apply(
                    p, x, moe=False, positions=positions, mask=mask,
                    kv_cache=kv_in, offset=offset, page_table=page_table,
                    window_mask=window_mask, causal_window=causal_window)
                return x, (kv[0], kv[1], aux)

            xs = ((params["dense_blocks"], cache["dense_k"], cache["dense_v"])
                  if use_cache else params["dense_blocks"])
            x, (dk, dv, aux) = jax.lax.scan(dense_body, x, xs,
                                            unroll=cfg.scan_unroll)
            aux_total += jnp.sum(aux)
            if use_cache:
                new_cache["dense_k"], new_cache["dense_v"] = dk, dv

        xs = ((params["blocks"], cache["k"], cache["v"]) if use_cache
              else params["blocks"])
        x, (k_new, v_new, aux) = jax.lax.scan(scan_body, x, xs,
                                              unroll=cfg.scan_unroll)
        aux_total += jnp.sum(aux)
        if use_cache:
            new_cache["k"], new_cache["v"] = k_new, v_new
        return x, new_cache, aux_total

    def _embed(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens].astype(_dtype(cfg.compute_dtype))
        if prefix_embeds is not None:
            x = jnp.concatenate(
                [prefix_embeds.astype(x.dtype), x], axis=1)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        x = self.norm(params["ln_f"], x)
        w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
        logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
        return logical_constraint(logits, "batch", None, "vocab")

    def apply(self, params, tokens, prefix_embeds=None, moe_capacity=None):
        """Full causal forward. tokens: (B, S) -> logits (B, S[+P], V)."""
        x = self._embed(params, tokens, prefix_embeds)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(S)[None, :]
        mask = (kj <= qi)[None, None, None]
        x, _, aux = self._stack_forward(params, x, positions, mask,
                                        moe_capacity=moe_capacity)
        return self._logits(params, x), aux

    def prefill(self, params, tokens, cache, prefix_embeds=None,
                moe_capacity="no_drop"):
        """Causal forward writing the KV cache at offset 0.

        MoE dispatch defaults to exact no-drop capacity: serving prefill
        batches are modest (K devices x prompt) and the cache must reflect
        the exact model for verification to stay exact.  Pass an explicit
        capacity factor for throughput-oriented bulk prefill.
        """
        if moe_capacity == "no_drop":
            moe_capacity = self.no_drop_capacity if self.moe_cfg else None
        x = self._embed(params, tokens, prefix_embeds)
        B, S, _ = x.shape
        S_max = self._cache_kv_capacity(cache)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(S_max)[None, :]
        mask = (kj <= qi)[None, None, None]
        offset = jnp.zeros((), jnp.int32)
        # prefill IS a sequential window at offset 0 (row t attends
        # [0, t]), so the paged-attention kernel path applies
        x, cache, aux = self._stack_forward(params, x, positions, mask,
                                            cache=cache, offset=offset,
                                            moe_capacity=moe_capacity,
                                            causal_window=True)
        return self._logits(params, x), cache, aux

    def forward_window(self, params, tokens, cache, pos, window_mask=None,
                       window_depth=None):
        """T new tokens against an existing cache.

        tokens: (B, T); pos: (B,) per-row write offsets (current lengths).
        T=1 -> decode step; T=L+1 -> speculative-verification scoring.
        Returns (logits (B, T, V), new_cache).

        ``cache`` is either the contiguous layout (``init_cache``) or a paged
        view: ``init_paged_cache`` pools plus a ``"pages"`` (B, n_slots)
        page table — writes route through the table, numerics are identical.

        Token-TREE windows (multi-draft verification) pass ``window_mask``
        (B, T, T), the tree's ancestor-or-self matrix, and ``window_depth``
        (B, T) node depths: window token t keeps cache SLOT pos + t but
        takes rope position pos + depth_t and attends committed KV plus its
        in-window ancestors only.  Defaults (causal / arange) reproduce the
        sequential window bit-for-bit.

        MoE layers dispatch with NO-DROP capacity here (cf = E/k => capacity =
        num window tokens): speculative verification must score with the exact
        model distribution, and capacity dropping is batch-coupled.  Training
        and prefill keep the configured capacity factor (DESIGN.md §3).
        """
        x = self._embed(params, tokens)
        B, T, _ = x.shape
        S_max = self._cache_kv_capacity(cache)
        if window_depth is None:
            positions = pos[:, None] + jnp.arange(T)[None, :]
        else:
            positions = pos[:, None] + window_depth
        if window_mask is None:
            kj = jnp.arange(S_max)[None, None, :]
            mask = (kj <= positions[:, :, None])[:, None, None]  # (B,1,1,T,S)
        else:
            mask = tree_window_mask(pos, window_mask, S_max)
        moe_capacity = self.no_drop_capacity if self.moe_cfg else None
        # ``causal_window`` marks the standard sequential window (positions
        # pos + t): a window_depth WITHOUT a window_mask would put rope
        # positions out of step with the kernels' row-index mask, so it is
        # excluded from kernel dispatch.
        x, cache, _ = self._stack_forward(
            params, x, positions, mask, cache=cache, offset=pos,
            moe_capacity=moe_capacity, window_mask=window_mask,
            causal_window=window_mask is None and window_depth is None)
        return self._logits(params, x), cache

    def num_params(self, params) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def _stack(trees: list[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)
