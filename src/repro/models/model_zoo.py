"""Model dispatch + input specs for every (arch x shape) cell.

``build_model(cfg)`` returns an object with the unified functional API:

    init(key) -> params
    apply(params, tokens, prefix_embeds=None) -> (logits, aux_loss)
    init_cache(batch, max_len, dtype) -> cache
    prefill(params, tokens, cache, prefix_embeds=None) -> (logits, cache, aux)
    forward_window(params, tokens, cache, pos) -> (logits, cache)

``input_specs(cfg, shape)`` yields jax.ShapeDtypeStruct stand-ins for the
step functions of the dry-run (no device allocation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES, InputShape

from .encdec import EncDecLM
from .hybrid import HybridLM
from .ssm import MambaLM
from .transformer import DecoderLM


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def has_prefix_embeds(cfg: ModelConfig) -> bool:
    return cfg.family in ("vlm", "audio")


def prefix_len(cfg: ModelConfig) -> int:
    if cfg.family == "vlm":
        return cfg.num_patches
    if cfg.family == "audio":
        return cfg.encoder_seq_len
    return 0


def input_specs(cfg: ModelConfig, shape: InputShape | str,
                compute_dtype=jnp.bfloat16, per_pod_batch: bool = False,
                draft_window: int = 0) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    * train/prefill: {"tokens", ["prefix_embeds"]}
    * decode:       {"tokens" (B, 1+draft_window), "pos" (B,)} (cache specs
      are produced separately since they depend on the model object)
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.family == "vlm":
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), compute_dtype)
        elif cfg.family == "audio":
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq_len, cfg.d_model), compute_dtype)
    else:  # decode
        T = 1 + draft_window
        specs["tokens"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
        specs["pos"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    return specs


def cache_specs(cfg: ModelConfig, shape: InputShape | str,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs of the KV/SSM cache for a decode cell."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    model = build_model(cfg)
    max_len = shape.seq_len + prefix_len(cfg)
    return jax.eval_shape(lambda: model.init_cache(shape.global_batch, max_len,
                                                   dtype))
