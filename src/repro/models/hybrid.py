"""Zamba2-style hybrid: Mamba-2 backbone with a shared attention block.

``hybrid_group`` Mamba2 layers form a group; after each group the single
shared transformer block (attention + MLP, one weight set) runs with its own
per-invocation KV cache.  54 layers / group 6 -> 9 shared-block invocations.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from .layers import (
    attention_apply,
    dense_init,
    embed_init,
    init_attention,
    init_mlp,
    init_rmsnorm,
    mlp_apply,
    rmsnorm,
)
from repro.distributed.sharding import logical_constraint

from .ssm import init_mamba2_block, mamba2_block_apply, ssm_dims
from .transformer import _dtype, _stack

Params = Any


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.num_layers % cfg.hybrid_group == 0
        self.num_groups = cfg.num_layers // cfg.hybrid_group

    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        k_embed, k_blocks, k_shared, k_head = jax.random.split(key, 4)
        keys = jax.random.split(k_blocks, cfg.num_layers)
        mamba = _stack([init_mamba2_block(k, cfg) for k in keys])
        # regroup leading axis (L,) -> (groups, group_size)
        mamba = jax.tree.map(
            lambda x: x.reshape((self.num_groups, cfg.hybrid_group) + x.shape[1:]),
            mamba)
        ka, km = jax.random.split(k_shared)
        shared = {
            "ln_attn": init_rmsnorm(cfg.d_model, dtype),
            "attn": init_attention(ka, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim, dtype=dtype),
            "ln_mlp": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
        }
        params = {
            "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
            "ln_f": init_rmsnorm(cfg.d_model, dtype),
            "mamba": mamba,
            "shared": shared,
        }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
        return params

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        d_inner, nheads, g, n, conv_dim = ssm_dims(cfg)
        L, G = cfg.num_layers, self.num_groups
        return {
            "ssm": jnp.zeros((G, cfg.hybrid_group, batch, nheads,
                              cfg.ssm_head_dim, n), jnp.float32),
            "conv": jnp.zeros((G, cfg.hybrid_group, batch,
                               cfg.ssm_conv_width - 1, conv_dim), dtype),
            "k": jnp.zeros((G, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((G, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        }

    CACHE_BATCH_AXES = {"ssm": 2, "conv": 2, "k": 1, "v": 1}

    def concat_caches(self, caches: list) -> Params:
        return {key: jnp.concatenate([c[key] for c in caches],
                                     axis=self.CACHE_BATCH_AXES[key])
                for key in caches[0]}

    def _shared_block(self, params, x, positions, mask, kv_cache=None, offset=None):
        cfg = self.cfg
        p = params["shared"]
        h = rmsnorm(p["ln_attn"], x)
        attn_out, kv = attention_apply(
            p["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, positions=positions, mask=mask,
            rope_theta=cfg.rope_theta, kv_cache=kv_cache, cache_offset=offset)
        x = x + attn_out
        h = rmsnorm(p["ln_mlp"], x)
        return x + mlp_apply(p["mlp"], h, cfg.activation), kv

    def _forward(self, params, x, positions, mask, cache=None, offset=None,
                 decode=False):
        cfg = self.cfg
        use_cache = cache is not None

        def mamba_body(carry, xs):
            x = carry
            if use_cache:
                p, ssm_s, conv_s = xs
            else:
                p, ssm_s, conv_s = xs, None, None
            fn = mamba2_block_apply
            if cfg.remat:
                fn = jax.checkpoint(fn, static_argnums=(2, 5))
            x, new_ssm, new_conv = fn(p, x, cfg, ssm_s, conv_s, decode)
            if new_ssm is None:
                new_ssm = jnp.zeros((), jnp.float32)
            if new_conv is None:
                new_conv = jnp.zeros((), jnp.float32)
            return x, (new_ssm, new_conv)

        def shared_fn(x, kv_in):
            return self._shared_block(params, x, positions, mask,
                                      kv_cache=kv_in, offset=offset)

        def shared_fn_nocache(x):
            return self._shared_block(params, x, positions, mask)

        if cfg.remat:
            shared_fn = jax.checkpoint(shared_fn)
            shared_fn_nocache = jax.checkpoint(shared_fn_nocache)

        def group_body(carry, xs):
            x = carry
            if use_cache:
                mp, ssm_s, conv_s, kc, vc = xs
                x, (new_ssm, new_conv) = jax.lax.scan(mamba_body, x,
                                                      (mp, ssm_s, conv_s),
                                                      unroll=cfg.scan_unroll)
                x, kv = shared_fn(x, (kc, vc))
                return x, (new_ssm, new_conv, kv[0], kv[1])
            mp = xs
            x, _ = jax.lax.scan(mamba_body, x, mp, unroll=cfg.scan_unroll)
            x, _ = shared_fn_nocache(x)
            return x, jnp.zeros((), jnp.float32)

        if use_cache:
            xs = (params["mamba"], cache["ssm"], cache["conv"], cache["k"], cache["v"])
            x, (ssm_new, conv_new, k_new, v_new) = jax.lax.scan(
                group_body, x, xs, unroll=cfg.scan_unroll)
            new_cache = {"ssm": ssm_new, "conv": conv_new, "k": k_new, "v": v_new}
        else:
            x, _ = jax.lax.scan(group_body, x, params["mamba"],
                                unroll=cfg.scan_unroll)
            new_cache = None
        return x, new_cache

    def _logits(self, params, x):
        x = rmsnorm(params["ln_f"], x)
        w = params["embed"].T if self.cfg.tie_embeddings else params["unembed"]
        logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
        return logical_constraint(logits, "batch", None, "vocab")

    def apply(self, params, tokens, prefix_embeds=None):
        x = params["embed"][tokens].astype(_dtype(self.cfg.compute_dtype))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        mask = (jnp.arange(S)[None, :] <= jnp.arange(S)[:, None])[None, None, None]
        x, _ = self._forward(params, x, positions, mask)
        return self._logits(params, x), jnp.zeros((), jnp.float32)

    def prefill(self, params, tokens, cache, prefix_embeds=None):
        x = params["embed"][tokens].astype(_dtype(self.cfg.compute_dtype))
        B, S, _ = x.shape
        S_max = cache["k"].shape[2]  # (G, B, S_max, KV, D)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        mask = (jnp.arange(S_max)[None, :] <= jnp.arange(S)[:, None])[None, None, None]
        offset = jnp.zeros((), jnp.int32)
        x, cache = self._forward(params, x, positions, mask, cache=cache,
                                 offset=offset)
        return self._logits(params, x), cache, jnp.zeros((), jnp.float32)

    def forward_window(self, params, tokens, cache, pos, return_snapshots=False):
        B, T = tokens.shape
        S_max = cache["k"].shape[2]  # (G, B, S_max, KV, D)
        logits_steps, snaps = [], []
        for t in range(T):
            x = params["embed"][tokens[:, t:t + 1]].astype(
                _dtype(self.cfg.compute_dtype))
            positions = (pos + t)[:, None]
            kj = jnp.arange(S_max)[None, None, :]
            mask = (kj <= positions[:, :, None])[:, None, None]
            x, cache = self._forward(params, x, positions, mask, cache=cache,
                                     offset=pos + t, decode=True)
            logits_steps.append(self._logits(params, x))
            if return_snapshots:
                # KV entries are rollback-free (masked by pos); only the SSM
                # recurrent state needs per-step snapshots.
                snaps.append({"ssm": cache["ssm"], "conv": cache["conv"]})
        logits = jnp.concatenate(logits_steps, axis=1)
        if return_snapshots:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *snaps)
            return logits, cache, stacked
        return logits, cache

    def num_params(self, params) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
