"""Mamba-2 (SSD) attention-free language model.

Block layout follows the Mamba-2 paper: in_proj -> (z | xBC | dt), short causal
depthwise conv over xBC, SSD scan, gated RMSNorm, out_proj.  Full-sequence
forwards use the chunked SSD algorithm (``kernels.ref.ssd_scan_ref`` or the
Pallas kernel); decode uses the O(1) recurrence with (ssm state, conv state)
carried in the cache.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_constraint
from repro.kernels import ops as kops

from .layers import dense_init, embed_init, init_rmsnorm, rmsnorm
from .transformer import _dtype, _stack

Params = Any


def ssm_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    g = cfg.ssm_ngroups
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * g * n
    return d_inner, nheads, g, n, conv_dim


def init_mamba2_block(key, cfg: ModelConfig) -> Params:
    dtype = _dtype(cfg.param_dtype)
    d_inner, nheads, g, n, conv_dim = ssm_dims(cfg)
    d_in_proj = 2 * d_inner + 2 * g * n + nheads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln": init_rmsnorm(cfg.d_model, dtype),
        "in_proj": dense_init(k1, cfg.d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv_width, conv_dim))
                   / np.sqrt(cfg.ssm_conv_width)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(dtype),
        "D": jnp.ones((nheads,), dtype),
        "dt_bias": jnp.zeros((nheads,), dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(k3, d_inner, cfg.d_model, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal 1-D conv.  x: (B, S, C); w: (W, C).

    Returns (y (B, S, C), new_state (B, W-1, C)) where state carries the last
    W-1 inputs for streaming decode.
    """
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xw = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xw[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xw[:, -(W - 1):] if W > 1 else state
    return y + b, new_state


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(y.dtype)


def mamba2_block_apply(p: Params, x: jax.Array, cfg: ModelConfig,
                       ssm_state: jax.Array | None = None,
                       conv_state: jax.Array | None = None,
                       decode: bool = False):
    """x: (B, S, d_model).  Returns (out, new_ssm_state, new_conv_state)."""
    d_inner, nheads, g, n, conv_dim = ssm_dims(cfg)
    B_, S, _ = x.shape
    h = rmsnorm(p["ln"], x)
    zxbcdt = h @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    xBC, new_conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xs, Bs, Cs = jnp.split(xBC, [d_inner, d_inner + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xs.reshape(B_, S, nheads, cfg.ssm_head_dim)
    Bh = Bs.reshape(B_, S, g, n)
    Ch = Cs.reshape(B_, S, g, n)

    if decode:
        assert S == 1
        y, new_state = kops.ssd_decode(
            xh[:, 0], dt[:, 0], A, Bh[:, 0], Ch[:, 0],
            ssm_state if ssm_state is not None
            else jnp.zeros((B_, nheads, cfg.ssm_head_dim, n), jnp.float32))
        y = y[:, None]
    else:
        y, new_state = kops.ssd_scan(xh, dt, A, Bh, Ch, chunk=cfg.ssm_chunk,
                                     initial_state=ssm_state)
    y = y + xh * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B_, S, d_inner)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return x + y @ p["out_proj"], new_state, new_conv_state


class MambaLM:
    """Attention-free Mamba-2 LM (mamba2-130m family)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        k_embed, k_blocks, k_head = jax.random.split(key, 3)
        keys = jax.random.split(k_blocks, cfg.num_layers)
        params = {
            "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
            "ln_f": init_rmsnorm(cfg.d_model, dtype),
            "blocks": _stack([init_mamba2_block(k, cfg) for k in keys]),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
        return params

    def init_cache(self, batch: int, max_len: int = 0, dtype=jnp.bfloat16) -> Params:
        """SSM cache is O(1) in sequence length (max_len unused, kept for API
        parity with attention models)."""
        cfg = self.cfg
        d_inner, nheads, g, n, conv_dim = ssm_dims(cfg)
        L = cfg.num_layers
        return {
            "ssm": jnp.zeros((L, batch, nheads, cfg.ssm_head_dim, n), jnp.float32),
            "conv": jnp.zeros((L, batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        }

    CACHE_BATCH_AXES = {"ssm": 1, "conv": 1}

    def concat_caches(self, caches: list) -> Params:
        return {key: jnp.concatenate([c[key] for c in caches],
                                     axis=self.CACHE_BATCH_AXES[key])
                for key in caches[0]}

    def _stack_forward(self, params, x, cache=None, decode=False):
        cfg = self.cfg
        use_cache = cache is not None

        def body(carry, xs):
            x = carry
            if use_cache:
                p, ssm_s, conv_s = xs
            else:
                p, ssm_s, conv_s = xs, None, None
            fn = mamba2_block_apply
            if cfg.remat:
                fn = jax.checkpoint(fn, static_argnums=(2, 5))
            x, new_ssm, new_conv = fn(p, x, cfg, ssm_s, conv_s, decode)
            if new_ssm is None:
                new_ssm = jnp.zeros((), jnp.float32)
            if new_conv is None:
                new_conv = jnp.zeros((), jnp.float32)
            return x, (new_ssm, new_conv)

        xs = ((params["blocks"], cache["ssm"], cache["conv"]) if use_cache
              else params["blocks"])
        x, (ssm_new, conv_new) = jax.lax.scan(body, x, xs,
                                              unroll=cfg.scan_unroll)
        new_cache = {"ssm": ssm_new, "conv": conv_new} if use_cache else None
        return x, new_cache

    def _logits(self, params, x):
        x = rmsnorm(params["ln_f"], x)
        w = params["embed"].T if self.cfg.tie_embeddings else params["unembed"]
        logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
        return logical_constraint(logits, "batch", None, "vocab")

    def apply(self, params, tokens, prefix_embeds=None):
        x = params["embed"][tokens].astype(_dtype(self.cfg.compute_dtype))
        x, _ = self._stack_forward(params, x)
        return self._logits(params, x), jnp.zeros((), jnp.float32)

    def prefill(self, params, tokens, cache, prefix_embeds=None):
        x = params["embed"][tokens].astype(_dtype(self.cfg.compute_dtype))
        x, cache = self._stack_forward(params, x, cache=cache)
        return self._logits(params, x), cache, jnp.zeros((), jnp.float32)

    def forward_window(self, params, tokens, cache, pos, return_snapshots=False):
        """SSM decode is strictly sequential: unroll T steps of the
        recurrence (T is the small draft window, not the context).

        With return_snapshots=True also returns the cache after EVERY step
        (leading axis T) — speculative verification rolls the state back to
        the accepted position by selecting a snapshot per row.
        """
        B, T = tokens.shape
        logits_steps, snaps = [], []
        for t in range(T):
            x = params["embed"][tokens[:, t:t + 1]].astype(
                _dtype(self.cfg.compute_dtype))
            x, cache = self._stack_forward(params, x, cache=cache, decode=True)
            logits_steps.append(self._logits(params, x))
            if return_snapshots:
                snaps.append(cache)
        logits = jnp.concatenate(logits_steps, axis=1)
        if return_snapshots:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *snaps)
            return logits, cache, stacked
        return logits, cache

    def num_params(self, params) -> int:
        return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
