"""Pure-JAX model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM backbones."""

from .model_zoo import build_model, cache_specs, has_prefix_embeds, input_specs  # noqa: F401
