"""Closed-form roofline terms per (arch x shape x mesh).

Why analytic: on the CPU dry-run backend, XLA's cost_analysis over SPMD
modules is unstable (recorded evidence: a 2-layer unrolled probe reports
FEWER flops than the 1-layer probe — propagation chooses different
replication), and while-loop bodies are counted once.  The dry-run therefore
proves compilability + memory fit, while FLOPs/bytes/collective-bytes come
from exact closed forms below, derived from the same configs and the same
sharding rules the dry-run lowers with.  The HLO collective inventory is
still parsed and stored as a structural cross-check.

Conventions:
  * all quantities are PER CHIP per step unless suffixed _global
  * bf16 activations/params (2 bytes); fp32 logits, scores softmax (4)
  * training counts fwd + 2x bwd (+1x fwd remat) = 4x forward matmul FLOPs
  * XLA-baseline attention MATERIALIZES (B, H, Sq, Skv) scores in HBM; the
    Pallas flash-attention path sets ``flash=True`` and removes those bytes
    (that delta is a §Perf lever, measured analytically)
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import InputShape, ModelConfig

from .analysis import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class MeshInfo:
    chips: int
    dp: int          # data-parallel ways (pod * data)
    mp: int          # model-parallel ways


@dataclasses.dataclass
class TermBreakdown:
    flops: float = 0.0            # per chip
    hbm_bytes: float = 0.0        # per chip
    coll_bytes: float = 0.0       # per chip (sent over own links)
    detail: dict = dataclasses.field(default_factory=dict)

    def add(self, key, flops=0.0, hbm=0.0, coll=0.0):
        self.flops += flops
        self.hbm_bytes += hbm
        self.coll_bytes += coll
        self.detail[key] = {"flops": flops, "hbm": hbm, "coll": coll}


def _ring(bytes_, ways):
    """Per-chip wire bytes of a ring all-gather / reduce-scatter of a
    ``bytes_``-sized global tensor over ``ways`` participants."""
    if ways <= 1:
        return 0.0
    return bytes_ * (ways - 1) / ways


def attention_flops(T, Skv, cfg, causal_half=False):
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    proj = 2 * T * d * (H * Dh) * 2 + 2 * T * d * (KV * Dh) * 2   # q,o + k,v
    factor = 0.5 if causal_half else 1.0
    scores = 2 * T * Skv * H * Dh * factor * 2                     # qk^T + av
    return proj + scores


def mlp_flops(T, cfg):
    if cfg.d_ff == 0:
        return 0.0
    n_mat = 3 if cfg.activation in ("swiglu", "geglu") else 2
    return 2 * T * cfg.d_model * cfg.d_ff * n_mat


def moe_flops(T, cfg):
    n_mat = 3 if cfg.activation in ("swiglu", "geglu") else 2
    f = 2 * T * cfg.top_k * cfg.d_model * cfg.moe_d_ff * n_mat
    if cfg.num_shared_experts:
        f += 2 * T * cfg.d_model * (cfg.shared_d_ff or cfg.moe_d_ff) * n_mat
    if cfg.dense_residual:
        f += 2 * T * cfg.d_model * cfg.d_ff * n_mat
    # router
    f += 2 * T * cfg.d_model * cfg.num_experts
    return f


def mamba_flops(T, cfg, decode=False):
    from repro.models.ssm import ssm_dims
    d_inner, nheads, g, n, conv_dim = ssm_dims(cfg)
    d_in_proj = 2 * d_inner + 2 * g * n + nheads
    f = 2 * T * cfg.d_model * d_in_proj + 2 * T * d_inner * cfg.d_model
    f += 2 * T * conv_dim * cfg.ssm_conv_width
    if decode:
        # recurrence: state update + output: ~4 * H * P * N per token
        f += T * 4 * nheads * cfg.ssm_head_dim * n
    else:
        l = cfg.ssm_chunk
        P = cfg.ssm_head_dim
        # intra-chunk: (l x N)x(N x l) + (l x l)x(l x P); inter: 2 state GEMMs
        per_head_per_chunk = 2 * l * l * n + 2 * l * l * P + 4 * l * P * n
        f += T / l * per_head_per_chunk * nheads
    return f


def layer_flops(T, Skv, cfg, decode=False):
    """Forward FLOPs of ONE repeating layer/unit at T tokens (global)."""
    if cfg.family == "ssm":
        return mamba_flops(T, cfg, decode)
    if cfg.family == "hybrid":
        # one unit = hybrid_group mamba layers + 1 shared attn block
        f = mamba_flops(T, cfg, decode) * cfg.hybrid_group
        f += attention_flops(T, Skv, cfg) + mlp_flops(T, cfg)
        return f
    if cfg.family == "audio":
        # one unit = 1 encoder layer (handled separately) + 1 decoder layer
        return attention_flops(T, Skv, cfg) + mlp_flops(T, cfg)
    att = attention_flops(T, Skv, cfg)
    if cfg.num_experts:
        return att + moe_flops(T, cfg)
    return att + mlp_flops(T, cfg)


def n_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid_group
    return cfg.num_layers


def params_per_unit(cfg) -> float:
    """Approximate parameter count of one repeating unit (for FSDP traffic)."""
    from .analysis import count_params
    total, _ = count_params(cfg)
    embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return max((total - embed) / n_units(cfg), 1.0)


def roofline_terms(cfg: ModelConfig, shape: InputShape, mesh: MeshInfo,
                   flash: bool = False, microbatches: int = 4,
                   fsdp: bool = True, seq_shard: bool = False,
                   draft_window: int = 0, kv_bytes: int = 2) -> TermBreakdown:
    """Per-chip roofline terms for one cell under the repo's sharding rules.

    draft_window > 0 models the paper's speculative verification: decode
    steps process (1 + draft_window) tokens per row against the same cache.
    kv_bytes = 1 models an int8-quantized KV cache (per-head scales).
    """
    tb = TermBreakdown()
    B, S = shape.global_batch, shape.seq_len
    training = shape.kind == "train"
    decode = shape.kind == "decode"
    d, V = cfg.d_model, cfg.vocab_size
    L = n_units(cfg)
    dp, mp, chips = mesh.dp, mesh.mp, mesh.chips

    if decode:
        T_global = B * (1 + draft_window)  # window tokens per row
        Skv = S
    else:
        T_global = B * S
        Skv = S
    T_chip = T_global / chips             # activations sharded over all chips
    T_dp = T_global / dp                  # batch rows per data shard

    fwd_mult = 4.0 if (training and cfg.remat) else (3.0 if training else 1.0)

    # ---- per-layer compute ----
    f_layer_fwd_global = layer_flops(T_global, Skv, cfg, decode)
    tb.add("layers_compute",
           flops=f_layer_fwd_global * L * fwd_mult / chips)

    # ---- embed + logits ----
    f_logits = 2 * T_global * d * V
    tb.add("logits_compute", flops=f_logits * (3.0 if training else 1.0) / chips)

    # ---- encoder (audio) ----
    if cfg.family == "audio":
        T_enc = B * cfg.encoder_seq_len
        f_enc = (attention_flops(T_enc, cfg.encoder_seq_len, cfg)
                 + mlp_flops(T_enc, cfg)) * cfg.num_encoder_layers
        if decode:
            f_enc = 0.0                   # encoder ran at prefill
        else:
            tb.add("encoder_compute", flops=f_enc * fwd_mult / chips)
        # cross-attention KV + scores per decoder layer
        f_cross = 2 * T_global * cfg.encoder_seq_len * cfg.num_heads * cfg.head_dim * 2
        tb.add("cross_attn_compute",
               flops=f_cross * L * (fwd_mult if training else 1.0) / chips)

    # ---- HBM bytes ----
    from .analysis import count_params
    P_total, _ = count_params(cfg)
    p_bytes_chip = P_total * 2 / chips    # bf16, fully sharded (fsdp x tp)
    if training:
        # fwd+bwd weight reads (per microbatch pass) + optimizer update r/w
        opt_bytes = 4 if cfg.name != "arctic-480b" else 2
        tb.add("weights_hbm",
               hbm=p_bytes_chip * 2 * microbatches
               + P_total / chips * (2 * opt_bytes + 2 + 2 * opt_bytes))
    else:
        tb.add("weights_hbm", hbm=p_bytes_chip)

    # activations: residual stream in/out per unit (+ revisits for bwd/remat)
    act_visits = 6.0 if training else 2.0
    act_bytes = L * T_chip * d * 2 * act_visits
    tb.add("activations_hbm", hbm=act_bytes)

    # attention score materialization (XLA baseline, not flash)
    if cfg.family in ("dense", "moe", "vlm", "audio") or cfg.family == "hybrid":
        n_att_layers = L if cfg.family != "hybrid" else L  # 1 shared blk / unit
        if not flash and not decode:
            sc = B * cfg.num_heads * S * Skv * 4 / chips
            tb.add("scores_hbm", hbm=sc * n_att_layers
                   * (3.0 if training else 1.0) * 2)
        if decode:
            kvb = (B * Skv * cfg.num_kv_heads * cfg.head_dim * 2 * kv_bytes
                   * n_att_layers / chips)
            tb.add("kv_cache_hbm", hbm=kvb)
            if not flash and draft_window > 0:
                # XLA decode materializes (B, H, T, Skv) f32 scores
                sc = (B * cfg.num_heads * (1 + draft_window) * Skv * 4 * 2
                      * n_att_layers / chips)
                tb.add("decode_scores_hbm", hbm=sc)

    if cfg.family in ("ssm", "hybrid"):
        from repro.models.ssm import ssm_dims
        d_inner, nheads, g, n, conv_dim = ssm_dims(cfg)
        n_ssm = cfg.num_layers
        if decode:
            state_bytes = B * nheads * cfg.ssm_head_dim * n * 4 * 2 * n_ssm / chips
            tb.add("ssm_state_hbm", hbm=state_bytes)
        else:
            # chunked states written/read once per chunk
            st = (T_global / cfg.ssm_chunk) * nheads * cfg.ssm_head_dim * n * 4
            tb.add("ssm_state_hbm", hbm=st * 2 * n_ssm / chips
                   * (2.0 if training else 1.0))

    # logits + one-hot loss traffic
    logit_bytes = T_global * V * 4 / chips * (2 if training else 1)
    if decode:
        logit_bytes = B * V * 4 / chips
    tb.add("logits_hbm", hbm=logit_bytes)

    # ---- collectives ----
    # TP all-reduce of activations: 2 per layer fwd (+2 bwd)
    n_ar = 2 * (2 if training else 1)
    ar_bytes = _ring(T_dp * d * 2, mp) * n_ar * L
    tb.add("tp_allreduce", coll=ar_bytes * (microbatches if training else 1)
           / (microbatches if training else 1))
    if fsdp and training:
        # per-layer param all-gather (fwd + bwd) over dp + grad reduce-scatter
        unit_p_bytes = params_per_unit(cfg) * 2 / mp
        ag = _ring(unit_p_bytes, dp) * 2 * L * microbatches
        rs = _ring(unit_p_bytes, dp) * L
        tb.add("fsdp_gather_scatter", coll=ag + rs)
    if cfg.num_experts:
        # token dispatch+combine all-to-all over mp (EP): T*d each way
        a2a = 2 * T_dp * d * 2 * cfg.top_k / mp * L
        tb.add("moe_all2all", coll=a2a * (2 if training else 1))
    if training:
        # cross-pod gradient all-reduce happens inside reduce-scatter ring
        # over the combined (pod, data) axis — covered by fsdp term.
        pass
    if decode and cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        pass  # decode TP all-reduce covered by tp_allreduce above

    return tb


def summarize(tb: TermBreakdown, model_flops_global: float, chips: int) -> dict:
    compute_s = tb.flops / PEAK_FLOPS_BF16
    memory_s = tb.hbm_bytes / HBM_BW
    collective_s = tb.coll_bytes / ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = (model_flops_global / chips) / PEAK_FLOPS_BF16
    bound = max(max(terms.values()), 1e-30)
    return {
        "flops": tb.flops,
        "hbm_bytes": tb.hbm_bytes,
        "collective_bytes": tb.coll_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": model_flops_global,
        "flops_ratio": (model_flops_global / chips) / max(tb.flops, 1e-30),
        "peak_fraction": useful / bound,
        "detail": tb.detail,
    }
