"""Roofline-term derivation from compiled XLA artifacts.

Three terms per (arch x shape x mesh) cell (assignment §Roofline):

  compute_s    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory_s     = HLO_bytes / (chips * HBM_bw)
  collective_s = per-chip collective bytes / link_bw
               ( == global collective bytes / (chips * link_bw), since the
                 partitioned HLO prints per-device shapes )

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; the partitioned HLO
text for collective operand sizes (cost_analysis does not expose them).
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

# TPU v5e hardware constants (assignment)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_LINK_BW = 50e9                # bytes/s per link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# result/operand type like  bf16[16,512,128]{2,1,0:T(8,128)}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\(|\w+\[)[^=]*?)\s+(" + "|".join(COLLECTIVE_OPS) + r")[\.\(]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_op: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of every collective in the (partitioned) HLO.

    Shapes in partitioned HLO are per-device, so the sums are per-chip
    traffic volumes.
    """
    counts = {op: 0 for op in COLLECTIVE_OPS}
    byte_tot = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_types, op = m.group(1), m.group(2)
        if f" {op}" not in line and f"{op}(" not in line:
            continue
        counts[op] += 1
        for dtype, dims in _SHAPE_RE.findall(result_types):
            byte_tot[op] += _shape_bytes(dtype, dims)
    return CollectiveStats(counts=counts, bytes_by_op=byte_tot)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes_per_chip: float
    collective_counts: dict
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    flops_ratio: float            # MODEL_FLOPS / HLO_FLOPs (useful fraction)
    bottleneck: str
    peak_fraction: float          # useful-FLOPs time / bound-time (roofline frac)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline(arch: str, shape: str, mesh_name: str, chips: int,
             cost: dict, hlo_text: str, model_flops: float) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    # cost_analysis reports 'bytes accessed' under various keys per backend
    byte_keys = [k for k in cost if "bytes accessed" in k]
    hbm_bytes = float(cost.get("bytes accessed", 0.0)) or \
        float(sum(cost[k] for k in byte_keys))
    coll = parse_collectives(hlo_text)

    # cost_analysis flops on the partitioned module are per-device for CPU
    # SPMD; normalize to per-chip terms.
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = hbm_bytes / HBM_BW
    collective_s = coll.total_bytes / ICI_LINK_BW

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful_s = (model_flops / chips) / PEAK_FLOPS_BF16
    bound = max(terms.values())
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=hbm_bytes,
        collective_bytes_per_chip=float(coll.total_bytes),
        collective_counts=coll.counts,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=model_flops,
        flops_ratio=(model_flops / chips) / flops if flops else 0.0,
        bottleneck=bottleneck,
        peak_fraction=useful_s / bound if bound else 0.0,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6ND rule; MoE uses active parameters)
# ---------------------------------------------------------------------------

def model_flops(cfg, shape, n_params: int, n_active_params: int | None = None) -> float:
    """6 * N * D for training; 2 * N * D for a forward-only step.

    decode steps process global_batch tokens (one per row); prefill/train
    process batch*seq tokens.
    """
    n = n_active_params if n_active_params is not None else n_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one new token per row
    return 2.0 * n * tokens


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from the config (no allocation)."""
    import jax

    from repro.models import build_model

    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    if not cfg.num_experts:
        return total, total
    # active = total - (routed expert params) * (1 - top_k/E)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    expert_params = 0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        if "moe" in keys and any(k in ("w_gate", "w_up", "w_down") for k in keys):
            expert_params += int(np.prod(leaf.shape))
    active = total - expert_params * (1 - cfg.top_k / cfg.num_experts)
    return total, int(active)
