"""Sharding rules: parameters, optimizer state, caches, and batches.

Strategy (DESIGN.md §5):
  * batch            -> data axes ("pod", "data")
  * attention heads / ffn hidden / vocab / experts -> "model" (TP / EP)
  * parameter d_model dim -> data axes (FSDP / ZeRO) — used for BOTH training
    and inference so 480B-class models fit per-chip HBM
  * optimizer moments inherit the parameter sharding (elementwise)

Rules are resolved against concrete leaf shapes via ``eval_shape`` (no
allocation), with divisibility fallbacks: if a preferred axis does not divide
the dim, the next candidate (or replication) is used, so every assigned
architecture lowers on every mesh without bespoke tables.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any


def data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _fit(dim: int, mesh: Mesh, *candidates):
    """First candidate axis (or axis tuple) that divides ``dim``; None if no
    candidate fits."""
    for cand in candidates:
        if cand is None:
            continue
        if dim % axis_size(mesh, cand) == 0:
            return cand
    return None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_spec(path: str, shape: tuple, mesh: Mesh, fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf, keyed on its name + rank."""
    dp = data_axes(mesh) if fsdp else None
    mp = "model"
    name = path.split("/")[-1]
    nd = len(shape)

    def spec(*dims):
        # pad leading stacked-layer axes with None
        lead = nd - len(dims)
        return P(*([None] * lead + list(dims)))

    if name == "embed":                      # (V, d)
        # vocab REPLICATED for the lookup gather (a vocab-sharded table makes
        # SPMD fully rematerialize the gather); d over the data axes.  Tied
        # unembed uses get resharded by propagation.
        return P(None, _fit(shape[1], mesh, dp))
    if name in ("unembed",):                 # (d, V)
        return P(_fit(shape[0], mesh, dp), _fit(shape[1], mesh, mp))
    if name in ("pos_embed", "enc_pos_embed"):
        return P(None, _fit(shape[1], mesh, dp))
    if name in ("wq", "w_gate", "w_up", "in_proj") and nd >= 2:
        if name in ("w_gate", "w_up") and nd == 4:   # MoE (L, E, d, f)
            return P(None, _fit(shape[1], mesh, mp),
                     _fit(shape[2], mesh, dp), None)
        return spec(_fit(shape[-2], mesh, dp), _fit(shape[-1], mesh, mp))
    if name in ("wk", "wv"):
        return spec(_fit(shape[-2], mesh, dp), _fit(shape[-1], mesh, mp))
    if name in ("wo", "w_down", "out_proj"):
        if name == "w_down" and nd == 4:             # MoE (L, E, f, d)
            return P(None, _fit(shape[1], mesh, mp), None,
                     _fit(shape[3], mesh, dp))
        return spec(_fit(shape[-2], mesh, mp), _fit(shape[-1], mesh, dp))
    if name == "router":                     # (L, d, E)
        return spec(_fit(shape[-2], mesh, dp), None)
    if name == "conv_w":                     # (L, W, C)
        return spec(None, _fit(shape[-1], mesh, mp))
    # norms, biases, A_log, D, dt_bias, conv_b, norm_scale: replicated
    return P(*([None] * nd))


def cache_spec(path: str, shape: tuple, mesh: Mesh, batch_axis: int) -> P:
    """PartitionSpec for a serving-cache leaf.

    KV caches (.., B, S, KV, D): batch->data; kv-heads->model when divisible,
    else sequence->model (long-cache fallback, e.g. whisper's 20 heads).
    SSM states (.., B, H, Pdim, N): heads->model, else head-dim->model.
    """
    dp = data_axes(mesh)
    name = path.split("/")[-1]
    nd = len(shape)
    dims = [None] * nd
    if shape[batch_axis] % axis_size(mesh, dp) == 0:
        dims[batch_axis] = dp
    if name in ("k", "v", "dense_k", "dense_v", "cross_k", "cross_v"):
        kv_dim, s_dim = nd - 2, nd - 3
        if shape[kv_dim] % axis_size(mesh, "model") == 0:
            dims[kv_dim] = "model"
        elif shape[s_dim] % axis_size(mesh, "model") == 0:
            dims[s_dim] = "model"
    elif name == "ssm":                      # (.., B, H, P, N)
        h_dim, p_dim = nd - 3, nd - 2
        if shape[h_dim] % axis_size(mesh, "model") == 0:
            dims[h_dim] = "model"
        elif shape[p_dim] % axis_size(mesh, "model") == 0:
            dims[p_dim] = "model"
    elif name == "conv":                     # (.., B, W-1, C)
        if shape[-1] % axis_size(mesh, "model") == 0:
            dims[-1] = "model"
    return P(*dims)


def batch_spec(shape: tuple, mesh: Mesh) -> P:
    """Input batches: leading batch dim over the data axes when divisible."""
    dp = data_axes(mesh)
    dims = [None] * len(shape)
    if shape[0] % axis_size(mesh, dp) == 0:
        dims[0] = dp
    return P(*dims)


# ---------------------------------------------------------------------------
# Tree-level entry points
# ---------------------------------------------------------------------------

def param_shardings(mesh: Mesh, params_shape: Params, fsdp: bool = True) -> Params:
    """NamedShardings matching an eval_shape pytree of the parameters."""
    def rule(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf.shape, mesh,
                                              fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_shardings(mesh: Mesh, param_sh: Params) -> Params:
    """Optimizer state shardings: moments inherit parameter shardings."""
    return {
        "m": param_sh,
        "v": param_sh,
        "step": NamedSharding(mesh, P()),
    }


def cache_shardings(mesh: Mesh, cache_shape: Params, batch_axes: dict) -> Params:
    def rule(path, leaf):
        key = _path_str(path).split("/")[-1]
        return NamedSharding(mesh, cache_spec(_path_str(path), leaf.shape, mesh,
                                              batch_axes[key]))
    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def batch_shardings(mesh: Mesh, batch_shape: Params) -> Params:
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(leaf.shape, mesh)),
        batch_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# In-model logical sharding constraints
# ---------------------------------------------------------------------------

_LOGICAL_AXES = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "experts": ("model",),
    "dmodel": ("data",),
    "seq": ("model",),
}


def _active_mesh():
    """The legacy `with mesh:` context mesh, or None (CPU single-device)."""
    import warnings

    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
    except Exception:
        return None
    if mesh is None or mesh.empty:
        return None
    return mesh


def _resolve(mesh, dim_size, name):
    """Mesh axes for a logical name if they exist and divide dim_size."""
    if name is None:
        return None
    axes = tuple(a for a in _LOGICAL_AXES[name] if a in mesh.axis_names)
    if not axes:
        return None
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return axes if dim_size % size == 0 else None


def logical_constraint(x, *logical):
    """with_sharding_constraint by LOGICAL axis names; a no-op when no mesh
    context is active or the named axes don't exist/divide (CPU tests run the
    same model code unconstrained)."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    dims = [_resolve(mesh, d, n) for d, n in zip(x.shape, logical)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*dims)))


def constrain_attention_scores(logits):
    """(B, KV, G, Sq, Skv) score tensor: batch -> data axes; kv-heads ->
    model when divisible, else query-heads, else query-seq (archs whose head
    counts don't divide the TP ways, e.g. whisper's 20 or arctic's 8x7)."""
    mesh = _active_mesh()
    if mesh is None:
        return logits
    B, KV, G, Sq, Skv = logits.shape
    dims = [_resolve(mesh, B, "batch"), None, None, None, None]
    if _resolve(mesh, KV, "heads"):
        dims[1] = _resolve(mesh, KV, "heads")
    elif _resolve(mesh, G, "heads"):
        dims[2] = _resolve(mesh, G, "heads")
    # NOTE(§Perf log): a query-seq fallback (Sq -> model) was measured on the
    # arctic train cell and REGRESSED temp 81.7 -> 280 GB/chip (softmax/AV
    # resharding copies); heads-or-nothing is the better baseline.
    return jax.lax.with_sharding_constraint(
        logits, NamedSharding(mesh, P(*dims)))
