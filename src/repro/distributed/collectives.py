"""Distributed-optimization collectives: gradient compression primitives.

Cross-pod data-parallel gradient traffic is the dominant inter-pod collective
during training (DESIGN.md §8).  Two standard compressors are provided, both
with error feedback so compression error accumulates into the next step
instead of biasing the gradient:

  * top-k sparsification (magnitude) — upload k fraction of entries
  * int8 quantization with per-leaf scale — 4x over fp32 / 2x over bf16

``compressed_psum_int8`` is the shard_map building block that performs the
quantized all-reduce on a named axis.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------------------
# Error feedback
# ---------------------------------------------------------------------------

def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# Top-k sparsification
# ---------------------------------------------------------------------------

def topk_compress(x: jax.Array, frac: float):
    """Keep the top ``frac`` fraction of entries by magnitude.

    Returns (idx, val, residual): residual = x - decompress(idx, val) feeds
    the error-feedback accumulator.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    mag = jnp.abs(flat)
    val_k, idx_k = jax.lax.top_k(mag, k)
    vals = flat[idx_k]
    residual = flat.at[idx_k].set(0.0).reshape(x.shape)
    return idx_k, vals, residual


def topk_decompress(idx: jax.Array, vals: jax.Array, shape) -> jax.Array:
    import numpy as np
    size = int(np.prod(shape))
    return jnp.zeros((size,), jnp.float32).at[idx].set(vals).reshape(shape)


def compress_gradients_topk(grads: Params, ef: Params, frac: float):
    """Apply error feedback + top-k to every leaf.

    Returns (compressed {path: (idx, val, shape)}, new_ef, effective_grads)
    where effective_grads is what the optimizer would see after an exact
    all-reduce of the compressed payloads (single-host semantics — the
    multi-host path wires the payloads through psum instead).
    """
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef)
    flat, treedef = jax.tree.flatten(corrected)
    comp, new_ef, effective = [], [], []
    for leaf in flat:
        idx, vals, residual = topk_compress(leaf, frac)
        comp.append((idx, vals, leaf.shape))
        new_ef.append(residual)
        effective.append(topk_decompress(idx, vals, leaf.shape))
    return (comp,
            jax.tree.unflatten(treedef, new_ef),
            jax.tree.unflatten(treedef, effective))


def compression_ratio(comp) -> float:
    import numpy as np
    dense = sum(np.prod(shape) * 4 for _, _, shape in comp)
    sparse = sum(idx.size * 4 + vals.size * 4 for idx, vals, _ in comp)
    return float(sparse / dense)


# ---------------------------------------------------------------------------
# Int8 quantized all-reduce
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """shard_map building block: int8-quantize locally, all-reduce the int32
    accumulations and the scales, dequantize.  Wire format is 1 byte/elem
    vs 4 (fp32) on the reduced axis."""
    q, scale = quantize_int8(x)
    # each participant quantized with its own scale; the reduction needs a
    # common one: re-quantize against the max scale (conservative)
    smax = jax.lax.pmax(scale, axis_name)
    requant = jnp.clip(jnp.round(dequantize_int8(q, scale) / smax), -127, 127)
    acc = jax.lax.psum(requant.astype(jnp.int32), axis_name)
    return acc.astype(jnp.float32) * smax


def make_compressed_allreduce(mesh, axis_name: str = "data"):
    """jit-able f(x) -> mean over ``axis_name`` with int8 wire format."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n = mesh.shape[axis_name]

    @jax.jit
    def allreduce_mean(x):
        """x: (n_workers, ...) per-worker gradients -> replicated mean."""
        fn = shard_map(
            lambda v: compressed_psum_int8(v[0], axis_name) / n,
            mesh=mesh, in_specs=P(axis_name), out_specs=P(),
        )
        return fn(x)

    return allreduce_mean
