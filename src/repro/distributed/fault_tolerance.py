"""Fault tolerance and elastic scaling plans.

On TPU pods, a failed host removes a fixed block of chips; the recovery path
is (1) pick a degraded mesh among the survivors, (2) re-derive shardings with
the same rules on the new mesh, (3) restore parameters from the latest
checkpoint, (4) rescale the data pipeline.  All of that is deterministic
planning logic — testable on CPU — plus the checkpoint layer.

The serving-side analogue (device churn in the Multi-SPIN cell) is handled by
``serving.cell`` re-solving draft control for the survivor set; here we
handle the training/verification cluster itself.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axis_names: tuple
    lost_fraction: float
    batch_scale: float          # keep global batch via grad-accum scaling
    notes: str


def degraded_mesh_plan(current_shape: tuple, axis_names: tuple,
                       failed_chips: int, chips_per_host: int = 4) -> MeshPlan:
    """Largest well-formed mesh after losing ``failed_chips`` chips.

    Policy: shrink the ``data`` axis (model/pod axes carry sharded parameter
    state whose re-layout is expensive; the data axis only re-slices the
    batch).  The global batch is preserved by raising per-step gradient
    accumulation on the survivors.
    """
    axes = dict(zip(axis_names, current_shape))
    total = int(np.prod(current_shape))
    failed_hosts = int(np.ceil(failed_chips / chips_per_host))
    lost = failed_hosts * chips_per_host

    data = axes.get("data", 1)
    per_data_row = total // data
    rows_lost = int(np.ceil(lost / per_data_row))
    new_data = data - rows_lost
    if new_data < 1:
        raise RuntimeError("failure exceeds recoverable capacity; "
                           "restore on a fresh allocation")
    new_axes = dict(axes, data=new_data)
    new_shape = tuple(new_axes[a] for a in axis_names)
    return MeshPlan(
        shape=new_shape,
        axis_names=axis_names,
        lost_fraction=lost / total,
        batch_scale=data / new_data,
        notes=(f"dropped {rows_lost} data row(s) after {failed_chips} chip "
               f"failures; raise grad-accum x{data / new_data:.2f} to keep "
               f"the global batch"),
    )


def expansion_mesh_plan(current_shape: tuple, axis_names: tuple,
                        new_chips: int) -> MeshPlan:
    """Elastic scale-UP: grow the data axis by whole rows."""
    axes = dict(zip(axis_names, current_shape))
    total = int(np.prod(current_shape))
    per_data_row = total // axes.get("data", 1)
    add_rows = new_chips // per_data_row
    new_axes = dict(axes, data=axes["data"] + add_rows)
    new_shape = tuple(new_axes[a] for a in axis_names)
    return MeshPlan(shape=new_shape, axis_names=axis_names, lost_fraction=0.0,
                    batch_scale=axes["data"] / new_axes["data"],
                    notes=f"added {add_rows} data row(s)")


@dataclasses.dataclass
class RecoveryPlan:
    mesh_plan: MeshPlan
    restore_step: int
    resume_data_step: int

    @classmethod
    def build(cls, mesh_plan: MeshPlan, checkpoint_steps: list[int]) -> "RecoveryPlan":
        if not checkpoint_steps:
            raise RuntimeError("no checkpoint to recover from")
        step = max(checkpoint_steps)
        return cls(mesh_plan=mesh_plan, restore_step=step,
                   resume_data_step=step)


def straggler_policy(step_times: np.ndarray, threshold: float = 2.0) -> dict:
    """Detect persistent stragglers from per-host step-time telemetry.

    Returns {"stragglers": idx array, "action": ...}.  Single-slow-step blips
    are ignored (median filter); persistent outliers are flagged for
    re-scheduling (their data shard reassigned, host drained).
    """
    med = np.median(step_times, axis=-1)          # per-host median over window
    global_med = np.median(med)
    stragglers = np.where(med > threshold * global_med)[0]
    return {
        "stragglers": stragglers,
        "action": "drain-and-redistribute" if len(stragglers) else "none",
        "severity": float(np.max(med) / global_med) if len(med) else 1.0,
    }
