"""Fault-tolerant checkpointing (msgpack + zstd, atomic, async)."""

from .checkpoint import CheckpointManager, load, save  # noqa: F401
