"""Fault-tolerant pytree checkpointing.

Format: one msgpack blob (zstd-compressed when ``zstandard`` is available,
stdlib zlib otherwise; detected from the frame header on load) holding
flattened key-paths ->
{dtype, shape, raw bytes}, plus a manifest with a SHA-256 content hash and
user metadata.  Writes are crash-safe: tmp file + fsync + atomic rename; a
half-written checkpoint can never shadow a good one.  ``CheckpointManager``
retains the newest ``keep`` checkpoints, restores the latest VALID one
(corrupt trailers are detected by hash and skipped), and supports an async
writer thread so the training loop never blocks on storage.
"""

from __future__ import annotations

import hashlib
import os
import threading
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # gated optional dep: fall back to stdlib zlib
    zstandard = None

Params = Any

_MAGIC = b"REPRO_CKPT1"
_ZSTD_FRAME_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(data: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(data)
    return zlib.compress(data, 6)


def _decompress(blob: bytes) -> bytes:
    """Codec is detected from the frame header, so checkpoints written with
    either codec load on any host that has the matching library."""
    if blob[:4] == _ZSTD_FRAME_MAGIC:
        if zstandard is None:
            raise ValueError("checkpoint is zstd-compressed but zstandard "
                             "is not installed")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten(tree: Params) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(template: Params, arrays: dict[str, np.ndarray]) -> Params:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def save(path: str, tree: Params, metadata: dict | None = None):
    arrays = _flatten(tree)
    payload = {
        "arrays": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in arrays.items()
        },
        "metadata": metadata or {},
    }
    blob = _compress(msgpack.packb(payload, use_bin_type=True))
    digest = hashlib.sha256(blob).digest()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_MAGIC)
        f.write(digest)
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load(path: str, template: Params):
    """Restore into the structure/dtypes of ``template``.  Raises on
    corruption (bad magic or hash)."""
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:len(_MAGIC)] != _MAGIC:
        raise ValueError(f"{path}: bad magic")
    digest, blob = raw[len(_MAGIC):len(_MAGIC) + 32], raw[len(_MAGIC) + 32:]
    if hashlib.sha256(blob).digest() != digest:
        raise ValueError(f"{path}: content hash mismatch (corrupt)")
    try:
        decompressed = _decompress(blob)
    except zlib.error as e:
        raise ValueError(f"{path}: decompression failed ({e})") from e
    payload = msgpack.unpackb(decompressed, raw=False)
    arrays = {
        k: np.frombuffer(v["data"], dtype=np.dtype(v["dtype"])).reshape(v["shape"])
        for k, v in payload["arrays"].items()
    }
    return _unflatten_into(template, arrays), payload["metadata"]


class CheckpointManager:
    """Step-indexed checkpoint directory with retention + async writes."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}.msgpack.zst")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_") and name.endswith(".msgpack.zst"):
                out.append(int(name[5:15]))
        return sorted(out)

    def save(self, step: int, tree: Params, metadata: dict | None = None):
        meta = dict(metadata or {}, step=step)
        save(self._path(step), tree, meta)
        self._gc()

    def save_async(self, step: int, tree: Params, metadata: dict | None = None):
        """Snapshot to host memory now, write in a background thread."""
        host_tree = jax.tree.map(np.asarray, tree)
        self.wait()
        self._thread = threading.Thread(
            target=self.save, args=(step, host_tree, metadata), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, template: Params):
        """Restore the newest valid checkpoint; corrupt files are skipped
        (node-failure tolerance).  Returns (tree, metadata) or None."""
        for step in reversed(self.steps()):
            try:
                return load(self._path(step), template)
            except (ValueError, KeyError, OSError):
                continue
        return None

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass
