"""Fused softmax-normalizer + token-gather Pallas kernel.

The verification hot path (paper eq. 3-4) needs p_L(x_l) = softmax(logits)[x]
for every drafted position — with V up to 256k, materializing the softmax
costs two extra HBM round-trips of (N, V) float32.  This kernel streams the
vocab tiles once, maintaining the online max/denominator and the picked
logit in VMEM scratch across the (sequential) vocab grid steps — the
TPU-native equivalent of the GPU two-pass reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(ids_ref, logits_ref, out_ref, m_scr, l_scr, pick_scr, *,
            bn: int, bv: int, n_v: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        pick_scr[...] = jnp.full_like(pick_scr, _NEG)

    logits = logits_ref[...].astype(jnp.float32)            # (bn, bv)
    ids = ids_ref[...]                                      # (bn, 1) int32

    m_prev = m_scr[:, :1]                                   # (bn, 1)
    m_tile = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_tile)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[:, :1] * corr + jnp.sum(jnp.exp(logits - m_new), axis=-1,
                                          keepdims=True)

    # gather the drafted token's logit if it lives in this tile
    cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1) + vi * bv
    hit = cols == ids
    picked_tile = jnp.max(jnp.where(hit, logits, _NEG), axis=-1, keepdims=True)
    pick_new = jnp.maximum(pick_scr[:, :1], picked_tile)

    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    pick_scr[...] = jnp.broadcast_to(pick_new, pick_scr.shape)

    @pl.when(vi == n_v - 1)
    def _finish():
        p = jnp.exp(pick_scr[:, :1] - m_scr[:, :1]) / l_scr[:, :1]
        out_ref[...] = jnp.broadcast_to(p, out_ref.shape)


@functools.partial(jax.jit, static_argnames=("bn", "bv", "interpret"))
def gather_softmax_prob_pallas(logits: jax.Array, token_ids: jax.Array,
                               bn: int = 8, bv: int = 2048,
                               interpret: bool = False) -> jax.Array:
    """logits: (N, V); token_ids: (N,) -> p (N,) float32."""
    N, V = logits.shape
    n_pad = (-N) % bn
    v_pad = (-V) % bv
    if n_pad or v_pad:
        logits = jnp.pad(logits, ((0, n_pad), (0, v_pad)),
                         constant_values=_NEG)
        token_ids = jnp.pad(token_ids, (0, n_pad))
    Np, Vp = logits.shape
    n_v = Vp // bv
    ids2d = token_ids.astype(jnp.int32)[:, None]

    out = pl.pallas_call(
        functools.partial(_kernel, bn=bn, bv=bv, n_v=n_v),
        grid=(Np // bn, n_v),
        in_specs=[
            pl.BlockSpec((bn, 1), lambda ni, vi: (ni, 0)),
            pl.BlockSpec((bn, bv), lambda ni, vi: (ni, vi)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda ni, vi: (ni, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bn, 128), jnp.float32),
            pltpu.VMEM((bn, 128), jnp.float32),
            pltpu.VMEM((bn, 128), jnp.float32),
        ],
        interpret=interpret,
    )(ids2d, logits)
    return out[:N, 0]
