"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth used by (a) the kernel allclose
tests and (b) the CPU execution path of ``ops.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Flash attention (prefill, causal, GQA)
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, scale: float | None = None) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, Sq, KV, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Skv)[None, :] <= (jnp.arange(Sq)[:, None] + (Skv - Sq))
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# Decode attention (one query step against a KV cache with valid lengths)
# ---------------------------------------------------------------------------

def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         lengths: jax.Array, scale: float | None = None) -> jax.Array:
    """q: (B, H, D); caches: (B, S, KV, D); lengths: (B,) -> (B, H, D).

    Attends to cache positions [0, lengths_b).
    """
    B, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    S = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, KV, G, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache).astype(jnp.float32) * scale
    valid = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v_cache)
    return out.reshape(B, H, D)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        page_table: jax.Array, lengths: jax.Array,
                        scale: float | None = None) -> jax.Array:
    """Decode/window attention through a paged KV cache.

    q: (B, T, H, D) window queries (T=1 decode, T=L+1 verification).
    k_pool / v_pool: (P, ps, KV, D) physical page pools.
    page_table: (B, n_slots) int32, physical page per logical slot (-1 =
        unmapped: those positions are masked out).
    lengths: (B,) valid kv count for query row 0; query row t attends
        logical positions [0, lengths_b + t) — the window's own tokens are
        already in the pool (written before attention, matching
        ``forward_window``'s update-then-attend order).
    Returns (B, T, H, D).
    """
    B, T, H, D = q.shape
    P, ps, KV, _ = k_pool.shape
    n_slots = page_table.shape[1]
    S = n_slots * ps
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    safe = jnp.maximum(page_table, 0)
    k = k_pool[safe].reshape(B, S, KV, D)
    v = v_pool[safe].reshape(B, S, KV, D)
    qg = q.reshape(B, T, KV, G, D)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32) * scale
    kpos = jnp.arange(S)
    valid = kpos[None, None, :] < (lengths[:, None]
                                   + jnp.arange(T)[None, :])[:, :, None]
    mapped = jnp.repeat(page_table >= 0, ps, axis=1)          # (B, S)
    valid = valid & mapped[:, None, :]
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(B, T, H, D)


def tree_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                       lengths: jax.Array, win_mask: jax.Array,
                       scale: float | None = None) -> jax.Array:
    """Token-tree verification window over a contiguous KV cache.

    q: (B, T, H, D) — the T-token tree window (slot 0 is the pending token,
        slots 1.. are tree nodes in construction order).
    k_cache / v_cache: (B, S, KV, D); the window's K/V are already written
        at cache slots [lengths_b, lengths_b + T) (update-then-attend order,
        matching ``forward_window``).
    lengths: (B,) committed kv count — query rows attend every committed
        slot [0, lengths_b).
    win_mask: (B, T, T) bool — in-window attendance: query row t may attend
        window slot t' iff win_mask[b, t, t'] (ancestor-or-self of the token
        tree; a lower-triangular mask recovers the sequential causal window).
    Returns (B, T, H, D).
    """
    B, T, H, D = q.shape
    S = k_cache.shape[1]
    KV = k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qg = q.reshape(B, T, KV, G, D)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg,
                        k_cache).astype(jnp.float32) * scale
    kpos = jnp.arange(S)
    committed = kpos[None, None, :] < lengths[:, None, None]      # (B, 1, S)
    w = kpos[None, :] - lengths[:, None]                          # (B, S)
    in_win = (w >= 0) & (w < T)
    idx = jnp.broadcast_to(jnp.clip(w, 0, T - 1)[:, None, :], (B, T, S))
    allow = jnp.take_along_axis(win_mask, idx, axis=2)            # (B, T, S)
    valid = committed | (allow & in_win[:, None, :])
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v_cache)
    return out.reshape(B, T, H, D)


def paged_tree_attention_ref(q: jax.Array, k_pool: jax.Array,
                             v_pool: jax.Array, page_table: jax.Array,
                             lengths: jax.Array, win_mask: jax.Array,
                             scale: float | None = None) -> jax.Array:
    """``tree_attention_ref`` through a paged KV cache.

    Pools are (P, ps, KV, D); page_table is (B, n_slots) int32 (-1 =
    unmapped, masked out).  The window occupies logical positions
    [lengths_b, lengths_b + T), already written through the page table.
    """
    B = q.shape[0]
    ps = k_pool.shape[1]
    n_slots = page_table.shape[1]
    S = n_slots * ps
    KV, D = k_pool.shape[2], k_pool.shape[3]
    safe = jnp.maximum(page_table, 0)
    k = k_pool[safe].reshape(B, S, KV, D)
    v = v_pool[safe].reshape(B, S, KV, D)
    # the committed prefix and the window are always fully mapped (the
    # engine extends before writing), and the tree mask already excludes
    # every slot outside [0, lengths) u window — so the gathered view can
    # delegate straight to the contiguous oracle.
    return tree_attention_ref(q, k, v, lengths, win_mask, scale=scale)


def decode_attention_quantized_ref(q: jax.Array, k_cache: jax.Array,
                                   v_cache: jax.Array, k_scale: jax.Array,
                                   v_scale: jax.Array, lengths: jax.Array
                                   ) -> jax.Array:
    """Decode attention over an int8-quantized KV cache.

    k_cache/v_cache: (B, S, KV, D) int8; scales: (B, KV) per-head dequant
    factors.  Dequantize then run the exact fp path (the kernel fuses the
    dequant into the tile loads instead).
    """
    k = k_cache.astype(jnp.float32) * k_scale[:, None, :, None]
    v = v_cache.astype(jnp.float32) * v_scale[:, None, :, None]
    return decode_attention_ref(q, k, v, lengths)


def quantize_kv(k: jax.Array, v: jax.Array):
    """Per (batch, kv-head) symmetric int8 quantization of a KV cache."""
    def q_one(x):
        scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 3)) / 127.0
        scale = jnp.maximum(scale, 1e-8)
        q = jnp.clip(jnp.round(x.astype(jnp.float32)
                               / scale[:, None, :, None]), -127, 127)
        return q.astype(jnp.int8), scale
    kq, ks = q_one(k)
    vq, vs = q_one(v)
    return kq, ks, vq, vs


# ---------------------------------------------------------------------------
# Fused softmax + gather (verification probabilities, paper eq. 3-4)
# ---------------------------------------------------------------------------

def gather_softmax_prob_ref(logits: jax.Array, token_ids: jax.Array) -> jax.Array:
    """logits: (N, V); token_ids: (N,) -> probability of each token (N,).

    p = softmax(logits)[token] computed without materializing softmax over V
    (reference does materialize; the kernel streams V tiles).
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = jnp.sum(jnp.exp(logits - m), axis=-1)
    picked = jnp.take_along_axis(logits, token_ids[:, None], axis=-1)[:, 0]
    return jnp.exp(picked - m[:, 0]) / z


# ---------------------------------------------------------------------------
# Residual-distribution sampling (paper eq. 5 calibrated token)
# ---------------------------------------------------------------------------

def residual_sample_ref(p: jax.Array, q: jax.Array, u: jax.Array) -> jax.Array:
    """Sample from normalize(max(p - q, 0)) by inverse CDF.

    p, q: (N, V) probability rows; u: (N,) uniforms in [0,1) -> tokens (N,).
    Falls back to argmax(p) when the residual is numerically all-zero
    (p == q), which matches rejection being impossible in exact arithmetic.
    """
    r = jnp.maximum(p.astype(jnp.float32) - q.astype(jnp.float32), 0.0)
    z = jnp.sum(r, axis=-1, keepdims=True)
    degenerate = z[:, 0] <= 0.0
    cdf = jnp.cumsum(r, axis=-1)
    target = u[:, None] * z
    token = jnp.sum((cdf <= target).astype(jnp.int32), axis=-1)
    token = jnp.minimum(token, p.shape[-1] - 1)
    return jnp.where(degenerate, jnp.argmax(p, axis=-1).astype(jnp.int32),
                     token.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Fused draft verification + calibrated sampling (paper eq. 4-5 in one op)
# ---------------------------------------------------------------------------

def _scatter_rows(out: jax.Array, idx: jax.Array, val: jax.Array) -> jax.Array:
    """Scatter-add (B, Vhat) sparse rows into dense (B, V) rows."""
    rows = jnp.arange(idx.shape[0])[:, None]
    return out.at[rows, idx].add(val)


def fused_verify_sample_ref(target_logits: jax.Array,   # (B, L+1, V)
                            draft_tokens: jax.Array,    # (B, L) int32
                            draft_probs: jax.Array,     # (B, L) p_S
                            q_idx: jax.Array,           # (B, L, Vhat) int32
                            q_val: jax.Array,           # (B, L, Vhat)
                            u_accept: jax.Array,        # (B, L) uniforms
                            u_resid: jax.Array,         # (B,) uniforms
                            draft_len: jax.Array,       # (B,) true L_k <= L
                            ):
    """One-dispatch oracle for accept-test + residual sampling.

    Composes ``gather_softmax_prob_ref`` (p_L of each drafted token), the
    accept test ``u < min(1, p_L/p_S)`` masked to ``draft_len``, the
    prefix-acceptance count, and ``residual_sample_ref`` at the first
    rejected position (sparse SLM distribution scattered dense) — exactly
    the math ``core.verification.verify_drafts`` used to run as separate
    dispatches, with the uniforms drawn by the caller so the rng stream is
    unchanged.

    Returns ``(accept (B, L) bool, n_acc (B,) int32, calibrated (B,) int32)``.
    The bonus token on full acceptance stays outside (it needs a categorical
    sample, not a residual one).
    """
    B, L = draft_tokens.shape
    V = target_logits.shape[-1]

    flat_logits = target_logits[:, :L].reshape(B * L, V)
    p_target = gather_softmax_prob_ref(
        flat_logits, draft_tokens.reshape(B * L)).reshape(B, L)

    ratio = p_target / jnp.maximum(draft_probs, 1e-30)
    accept = u_accept < jnp.minimum(ratio, 1.0)
    accept = accept & (jnp.arange(L)[None, :] < draft_len[:, None])
    prefix_ok = jnp.cumprod(accept.astype(jnp.int32), axis=-1)
    n_acc = jnp.sum(prefix_ok, axis=-1)

    sel = jnp.minimum(n_acc, L - 1)
    logits_rej = jnp.take_along_axis(
        target_logits, sel[:, None, None], axis=1)[:, 0]
    p_rej = jax.nn.softmax(logits_rej.astype(jnp.float32), axis=-1)
    idx_rej = jnp.take_along_axis(q_idx, sel[:, None, None], axis=1)[:, 0]
    val_rej = jnp.take_along_axis(q_val, sel[:, None, None], axis=1)[:, 0]
    q_rej = _scatter_rows(jnp.zeros((B, V), jnp.float32), idx_rej,
                          val_rej.astype(jnp.float32))
    calibrated = residual_sample_ref(p_rej, q_rej, u_resid)
    return accept, n_acc.astype(jnp.int32), calibrated


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) chunked scan
# ---------------------------------------------------------------------------

def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i >= j),
    -inf above the diagonal."""
    T = x.shape[-1]
    xx = jnp.repeat(x[..., None], T, axis=-1)        # xx[..., k, j] = x_k
    mask = jnp.tril(jnp.ones((T, T), bool), k=-1)    # keep rows k > col j
    xx = jnp.where(mask, xx, 0.0)
    out = jnp.cumsum(xx, axis=-2)
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                 C: jax.Array, chunk: int = 64,
                 initial_state: jax.Array | None = None):
    """Chunked SSD forward (Mamba-2, arXiv:2405.21060 listing 1).

    x:  (b, s, h, p)   head inputs
    dt: (b, s, h)      positive step sizes (softplus already applied)
    A:  (h,)           negative decay rates
    B:  (b, s, g, n)   input projections (g groups, g | h)
    C:  (b, s, g, n)   output projections
    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)  # (b, s, h, n)
    Ch = jnp.repeat(C, rep, axis=2)

    xw = (x * dt[..., None]).astype(jnp.float32)     # dt-weighted input
    Abar = (A[None, None, :] * dt).astype(jnp.float32)  # (b, s, h)

    c = s // chunk
    xw = xw.reshape(b, c, chunk, h, p)
    Bh = Bh.reshape(b, c, chunk, h, n).astype(jnp.float32)
    Ch = Ch.reshape(b, c, chunk, h, n).astype(jnp.float32)
    Ab = Abar.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # (b, h, c, l)
    A_cum = jnp.cumsum(Ab, axis=-1)                          # (b, h, c, l)

    # Intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(Ab))                                 # (b, h, c, l, l)
    Y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Ch, Bh, L, xw)

    # Chunk end-states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)          # (b, h, c, l)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bh, decay_states, xw)

    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    states = jnp.concatenate([initial_state[:, None], states], axis=1)  # (b, c+1, h, p, n)

    # Inter-chunk recurrence
    chunk_decay = A_cum[..., -1]                             # (b, h, c)
    padded = jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))
    decay_chunk = jnp.exp(_segsum(padded))                   # (b, h, c+1, c+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    # Inter-chunk contribution to outputs
    state_decay_out = jnp.exp(A_cum)                         # (b, h, c, l)
    Y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Ch, prev_states, state_decay_out)

    y = (Y_diag + Y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array, state: jax.Array):
    """One-token SSD recurrence.

    x: (b, h, p); dt: (b, h); A: (h,); B, C: (b, g, n); state: (b, h, p, n).
    Returns (y (b, h, p), new_state).
    """
    b, h, p = x.shape
    g, n = B.shape[1], B.shape[2]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)      # (b, h, n)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(A[None, :] * dt)                          # (b, h)
    upd = (dt[..., None] * x.astype(jnp.float32))[..., None] * Bh[:, :, None, :]
    new_state = decay[..., None, None] * state + upd          # (b, h, p, n)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state
