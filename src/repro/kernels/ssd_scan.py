"""Mamba-2 SSD chunked scan, Pallas TPU.

One grid step processes one (batch, head, chunk) cell: the intra-chunk
quadratic part is two small MXU matmuls ((l,n)x(n,l) and (l,l)x(l,p)); the
inter-chunk recurrence carries the (p, n) state in VMEM scratch across the
sequential chunk-grid axis.  This keeps the whole recurrence on-chip — the
jnp reference materializes (b, h, c, l, l) decay tensors in HBM instead.

Layout prepared by the wrapper: x (B, H, C, L, P); dt (B, H, C, L, 1);
B/C projections (B, G, C, L, N); A (1, H) in SMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(A_ref, x_ref, dt_ref, B_ref, C_ref, init_ref, y_ref, fs_ref,
            state_scr, *, L: int, P: int, N: int, n_c: int, has_init: bool):
    h = pl.program_id(1)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        if has_init:
            state_scr[...] = init_ref[0, 0].astype(jnp.float32)
        else:
            state_scr[...] = jnp.zeros_like(state_scr)

    a = A_ref[0, h]                                        # scalar decay rate
    x = x_ref[0, 0, 0].astype(jnp.float32)                 # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)               # (L, 1)
    Bp = B_ref[0, 0, 0].astype(jnp.float32)                # (L, N)
    Cp = C_ref[0, 0, 0].astype(jnp.float32)                # (L, N)

    abar = a * dt                                          # (L, 1)
    acum = jnp.cumsum(abar[:, 0])                          # (L,)
    xw = x * dt                                            # dt-weighted input

    # intra-chunk: scores[i, j] = C_i . B_j * exp(acum_i - acum_j) for j <= i
    scores = jax.lax.dot_general(Cp, Bp, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.exp(acum[:, None] - acum[None, :])
    scores = jnp.where(jj <= ii, scores * decay, 0.0)
    y = jax.lax.dot_general(scores, xw, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (L, P)

    # inter-chunk: contribution of the carried state
    state = state_scr[...]                                 # (P, N)
    y = y + jnp.exp(acum)[:, None] * jax.lax.dot_general(
        Cp, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (L, N)x(N, P)->(L, P)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: s' = exp(acum_last) s + sum_j exp(acum_last - acum_j) xw_j B_j^T
    w = jnp.exp(acum[-1] - acum)[:, None] * xw             # (L, P)
    upd = jax.lax.dot_general(w, Bp, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)     # (P, N)
    state_scr[...] = jnp.exp(acum[-1]) * state + upd

    @pl.when(ci == n_c - 1)
    def _finish():
        fs_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                    C: jax.Array, chunk: int = 64, initial_state=None,
                    interpret: bool = False):
    """Same contract as ref.ssd_scan_ref (seq already chunk-multiple)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    c = s // chunk

    xt = x.transpose(0, 2, 1, 3).reshape(b, h, c, chunk, p)
    dtt = dt.transpose(0, 2, 1).reshape(b, h, c, chunk, 1)
    Bt = B.transpose(0, 2, 1, 3).reshape(b, g, c, chunk, n)
    Ct = C.transpose(0, 2, 1, 3).reshape(b, g, c, chunk, n)
    A2 = A.reshape(1, h).astype(jnp.float32)
    has_init = initial_state is not None
    init = (initial_state.astype(jnp.float32) if has_init
            else jnp.zeros((b, h, p, n), jnp.float32))

    y, fs = pl.pallas_call(
        functools.partial(_kernel, L=chunk, P=p, N=n, n_c=c, has_init=has_init),
        grid=(b, h, c),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                       # A
            pl.BlockSpec((1, 1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bi, hi, ci, rep=rep: (bi, hi // rep, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bi, hi, ci, rep=rep: (bi, hi // rep, ci, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, c, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(A2, xt, dtt, Bt, Ct, init)

    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    return y, fs
