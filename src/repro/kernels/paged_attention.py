"""Paged decode/window attention: queries against a paged KV cache, Pallas TPU.

The serving engine's KV lives in fixed-size pages of a preallocated pool
(``serving.kv_cache.PagedKVCache``); a per-stream page table maps logical
positions to physical pages.  The kernel walks the page-table SLOTS of each
row in grid order and lets the BlockSpec index map chase the physical page:
the page table rides in SMEM via scalar prefetch, so the pipeline DMAs each
KV tile HBM->VMEM directly from its physical page — the logical view is
never materialized (the XLA reference path gathers it instead).

Masking is per query row: row ``t`` of a T-token window attends logical
positions ``[0, lengths_b + t)`` (T=1 is plain decode); unmapped slots
(page id -1) are skipped whole.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, ps: int, n_slots: int, gsize: int, T: int,
            scale: float):
    b = pl.program_id(0)
    si = pl.program_id(2)
    R = T * gsize                                        # query rows

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    base = len_ref[b]
    # the LAST query row sees the most positions; slots past its horizon or
    # unmapped slots contribute nothing and are skipped whole
    page_live = (pt_ref[b, si] >= 0) & (si * ps < base + T - 1)

    @pl.when(page_live)
    def _update():
        D = q_ref.shape[-1]
        q = q_ref[0, :, 0, :, :].astype(jnp.float32).reshape(R, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (ps, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = jax.lax.broadcasted_iota(jnp.int32, (R, ps), 1) + si * ps
        trow = jax.lax.broadcasted_iota(jnp.int32, (R, ps), 0) // gsize
        s = jnp.where(kpos < base + trow, s, _NEG)       # (R, ps)
        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, :1] = l_scr[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[:, :1] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(si == n_slots - 1)
    def _finish():
        D = q_ref.shape[-1]
        out = acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, :, 0, :, :] = out.reshape(T, gsize, D).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           page_table: jax.Array, lengths: jax.Array,
                           interpret: bool = False) -> jax.Array:
    """q: (B, T, H, D); pools: (P, ps, KV, D); page_table: (B, n_slots)
    int32 (-1 = unmapped); lengths: (B,) valid kv count for query row 0
    (row t attends [0, lengths_b + t)).  Returns (B, T, H, D).

    Grid: (B, KV, n_slots); the page table and lengths are scalar-prefetched
    so the k/v index maps resolve slot -> physical page before each DMA.
    All G = H/KV query heads x T window rows of one kv head share the
    (T*G, D) q tile, so each physical page is streamed once per kv head.
    """
    B, T, H, D = q.shape
    P, ps, KV, _ = k_pool.shape
    n_slots = page_table.shape[1]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, T, KV, G, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_slots),
        in_specs=[
            pl.BlockSpec((1, T, 1, G, D), lambda b, h, si, pt, ln: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, si, pt, ln: (jnp.maximum(pt[b, si], 0),
                                                   0, h, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, si, pt, ln: (jnp.maximum(pt[b, si], 0),
                                                   0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, 1, G, D),
                               lambda b, h, si, pt, ln: (b, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G, 128), jnp.float32),
            pltpu.VMEM((T * G, 128), jnp.float32),
            pltpu.VMEM((T * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, ps=ps, n_slots=n_slots, gsize=G, T=T,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, KV, G, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pool, v_pool)
    return out.reshape(B, T, H, D)
