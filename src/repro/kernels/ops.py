"""Jit'd public wrappers around the Pallas kernels.

Each op dispatches between the Pallas TPU kernel and the pure-jnp oracle in
``ref.py``:  on CPU (this container) the oracle executes; on TPU the Pallas
path is used; ``interpret=True`` Pallas execution is exercised by the kernel
tests.  The environment variable / flag ``REPRO_KERNELS`` ∈
{auto, pallas, ref, interpret} forces a path.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.obs import trace

from . import ref

_MODE_ENV = "REPRO_KERNELS"


def kernel_mode() -> str:
    mode = os.environ.get(_MODE_ENV, "auto")
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return mode


def _use_pallas() -> bool:
    return kernel_mode() in ("pallas", "interpret")


def _interpret() -> bool:
    return kernel_mode() == "interpret"


def _span(name: str, x):
    """Dispatch span for one op: name + lead-operand shape/dtype + the
    backend actually dispatched (pallas | interpret | ref).  The args dict
    is only built when a tracer is installed, so untraced dispatch pays a
    single function call (``trace.NULL_SPAN``) and nothing else.  Span
    durations measure DISPATCH wall time unless the installed tracer has
    ``device_sync=True`` and the call site attaches its output."""
    if trace.active() is None:
        return trace.NULL_SPAN
    backend = ("interpret" if _interpret()
               else "pallas" if _use_pallas() else "ref")
    return trace.span(name, cat="kernel",
                      args={"shape": list(x.shape), "dtype": str(x.dtype),
                            "backend": backend})


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk",))
def _ssd_scan_ref_jit(x, dt, A, B, C, chunk, initial_state):
    return ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk, initial_state=initial_state)


def ssd_scan(x, dt, A, B, C, chunk: int = 64, initial_state=None):
    """Chunked SSD forward. See ref.ssd_scan_ref for shapes.

    Sequences are zero-padded to a chunk multiple; dt=0 padding is exact
    (decay e^0 = 1, update 0), so the final state is untouched.
    """
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    with _span("ops.ssd_scan", x) as sp:
        if _use_pallas():
            from .ssd_scan import ssd_scan_pallas
            y, fs = ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                                    initial_state=initial_state,
                                    interpret=_interpret())
        else:
            y, fs = ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk,
                                     initial_state=initial_state)
        sp.attach(y)
    return (y[:, :s] if pad else y), fs


def ssd_decode(x, dt, A, B, C, state):
    """One-token SSD recurrence (cheap; always the jnp formulation)."""
    with _span("ops.ssd_decode", x) as sp:
        out = ref.ssd_decode_ref(x, dt, A, B, C, state)
        sp.attach(out[0])
    return out


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, causal: bool = True):
    """Causal GQA attention. q: (B,Sq,H,D), k/v: (B,Skv,KV,D)."""
    with _span("ops.flash_attention", q) as sp:
        if _use_pallas():
            from .flash_attention import flash_attention_pallas
            out = flash_attention_pallas(q, k, v, causal=causal,
                                         interpret=_interpret())
        else:
            out = ref.flash_attention_ref(q, k, v, causal=causal)
        sp.attach(out)
    return out


def decode_attention(q, k_cache, v_cache, lengths):
    """Single-step decode attention against a KV cache."""
    with _span("ops.decode_attention", q) as sp:
        if _use_pallas():
            from .decode_attention import decode_attention_pallas
            out = decode_attention_pallas(q, k_cache, v_cache, lengths,
                                          interpret=_interpret())
        else:
            out = ref.decode_attention_ref(q, k_cache, v_cache, lengths)
        sp.attach(out)
    return out


def paged_attention(q, k_pool, v_pool, page_table, lengths):
    """Window/decode attention through a paged KV cache.

    q: (B, T, H, D); pools: (P, ps, KV, D); page_table: (B, n_slots) int32
    (-1 = unmapped); lengths: (B,) valid kv count for query row 0 (row t
    attends [0, lengths + t)).
    """
    with _span("ops.paged_attention", q) as sp:
        if _use_pallas():
            from .paged_attention import paged_attention_pallas
            out = paged_attention_pallas(q, k_pool, v_pool, page_table,
                                         lengths, interpret=_interpret())
        else:
            out = ref.paged_attention_ref(q, k_pool, v_pool, page_table,
                                          lengths)
        sp.attach(out)
    return out


def tree_attention(q, k_cache, v_cache, lengths, win_mask):
    """Token-tree verification window over a contiguous KV cache.

    q: (B, T, H, D) tree window (slot 0 = pending token); caches:
    (B, S, KV, D) with the window already written at slots
    [lengths, lengths + T); win_mask: (B, T, T) ancestor-or-self matrix.
    A lower-triangular win_mask recovers the sequential causal window.
    """
    with _span("ops.tree_attention", q) as sp:
        if _use_pallas():
            from .tree_attention import tree_attention_pallas
            out = tree_attention_pallas(q, k_cache, v_cache, lengths,
                                        win_mask, interpret=_interpret())
        else:
            out = ref.tree_attention_ref(q, k_cache, v_cache, lengths,
                                         win_mask)
        sp.attach(out)
    return out


def paged_tree_attention(q, k_pool, v_pool, page_table, lengths, win_mask):
    """``tree_attention`` through a paged KV cache (scalar-prefetched page
    table; pools (P, ps, KV, D), page_table (B, n_slots), -1 = unmapped)."""
    with _span("ops.paged_tree_attention", q) as sp:
        if _use_pallas():
            from .tree_attention import paged_tree_attention_pallas
            out = paged_tree_attention_pallas(q, k_pool, v_pool, page_table,
                                              lengths, win_mask,
                                              interpret=_interpret())
        else:
            out = ref.paged_tree_attention_ref(q, k_pool, v_pool, page_table,
                                               lengths, win_mask)
        sp.attach(out)
    return out


def decode_attention_q8(q, k_cache, v_cache, k_scale, v_scale, lengths):
    """Decode attention over an int8 KV cache (per-head scales)."""
    with _span("ops.decode_attention_q8", q) as sp:
        if _use_pallas():
            from .decode_attention import decode_attention_q8_pallas
            out = decode_attention_q8_pallas(q, k_cache, v_cache, k_scale,
                                             v_scale, lengths,
                                             interpret=_interpret())
        else:
            out = ref.decode_attention_quantized_ref(q, k_cache, v_cache,
                                                     k_scale, v_scale,
                                                     lengths)
        sp.attach(out)
    return out


# ---------------------------------------------------------------------------
# Speculative-verification ops (the paper's server-side hot path)
# ---------------------------------------------------------------------------

def gather_softmax_prob(logits, token_ids):
    """p_target(token) for each row without materializing softmax(V)."""
    with _span("ops.gather_softmax_prob", logits) as sp:
        if _use_pallas():
            from .gather_softmax_prob import gather_softmax_prob_pallas
            out = gather_softmax_prob_pallas(logits, token_ids,
                                             interpret=_interpret())
        else:
            out = ref.gather_softmax_prob_ref(logits, token_ids)
        sp.attach(out)
    return out


def residual_sample(p, q, u):
    """Sample from normalize(max(p-q, 0)) via inverse CDF (paper eq. 5)."""
    with _span("ops.residual_sample", p) as sp:
        if _use_pallas():
            from .residual_sample import residual_sample_pallas
            out = residual_sample_pallas(p, q, u, interpret=_interpret())
        else:
            out = ref.residual_sample_ref(p, q, u)
        sp.attach(out)
    return out


def fused_verify_sample(target_logits, draft_tokens, draft_probs, q_idx,
                        q_val, u_accept, u_resid, draft_len=None):
    """Accept-test + prefix count + calibrated residual token, one dispatch.

    Fuses ``gather_softmax_prob`` over every drafted position, the accept
    test ``u < min(1, p_L/p_S)`` (masked to ``draft_len``), the prefix
    acceptance count, and ``residual_sample`` at the first rejected position
    with the sparse SLM row (q_idx, q_val) rebuilt tile-locally — the dense
    (B, V) residual distribution never touches HBM on the Pallas path.

    target_logits: (B, L+1, V); draft_tokens / draft_probs / u_accept:
    (B, L); q_idx / q_val: (B, L, Vhat); u_resid: (B,); draft_len: (B,)
    true lengths (defaults to L).  Uniforms are drawn by the caller so the
    rng stream matches the unfused path exactly.

    Returns ``(accept (B, L) bool, n_acc (B,) int32, calibrated (B,) int32)``.
    """
    B, L = draft_tokens.shape
    if draft_len is None:
        draft_len = jnp.full((B,), L, jnp.int32)
    with _span("ops.fused_verify_sample", target_logits) as sp:
        if _use_pallas():
            from .fused_verify_sample import fused_verify_sample_pallas
            out = fused_verify_sample_pallas(target_logits, draft_tokens,
                                             draft_probs, q_idx, q_val,
                                             u_accept, u_resid, draft_len,
                                             interpret=_interpret())
        else:
            out = ref.fused_verify_sample_ref(target_logits, draft_tokens,
                                              draft_probs, q_idx, q_val,
                                              u_accept, u_resid, draft_len)
        sp.attach(out[2])
    return out
