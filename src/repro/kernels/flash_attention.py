"""Causal GQA flash attention (prefill path), Pallas TPU.

Online-softmax tiling (FlashAttention adapted to the TPU memory hierarchy):
the (bq x bk) score tile lives in VMEM/VREGs, the running max / denominator /
accumulator persist in VMEM scratch across the sequential kv-grid steps, and
q/k/v tiles stream HBM->VMEM once each.  Block shapes default to 128 (MXU
lane-aligned); causal skipping is done with pl.when on whole tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, bq: int, bk: int, n_k: int, causal: bool,
            kv_off: int, skv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # whole tile below the causal diagonal? (first kv position of tile vs
    # last query position of tile)
    run = True
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1 + kv_off)

    @pl.when(run)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)                  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + ki * bk
        if causal:
            qpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + qi * bq + kv_off
            s = jnp.where(kpos <= qpos, s, _NEG)
        s = jnp.where(kpos < skv, s, _NEG)  # kv padding
        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, :1] = l_scr[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[:, :1] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    q_pad, k_pad = (-Sq) % bq, (-Skv) % bk
    # layout: (B, H, S, D) so the head axis is a clean grid dimension
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if q_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
    Sqp, Skvp = qt.shape[2], kt.shape[2]
    n_q, n_k = Sqp // bq, Skvp // bk
    # causal offset: query i attends kv j <= i + (Skv - Sq)
    kv_off = Skv - Sq

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=bq, bk=bk, n_k=n_k,
                          causal=causal, kv_off=kv_off, skv=Skv),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)


def mha_reference(q, k, v, causal=True):
    from . import ref
    return ref.flash_attention_ref(q, k, v, causal=causal)
