"""Flash-decode attention: one query step against a long KV cache, Pallas TPU.

The decode_32k / long_500k serving shapes are dominated by streaming the KV
cache HBM->VMEM.  The kernel walks the sequence tiles of the cache in grid
order, carrying the online-softmax state in VMEM scratch, and masks tiles
beyond each row's valid length (per-row lengths live in SMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bs: int, n_s: int, scale: float, gsize: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_len = len_ref[pl.program_id(0)]

    @pl.when(si * bs < valid_len)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = jax.lax.broadcasted_iota(jnp.int32, (gsize, bs), 1) + si * bs
        s = jnp.where(kpos < valid_len, s, _NEG)             # (G, bs)
        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, :1] = l_scr[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[:, :1] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(si == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, lengths: jax.Array,
                            bs: int = 512, interpret: bool = False) -> jax.Array:
    """q: (B, H, D); caches: (B, S, KV, D); lengths: (B,) -> (B, H, D).

    Grid: (B, KV, S-tiles); all G = H/KV query heads of one kv head are
    processed together in the (G, D) q tile, so the kv tile is read once for
    the whole group (the GQA bandwidth win).
    """
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    bs = min(bs, S)
    s_pad = (-S) % bs
    if s_pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    Sp = k_cache.shape[1]
    n_s = Sp // bs
    qg = q.reshape(B, KV, G, D)

    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, n_s=n_s, scale=scale, gsize=G),
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths prefetch-like
            pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, si: (b, si, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, si: (b, si, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, H, D)


# ---------------------------------------------------------------------------
# int8-quantized KV variant (KIVI-style per-head scales, fused dequant)
# ---------------------------------------------------------------------------

def _kernel_q8(len_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref, o_ref,
               m_scr, l_scr, acc_scr, *, bs: int, n_s: int, scale: float,
               gsize: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_len = len_ref[b]
    k_scale = ks_ref[b, h]
    v_scale = vs_ref[b, h]

    @pl.when(si * bs < valid_len)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
        # fused dequantization on the VMEM tiles
        k = k_ref[0, :, 0, :].astype(jnp.float32) * k_scale  # (bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32) * v_scale
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = jax.lax.broadcasted_iota(jnp.int32, (gsize, bs), 1) + si * bs
        s = jnp.where(kpos < valid_len, s, _NEG)
        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, :1] = l_scr[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_scr[:, :1] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(si == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention_q8_pallas(q: jax.Array, k_cache: jax.Array,
                               v_cache: jax.Array, k_scale: jax.Array,
                               v_scale: jax.Array, lengths: jax.Array,
                               bs: int = 512, interpret: bool = False
                               ) -> jax.Array:
    """q: (B, H, D); caches: (B, S, KV, D) int8; scales: (B, KV)."""
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    bs = min(bs, S)
    s_pad = (-S) % bs
    if s_pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    Sp = k_cache.shape[1]
    n_s = Sp // bs
    qg = q.reshape(B, KV, G, D)

    out = pl.pallas_call(
        functools.partial(_kernel_q8, bs=bs, n_s=n_s, scale=scale, gsize=G),
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths
            pl.BlockSpec(memory_space=pltpu.SMEM),  # k scales (B, KV)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # v scales
            pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, si: (b, si, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, si: (b, si, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32), qg, k_cache, v_cache)
    return out.reshape(B, H, D)
