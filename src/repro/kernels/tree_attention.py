"""Token-tree verification attention (SpecInfer-style multi-draft), Pallas TPU.

The engine scores a whole token tree — J root-divergent drafts packed into a
prefix-deduplicated trie — in ONE target pass: the T-token window (pending
token + tree nodes) is written into the KV cache at consecutive SLOTS
``[lengths_b, lengths_b + T)`` while each node's rope position is its tree
DEPTH, and attention is masked so a node sees (a) every committed slot and
(b) exactly its in-window ancestors (``win_mask``, the ancestor-or-self
matrix of the tree).  A lower-triangular ``win_mask`` makes this kernel
bit-compatible with the sequential verification window.

Two layouts, matching the cache layouts of ``SpecEngine``:

  * contiguous — caches are (B, S, KV, D) slabs; the kernel walks S tiles
    with the online-softmax state in VMEM scratch (``decode_attention``
    pattern).
  * paged      — caches are (P, ps, KV, D) pools addressed through a
    scalar-prefetched page table, so each KV tile is DMA'd straight from
    its physical page (``paged_attention`` pattern); unmapped slots are
    skipped whole.

The in-window ancestor test is evaluated on the MXU as a one-hot matmul
(mask-row x slot-one-hot) instead of a gather, which keeps the kernel free
of dynamic indexing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30
_DOT_1_1 = (((1,), (1,)), ((), ()))
_DOT_1_0 = (((1,), (0,)), ((), ()))


def _win_allow(mask_f, base, tile0, tile_w, T, gsize):
    """(T * gsize, tile_w) float: 1.0 where query row r (= t * gsize + g) may
    attend the window node living at slot ``tile0 + column``.

    ``mask_f`` is the (T, T) ancestor matrix as float32; slot -> window-node
    membership is resolved by a one-hot matmul so no gather is needed:
    column c holds window node ``tile0 + c - base`` when that lands in
    [0, T).
    """
    kcol = jax.lax.broadcasted_iota(jnp.int32, (tile_w, T), 0) + tile0
    tcol = jax.lax.broadcasted_iota(jnp.int32, (tile_w, T), 1)
    onehot = (kcol - base == tcol).astype(jnp.float32)  # (tile_w, T)
    allow_t = jax.lax.dot_general(mask_f, onehot, _DOT_1_1, preferred_element_type=jnp.float32)
    allow = jnp.broadcast_to(allow_t[:, None, :], (T, gsize, tile_w))
    return allow.reshape(T * gsize, tile_w)


def _flash_update(s, v, m_scr, l_scr, acc_scr):
    """One online-softmax accumulation step over masked scores ``s``."""
    m_prev = m_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[:, :1] = l_scr[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[:, :1] = m_new
    pv = jax.lax.dot_general(p, v, _DOT_1_0, preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv


def _kernel(
    len_ref,
    q_ref,
    k_ref,
    v_ref,
    mask_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    bs,
    n_s,
    T,
    gsize,
    scale,
):
    b = pl.program_id(0)
    si = pl.program_id(2)
    R = T * gsize

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    base = len_ref[b]
    # tiles wholly past the window horizon contribute nothing
    tile_live = si * bs < base + T

    @pl.when(tile_live)
    def _update():
        D = q_ref.shape[-1]
        q = q_ref[0, :, 0, :, :].astype(jnp.float32).reshape(R, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bs, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, _DOT_1_1, preferred_element_type=jnp.float32) * scale
        kpos = jax.lax.broadcasted_iota(jnp.int32, (R, bs), 1) + si * bs
        committed = kpos < base
        allow = _win_allow(mask_ref[0].astype(jnp.float32), base, si * bs, bs, T, gsize)
        s = jnp.where(committed | (allow > 0.5), s, _NEG)
        _flash_update(s, v, m_scr, l_scr, acc_scr)

    @pl.when(si == n_s - 1)
    def _finish():
        D = q_ref.shape[-1]
        out = acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, :, 0, :, :] = out.reshape(T, gsize, D).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def tree_attention_pallas(q, k_cache, v_cache, lengths, win_mask, bs=128, interpret=False):
    """q: (B, T, H, D); caches: (B, S, KV, D) with the window already written
    at slots [lengths_b, lengths_b + T); lengths: (B,); win_mask: (B, T, T)
    bool ancestor-or-self matrix.  Returns (B, T, H, D).

    Grid: (B, KV, S-tiles); all T window rows x G = H/KV query heads of one
    kv head share the (T*G, D) q tile so each KV tile is streamed once per
    kv head (the GQA + tree-window bandwidth win).
    """
    B, T, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    bs = min(bs, S)
    s_pad = (-S) % bs
    if s_pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    n_s = k_cache.shape[1] // bs
    qg = q.reshape(B, T, KV, G, D)

    kernel = functools.partial(_kernel, bs=bs, n_s=n_s, T=T, gsize=G, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths
            pl.BlockSpec((1, T, 1, G, D), lambda b, h, si: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, si: (b, si, h, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, si: (b, si, h, 0)),
            pl.BlockSpec((1, T, T), lambda b, h, si: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, 1, G, D), lambda b, h, si: (b, 0, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((T * G, 128), jnp.float32),
            pltpu.VMEM((T * G, 128), jnp.float32),
            pltpu.VMEM((T * G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache, win_mask.astype(jnp.int32))
    return out.reshape(B, T, H, D)


def _paged_kernel(
    pt_ref,
    len_ref,
    q_ref,
    k_ref,
    v_ref,
    mask_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    ps,
    n_slots,
    T,
    gsize,
    scale,
):
    b = pl.program_id(0)
    si = pl.program_id(2)
    R = T * gsize

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    base = len_ref[b]
    # unmapped slots or slots wholly past the window horizon skip the DMA
    page_live = (pt_ref[b, si] >= 0) & (si * ps < base + T)

    @pl.when(page_live)
    def _update():
        D = q_ref.shape[-1]
        q = q_ref[0, :, 0, :, :].astype(jnp.float32).reshape(R, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (ps, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, _DOT_1_1, preferred_element_type=jnp.float32) * scale
        kpos = jax.lax.broadcasted_iota(jnp.int32, (R, ps), 1) + si * ps
        committed = kpos < base
        allow = _win_allow(mask_ref[0].astype(jnp.float32), base, si * ps, ps, T, gsize)
        s = jnp.where(committed | (allow > 0.5), s, _NEG)
        _flash_update(s, v, m_scr, l_scr, acc_scr)

    @pl.when(si == n_slots - 1)
    def _finish():
        D = q_ref.shape[-1]
        out = acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, :, 0, :, :] = out.reshape(T, gsize, D).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_tree_attention_pallas(q, k_pool, v_pool, page_table, lengths, win_mask, interpret=False):
    """``tree_attention_pallas`` through a paged KV cache.

    q: (B, T, H, D); pools: (P, ps, KV, D); page_table: (B, n_slots) int32
    (-1 = unmapped); lengths: (B,); win_mask: (B, T, T) bool.  The page
    table and lengths are scalar-prefetched so the k/v index maps resolve
    slot -> physical page before each DMA, exactly like ``paged_attention``.
    """
    B, T, H, D = q.shape
    ps = k_pool.shape[1]
    KV = k_pool.shape[2]
    n_slots = page_table.shape[1]
    G = H // KV
    scale = 1.0 / np.sqrt(D)
    qg = q.reshape(B, T, KV, G, D)

    def kv_map(b, h, si, pt, ln):
        return (jnp.maximum(pt[b, si], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_slots),
        in_specs=[
            pl.BlockSpec((1, T, 1, G, D), lambda b, h, si, pt, ln: (b, 0, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, D), kv_map),
            pl.BlockSpec((1, ps, 1, D), kv_map),
            pl.BlockSpec((1, T, T), lambda b, h, si, pt, ln: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, 1, G, D), lambda b, h, si, pt, ln: (b, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G, 128), jnp.float32),
            pltpu.VMEM((T * G, 128), jnp.float32),
            pltpu.VMEM((T * G, D), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_kernel, ps=ps, n_slots=n_slots, T=T, gsize=G, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, KV, G, D), q.dtype),
        interpret=interpret,
    )(
        page_table.astype(jnp.int32),
        lengths.astype(jnp.int32),
        qg,
        k_pool,
        v_pool,
        win_mask.astype(jnp.int32),
    )
    return out.reshape(B, T, H, D)
