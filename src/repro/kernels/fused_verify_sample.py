"""Fused draft-verification + calibrated-sampling Pallas kernel.

The verify hot path ran THREE dispatches per round: ``gather_softmax_prob``
over the (B*L, V) drafted-position logits, the jnp accept-test/cumprod, and
``residual_sample`` over dense (B, V) rows — the middle one forcing the
dense residual distribution (softmax + sparse-q scatter) to materialize in
HBM between the other two.  This kernel does the whole chain in one
``pallas_call`` per batch row, streaming the vocab tiles three times within
one sequential grid:

  phase 0  online softmax max/denominator for every drafted position plus
           the drafted token's logit; at the last tile run the accept test
           ``u < min(1, p_L/p_S)``, the prefix-acceptance count, and record
           the first-rejected row ``sel`` and its softmax stats.
  phase 1  residual mass Z_r = sum max(p_sel - q_sel, 0), rebuilding the
           sparse SLM row (idx, val) tile-locally, plus the argmax(p)
           degenerate fallback.
  phase 2  inverse-CDF crossing of u_resid * Z_r -> calibrated token.

Uniforms are drawn by the caller (``core.verification.verify_drafts``) with
the unchanged key splits, so the committed tokens are distributed exactly as
the unfused path.  The bonus token on full acceptance stays outside.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(dlen_ref, u_res_ref, tok_ids_ref, probs_ref, u_acc_ref,
            logits_ref, qidx_ref, qval_ref,
            acc_ref, nacc_ref, out_ref,
            m_scr, z_scr, pick_scr, sel_scr, res_scr,
            *, L: int, Lr: int, bv: int, n_v: int):
    phase = pl.program_id(1)
    vi = pl.program_id(2)

    logits = logits_ref[0].astype(jnp.float32)              # (Lr, bv)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Lr, bv), 1) + vi * bv
    rows = jax.lax.broadcasted_iota(jnp.int32, (Lr, 1), 0)

    # ---- phase 0: online softmax stats + picked logit per drafted row ----
    @pl.when((phase == 0) & (vi == 0))
    def _init0():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        z_scr[...] = jnp.zeros_like(z_scr)
        pick_scr[...] = jnp.full_like(pick_scr, _NEG)

    @pl.when(phase == 0)
    def _stats():
        m_prev = m_scr[:, :1]                               # (Lr, 1)
        m_tile = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_tile)
        corr = jnp.exp(m_prev - m_new)
        z_new = z_scr[:, :1] * corr + jnp.sum(
            jnp.exp(logits - m_new), axis=-1, keepdims=True)

        ids = tok_ids_ref[0][:, None]                       # (Lr, 1)
        hit = cols == ids
        picked_tile = jnp.max(jnp.where(hit, logits, _NEG), axis=-1,
                              keepdims=True)
        pick_new = jnp.maximum(pick_scr[:, :1], picked_tile)

        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        z_scr[...] = jnp.broadcast_to(z_new, z_scr.shape)
        pick_scr[...] = jnp.broadcast_to(pick_new, pick_scr.shape)

        @pl.when(vi == n_v - 1)
        def _accept():
            p_t = jnp.exp(pick_new - m_new) / z_new          # (Lr, 1)
            ratio = p_t[:, 0] / jnp.maximum(probs_ref[0], 1e-30)
            live = rows[:, 0] < dlen_ref[0, 0]
            acc = (u_acc_ref[0] < jnp.minimum(ratio, 1.0)) & live
            prefix = jnp.cumprod(acc.astype(jnp.int32))
            n_acc = jnp.sum(prefix)
            acc_ref[0, :] = acc.astype(jnp.int32)
            nacc_ref[0, 0] = n_acc
            sel = jnp.minimum(n_acc, L - 1)
            is_sel = rows[:, 0] == sel
            sel_scr[0, 0] = sel.astype(jnp.float32)
            sel_scr[0, 1] = jnp.sum(jnp.where(is_sel, m_new[:, 0], 0.0))
            sel_scr[0, 2] = jnp.sum(jnp.where(is_sel, z_new[:, 0], 0.0))

    def _residual_tile():
        """max(p_sel - q_sel, 0) over this vocab tile, plus p_sel itself."""
        sel = sel_scr[0, 0].astype(jnp.int32)
        m_sel, z_sel = sel_scr[0, 1], sel_scr[0, 2]
        is_sel = rows == sel                                 # (Lr, 1)
        l_sel = jnp.sum(jnp.where(is_sel, logits, 0.0), axis=0)     # (bv,)
        p = jnp.exp(l_sel - m_sel) / z_sel
        idx_sel = jnp.sum(jnp.where(is_sel, qidx_ref[0], 0), axis=0)
        val_sel = jnp.sum(
            jnp.where(is_sel, qval_ref[0].astype(jnp.float32), 0.0), axis=0)
        q = jnp.sum(jnp.where(idx_sel[:, None] == cols[:1], val_sel[:, None],
                              0.0), axis=0)                  # (bv,)
        return p, jnp.maximum(p - q, 0.0)

    # ---- phase 1: residual mass + argmax(p) fallback ----
    @pl.when((phase == 1) & (vi == 0))
    def _init1():
        res_scr[...] = jnp.zeros_like(res_scr)
        res_scr[0, 3] = -1.0                                 # picked token
        res_scr[0, 4] = _NEG                                 # best p
        res_scr[0, 5] = -1.0                                 # argmax col

    @pl.when(phase == 1)
    def _mass():
        p, r = _residual_tile()
        res_scr[0, 0] = res_scr[0, 0] + jnp.sum(r)
        m_tile = jnp.max(p)
        arg_tile = jnp.max(jnp.where(p == m_tile, cols[0], -1))

        @pl.when(m_tile > res_scr[0, 4])
        def _upd():
            res_scr[0, 4] = m_tile
            res_scr[0, 5] = arg_tile.astype(jnp.float32)

    # ---- phase 2: inverse-CDF crossing ----
    @pl.when(phase == 2)
    def _pick():
        _, r = _residual_tile()
        target = u_res_ref[0, 0] * res_scr[0, 0]
        prev = res_scr[0, 1]
        tile_cum = prev + jnp.cumsum(r)                      # (bv,)
        crossed = tile_cum > target
        idx_in_tile = jnp.argmax(crossed)
        has = jnp.any(crossed)

        @pl.when(has & (res_scr[0, 3] < 0))
        def _record():
            res_scr[0, 3] = (vi * bv + idx_in_tile).astype(jnp.float32)

        res_scr[0, 1] = prev + jnp.sum(r)

        @pl.when(vi == n_v - 1)
        def _finish():
            degenerate = res_scr[0, 0] <= 0.0
            fallback = res_scr[0, 5]
            picked = res_scr[0, 3]
            picked = jnp.where(picked < 0, fallback, picked)
            out_ref[0, 0] = jnp.where(degenerate, fallback,
                                      picked).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bv", "interpret"))
def fused_verify_sample_pallas(target_logits: jax.Array, draft_tokens: jax.Array,
                               draft_probs: jax.Array, q_idx: jax.Array,
                               q_val: jax.Array, u_accept: jax.Array,
                               u_resid: jax.Array, draft_len: jax.Array,
                               bv: int = 2048, interpret: bool = False):
    """See ``ref.fused_verify_sample_ref`` for shapes and semantics."""
    B, L = draft_tokens.shape
    V = target_logits.shape[-1]
    Vhat = q_idx.shape[-1]

    logits = target_logits[:, :L]                            # (B, L, V)
    Lr = -(-L // 8) * 8
    l_pad = Lr - L
    v_pad = (-V) % bv
    if l_pad or v_pad:
        logits = jnp.pad(logits, ((0, 0), (0, l_pad), (0, v_pad)),
                         constant_values=_NEG)
        draft_tokens = jnp.pad(draft_tokens, ((0, 0), (0, l_pad)))
        draft_probs = jnp.pad(draft_probs, ((0, 0), (0, l_pad)),
                              constant_values=1.0)
        u_accept = jnp.pad(u_accept, ((0, 0), (0, l_pad)), constant_values=1.0)
        q_idx = jnp.pad(q_idx, ((0, 0), (0, l_pad), (0, 0)))
        q_val = jnp.pad(q_val, ((0, 0), (0, l_pad), (0, 0)))
    n_v = logits.shape[-1] // bv

    dlen2d = jnp.minimum(draft_len, L).astype(jnp.int32)[:, None]
    u_res2d = u_resid.astype(jnp.float32)[:, None]

    acc, nacc, tok = pl.pallas_call(
        functools.partial(_kernel, L=L, Lr=Lr, bv=bv, n_v=n_v),
        grid=(B, 3, n_v),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, ph, vi: (b, 0)),       # draft_len
            pl.BlockSpec((1, 1), lambda b, ph, vi: (b, 0)),       # u_resid
            pl.BlockSpec((1, Lr), lambda b, ph, vi: (b, 0)),      # tokens
            pl.BlockSpec((1, Lr), lambda b, ph, vi: (b, 0)),      # p_S
            pl.BlockSpec((1, Lr), lambda b, ph, vi: (b, 0)),      # u_accept
            pl.BlockSpec((1, Lr, bv), lambda b, ph, vi: (b, 0, vi)),
            pl.BlockSpec((1, Lr, Vhat), lambda b, ph, vi: (b, 0, 0)),
            pl.BlockSpec((1, Lr, Vhat), lambda b, ph, vi: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Lr), lambda b, ph, vi: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, ph, vi: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, ph, vi: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Lr), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Lr, 128), jnp.float32),   # online max
            pltpu.VMEM((Lr, 128), jnp.float32),   # online denominator
            pltpu.VMEM((Lr, 128), jnp.float32),   # picked logit
            pltpu.VMEM((1, 128), jnp.float32),    # sel / m_sel / z_sel
            pltpu.VMEM((1, 128), jnp.float32),    # Z_r / cum / tok / argmax
        ],
        interpret=interpret,
    )(dlen2d, u_res2d, draft_tokens.astype(jnp.int32), draft_probs, u_accept,
      logits, q_idx.astype(jnp.int32), q_val)
    return acc[:, :L].astype(bool), nacc[:, 0], tok[:, 0]
