"""Fused residual-distribution inverse-CDF sampler (paper eq. 5).

The calibrated token at the first rejected position is a sample from
normalize(max(p_L - p_S, 0)) over the vocab.  A naive implementation
materializes the residual, its sum, and its cumsum — three extra HBM sweeps
of (N, V).  This kernel streams the vocab tiles twice within one grid
(phase 0: residual mass Z; phase 1: CDF crossing), carrying the running sum
and the found-token state in VMEM scratch across the sequential TPU grid.

Degenerate rows (Z == 0, i.e. p == q elementwise) fall back to argmax(p),
matching the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, p_ref, q_ref, out_ref, z_scr, cum_scr, tok_scr, best_scr,
            *, bv: int, n_v: int):
    phase = pl.program_id(1)
    vi = pl.program_id(2)

    p = p_ref[...].astype(jnp.float32)            # (1, bv)
    q = q_ref[...].astype(jnp.float32)
    r = jnp.maximum(p - q, 0.0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, bv), 1) + vi * bv

    @pl.when((phase == 0) & (vi == 0))
    def _init():
        z_scr[...] = jnp.zeros_like(z_scr)
        cum_scr[...] = jnp.zeros_like(cum_scr)
        tok_scr[...] = jnp.full_like(tok_scr, -1)
        best_scr[...] = jnp.full_like(best_scr, -1e30)

    @pl.when(phase == 0)
    def _accumulate():
        z_scr[0, 0] = z_scr[0, 0] + jnp.sum(r)
        # track argmax(p) for the degenerate fallback
        m_tile = jnp.max(p)
        arg_tile = jnp.max(jnp.where(p == m_tile, cols, -1))

        @pl.when(m_tile > best_scr[0, 0])
        def _upd():
            best_scr[0, 0] = m_tile
            best_scr[0, 1] = arg_tile.astype(jnp.float32)

    @pl.when(phase == 1)
    def _pick():
        target = u_ref[0, 0] * z_scr[0, 0]
        prev = cum_scr[0, 0]
        tile_cum = prev + jnp.cumsum(r[0])        # (bv,)
        crossed = tile_cum > target
        # first crossing column within this tile (or bv if none)
        idx_in_tile = jnp.argmax(crossed)
        has = jnp.any(crossed)

        @pl.when(has & (tok_scr[0, 0] < 0))
        def _record():
            tok_scr[0, 0] = (vi * bv + idx_in_tile).astype(jnp.float32)

        cum_scr[0, 0] = prev + jnp.sum(r)

        @pl.when(vi == n_v - 1)
        def _finish():
            degenerate = z_scr[0, 0] <= 0.0
            fallback = best_scr[0, 1]
            picked = tok_scr[0, 0]
            picked = jnp.where(picked < 0, fallback, picked)
            out_ref[0, 0] = jnp.where(degenerate, fallback, picked).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bv", "interpret"))
def residual_sample_pallas(p: jax.Array, q: jax.Array, u: jax.Array,
                           bv: int = 2048, interpret: bool = False) -> jax.Array:
    """p, q: (N, V) probability rows; u: (N,) uniforms -> tokens (N,) int32."""
    N, V = p.shape
    v_pad = (-V) % bv
    if v_pad:
        p = jnp.pad(p, ((0, 0), (0, v_pad)))
        q = jnp.pad(q, ((0, 0), (0, v_pad)))
    Vp = p.shape[1]
    n_v = Vp // bv
    u2d = u.astype(jnp.float32)[:, None]

    out = pl.pallas_call(
        functools.partial(_kernel, bv=bv, n_v=n_v),
        grid=(N, 2, n_v),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ni, ph, vi: (ni, 0)),
            pl.BlockSpec((1, bv), lambda ni, ph, vi: (ni, vi)),
            pl.BlockSpec((1, bv), lambda ni, ph, vi: (ni, vi)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda ni, ph, vi: (ni, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((1, 128), jnp.float32),   # Z
            pltpu.VMEM((1, 128), jnp.float32),   # running cumsum
            pltpu.VMEM((1, 128), jnp.float32),   # picked token
            pltpu.VMEM((1, 128), jnp.float32),   # (best p, argmax) fallback
        ],
        interpret=interpret,
    )(u2d, p, q)
    return out[:, 0]
