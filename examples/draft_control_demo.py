"""Visualize the paper's draft-control solutions (Figs. 3, 4 analogues).

Prints ASCII curves of (a) the content-latency tradeoff tau(L) with the
Theorem-1 optimum marked, and (b) the heterogeneous allocation produced by
Algorithm 1 — longer drafts AND more bandwidth to high-acceptance devices in
the communication-limited regime (Remark 2).

  PYTHONPATH=src python examples/draft_control_demo.py
"""

import numpy as np

from repro.core.bandwidth import solve_equalized_theta
from repro.core.channel import ChannelConfig, ChannelState
from repro.core.draft_control import optimal_uniform_length, solve_heterogeneous
from repro.core.goodput import goodput_homogeneous

rng = np.random.default_rng(0)
cfg = ChannelConfig(total_bandwidth_hz=2e6)   # communication-limited cell
K = 8
alphas = np.array([0.71, 0.74, 0.74, 0.86, 0.93, 0.93, 0.96, 0.74])
T_S = rng.uniform(0.85, 1.15, K) * 0.006
ch = ChannelState.sample(cfg, K, rng)
T_ver = 0.035 + K * 0.0177

# --- (a) uniform-length tradeoff ---
theta, _ = solve_equalized_theta(T_S, ch.rates, cfg.q_tok_bits,
                                 cfg.total_bandwidth_hz)
alpha = float(np.mean(alphas))
Ls = np.arange(1, 26)
taus = np.array([goodput_homogeneous(alpha, L, float(theta), T_ver, K)
                 for L in Ls])
L_star, L_tilde = optimal_uniform_length(alpha, float(theta), T_ver, L_max=25)
print("tau(L) — content-latency tradeoff (paper Fig. 3):")
for L, tau in zip(Ls, taus):
    bar = "#" * int(40 * tau / taus.max())
    mark = "  <= L* (Theorem 1)" if L == int(L_star) else ""
    print(f"  L={L:2d} {tau:7.1f} {bar}{mark}")

# --- (b) heterogeneous allocation ---
sol = solve_heterogeneous(alphas, T_S, ch.rates, cfg.q_tok_bits,
                          cfg.total_bandwidth_hz, T_ver, L_max=25)
print(f"\nAlgorithm 1 (goodput {sol.goodput:.1f} tok/s, "
      f"phi*={sol.equalized_latency * 1e3:.1f} ms):")
print("  device | alpha | T_S(ms) | rate | L_k | B_k(kHz)")
for k in range(K):
    print(f"    {k}    | {alphas[k]:.2f} | {T_S[k] * 1e3:5.1f}  "
          f"| {ch.rates[k]:4.1f} | {sol.lengths[k]:3d} "
          f"| {sol.bandwidth[k] / 1e3:7.1f}")
corr = np.corrcoef(alphas, sol.lengths)[0, 1]
print(f"\ncorr(alpha, L_k) = {corr:.2f}  (Remark 2: high-alpha devices get "
      f"longer drafts and more bandwidth)")
