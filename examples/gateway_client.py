"""Talk to the Multi-SPIN live serving gateway.

Self-contained by default: stands up an in-process gateway over a
synthetic-backend cell, streams two generations concurrently over SSE,
retires a third mid-flight, and scrapes the Prometheus metrics — the whole
client surface in one script, stdlib only.

    PYTHONPATH=src python examples/gateway_client.py

Point it at an already-running gateway (e.g. started with
``python -m repro.launch.gateway --port 8011``) instead:

    PYTHONPATH=src python examples/gateway_client.py --port 8011
"""

import argparse
import asyncio

from repro.serving.gateway import GatewayClient


async def stream_one(client: GatewayClient, name: str, **fields):
    """Stream one generation, printing every SSE event as it lands."""
    async for ev in client.stream_generate(**fields):
        if ev.event == "queued":
            print(f"[{name}] queued as rid={ev.data['rid']} "
                  f"scheme={ev.data['scheme']}")
        elif ev.event == "round":
            print(f"[{name}] round {ev.data['round']}: "
                  f"+{ev.data['n']} tokens {ev.data['tokens']} "
                  f"(total {ev.data['generated']}, "
                  f"t_round={ev.data['t_round'] * 1e3:.0f}ms sim)")
        elif ev.event == "done":
            print(f"[{name}] done: {ev.data['generated']} tokens in "
                  f"{ev.data['rounds']} rounds "
                  f"(sim TTFT {ev.data['ttft_sim_s'] * 1e3:.0f}ms)")
        else:
            print(f"[{name}] {ev.event}: {ev.data}")


async def demo(host: str, port: int):
    client = GatewayClient(host, port)

    # two concurrent streams with different device profiles
    await asyncio.gather(
        stream_one(client, "fast-device", prompt_len=8, max_new_tokens=24,
                   alpha=0.86, T_S=0.008),
        stream_one(client, "slow-device", prompt_len=8, max_new_tokens=24,
                   alpha=0.71, T_S=0.012),
    )

    # a third stream, retired mid-flight via DELETE /v1/streams/{rid}
    res = await client.generate(prompt_len=8, max_new_tokens=10 ** 6,
                                alpha=0.8, T_S=0.009,
                                disconnect_after_rounds=2)
    print(f"[abandoned] rid={res.rid} got {len(res.tokens)} tokens in "
          f"{res.n_rounds} rounds, then disconnected "
          "(the gateway retires the stream and frees its pages)")

    stats = await client.stats()
    print(f"\n/v1/stats: rounds={stats['rounds_total']} "
          f"tokens={stats['tokens_committed_total']} "
          f"acceptance={stats['acceptance_total']:.3f} "
          f"goodput_capped={stats['scheduler']['goodput_capped']:.1f} tok/s")
    metrics = await client.metrics()
    print("\n/metrics (first lines):")
    for line in metrics.splitlines()[:8]:
        print(" ", line)


async def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=None,
                    help="attach to a running gateway instead of starting "
                         "an in-process one")
    args = ap.parse_args()

    if args.port is not None:
        await demo(args.host, args.port)
        return

    from repro.api import CellConfig, MultiSpinCell
    from repro.serving.gateway import GatewayConfig, MultiSpinGateway

    cell = MultiSpinCell(CellConfig(scheme="hete", max_batch=4, seed=0,
                                    t_ver_fix=0.035, t_ver_lin=0.0177,
                                    L_max=8))
    gw = MultiSpinGateway(cell, GatewayConfig(port=0, idle_wait_s=0.02))
    await gw.start()
    print(f"in-process gateway on port {gw.port}\n")
    try:
        await demo("127.0.0.1", gw.port)
    finally:
        await gw.stop()


if __name__ == "__main__":
    asyncio.run(main())
