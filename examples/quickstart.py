"""Quickstart: solve multi-access draft control and run a Multi-SPIN round.

Runs in seconds on CPU.  Demonstrates the paper's full control loop:
channel sampling -> draft-length + bandwidth optimization (Algorithm 1) ->
a simulated Multi-SPIN round with realized goodput.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.controller import MultiSpinController, VerificationLatencyModel
from repro.core.protocol import DeviceProfile, MultiSpinProtocol

K = 12
rng = np.random.default_rng(0)

# 1. a heterogeneous edge cell: four task types (paper Table I) and +-15%
#    device compute spread
alphas = {"mbpp": 0.8582, "gsm8k": 0.7390, "mtbench": 0.7393, "squad": 0.7126}
tasks = rng.choice(list(alphas), K)
devices = [DeviceProfile(T_S=0.009 * f, alpha=alphas[t], task=t)
           for f, t in zip(rng.uniform(0.85, 1.15, K), tasks)]

# 2. the server-side controller (Algorithm 1: heterogeneous lengths)
channel = ChannelConfig()
controller = MultiSpinController(
    scheme="hete",
    q_tok_bits=channel.q_tok_bits,
    bandwidth_hz=channel.total_bandwidth_hz,
    t_ver_model=VerificationLatencyModel(t_fix=0.035, t_lin=0.0177),
)

# 3. run 20 rounds
proto = MultiSpinProtocol(controller, channel, devices, rng)
for i in range(20):
    rec = proto.run_round()
    if i < 3 or i == 19:
        print(f"round {i:2d}: L={rec.lengths} "
              f"goodput={rec.realized_goodput:6.1f} tok/s "
              f"(predicted {rec.predicted_goodput:6.1f})")

summary = proto.summary()
print(f"\n{summary['rounds']} rounds, {summary['tokens']:.0f} tokens, "
      f"sum goodput {summary['goodput']:.1f} tok/s")

# 4. compare against the heterogeneity-agnostic baseline
proto_fixed = MultiSpinProtocol(
    MultiSpinController(scheme="fixed", q_tok_bits=channel.q_tok_bits,
                        bandwidth_hz=channel.total_bandwidth_hz,
                        t_ver_model=VerificationLatencyModel(0.035, 0.0177)),
    channel, devices, np.random.default_rng(0))
fixed = proto_fixed.run(20)
print(f"fixed BW&L baseline: {fixed['goodput']:.1f} tok/s "
      f"(+{100 * (summary['goodput'] / fixed['goodput'] - 1):.0f}% from joint "
      f"draft control)")
