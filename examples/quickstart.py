"""Quickstart: stand up a Multi-SPIN cell and run the paper's control loop.

Runs in seconds on CPU.  Demonstrates the full loop through the session
API: channel sampling -> draft-length + bandwidth optimization
(Algorithm 1) -> simulated Multi-SPIN rounds with realized goodput.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.api import CellConfig, MultiSpinCell, Request, available_schemes

K = 12
rng = np.random.default_rng(0)

# 1. a heterogeneous edge cell: four task types (paper Table I) and +-15%
#    device compute spread, described as requests joining the cell
alphas = {"mbpp": 0.8582, "gsm8k": 0.7390, "mtbench": 0.7393, "squad": 0.7126}
tasks = rng.choice(list(alphas), K)
requests = [Request(rid=i, prompt_len=8, max_new_tokens=10 ** 9,
                    alpha=alphas[t], T_S=0.009 * f, task=t)
            for i, (f, t) in enumerate(zip(rng.uniform(0.85, 1.15, K), tasks))]

# 2. one JSON-serializable config: scheme (Algorithm 1: heterogeneous
#    lengths), channel, and the verification latency model; every scheme's
#    parameters and capability flags come from the registry's schemas
config = CellConfig(scheme="hete", t_ver_fix=0.035, t_ver_lin=0.0177,
                    max_batch=K)
print("registered schemes:", ", ".join(available_schemes()))

# 3. run 20 rounds
cell = MultiSpinCell(config, rng=np.random.default_rng(0))
for r in requests:
    cell.submit(r)
for i in range(20):
    rec = cell.step()
    if i < 3 or i == 19:
        print(f"round {i:2d}: L={rec.lengths} "
              f"goodput={rec.realized_goodput:6.1f} tok/s "
              f"(predicted {rec.predicted_goodput:6.1f})")

summary = cell.summary()
print(f"\n{summary['rounds']} rounds, {summary['tokens']:.0f} tokens, "
      f"sum goodput {summary['goodput']:.1f} tok/s")

# 4. compare against the heterogeneity-agnostic baseline — same cell, one
#    config field changed (scheme_params validates against the scheme's
#    declared Params schema)
fixed_cell = MultiSpinCell(CellConfig(scheme="fixed",
                                      scheme_params={"L_fixed": 8},
                                      max_batch=K),
                           rng=np.random.default_rng(0))
for r in requests:
    fixed_cell.submit(Request(rid=r.rid, prompt_len=r.prompt_len,
                              max_new_tokens=10 ** 9, alpha=r.alpha,
                              T_S=r.T_S, task=r.task))
fixed = fixed_cell.run(20)
print(f"fixed BW&L baseline: {fixed['goodput']:.1f} tok/s "
      f"(+{100 * (summary['goodput'] / fixed['goodput'] - 1):.0f}% from joint "
      f"draft control)")
