"""End-to-end Multi-SPIN serving with REAL models through the session API.

K simulated edge devices each run a small draft LM; the server runs a larger
target LM; every round the cell re-solves draft control from the current
channel state, the ``EngineBackend`` drafts + batch-verifies on real
weights, and goodput is accounted with the paper's latency model.  The
online acceptance estimator feeds planning (protocol step 5).

The engine uses the PAGED KV cache, so the device population is live: a
device joins mid-session (admitted onto pooled pages — no fixed batch) and
another leaves (its pages return to the pool).

  PYTHONPATH=src python examples/multi_spin_serving.py
"""

import jax
import numpy as np

from repro.api import (
    CellConfig,
    ChannelConfig,
    EngineBackend,
    MultiSpinCell,
    Request,
    SpecEngine,
)
from repro.configs import get_config

K, PROMPT_LEN, ROUNDS = 4, 12, 6
rng = np.random.default_rng(0)

# target: qwen2.5-3b family (reduced); draft: 1-layer sibling
target_cfg = get_config("qwen2.5-3b").smoke().replace(
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256)
draft_cfg = target_cfg.replace(num_layers=1, d_model=64, num_heads=2,
                               num_kv_heads=1, head_dim=32, d_ff=128,
                               name="draft")

engine = SpecEngine(target_cfg, draft_cfg, max_len=256, cache_kind="paged")
engine.init_params(jax.random.PRNGKey(0))
prompts = jax.random.randint(jax.random.PRNGKey(1), (K, PROMPT_LEN), 0,
                             target_cfg.vocab_size)
backend = EngineBackend(engine, engine.start(prompts))

config = CellConfig(
    scheme="hete", channel=ChannelConfig(vocab_size=target_cfg.vocab_size),
    t_ver_fix=0.035, t_ver_lin=0.0177, L_max=8, max_batch=K + 1,
    use_estimator=True)
cell = MultiSpinCell(config, backend=backend, rng=rng)
for i, f in enumerate(rng.uniform(0.85, 1.15, K)):
    cell.submit(Request(rid=i, prompt_len=PROMPT_LEN, max_new_tokens=10 ** 9,
                        alpha=0.8, T_S=0.009 * f, task="mixed"))

print(f"serving {K} devices, target={target_cfg.name}, draft={draft_cfg.name}")
for i in range(ROUNDS):
    if i == 2:     # a new device joins AFTER engine.start(): paged admission
        cell.submit(Request(rid=K, prompt_len=8, max_new_tokens=10 ** 9,
                            alpha=0.8, T_S=0.01, task="mixed"))
        print(f"  + device {K} joins (pool: {engine.pool_stats()['free_pages']} "
              "pages free)")
    if i == 4:     # ... and one leaves: its pages return to the pool
        cell.leave(0)
        print(f"  - device 0 leaves (pool: {engine.pool_stats()['free_pages']} "
              "pages free)")
    rec = cell.step()
    print(f"round {i}: L={rec.lengths} accepted={rec.accepted} "
          f"goodput={rec.realized_goodput:.1f} tok/s  "
          f"alpha_hat={np.round(cell.estimator.alpha_hat, 2)}")

print("\nfinal stream lengths:",
      [len(c) for c in backend.state.committed])
print("summary:", {k: round(v, 2) if isinstance(v, float) else v
                   for k, v in cell.summary().items()})
