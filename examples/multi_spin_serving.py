"""End-to-end Multi-SPIN serving with REAL models.

K simulated edge devices each run a small draft LM; the server runs a larger
target LM; every round the controller re-solves draft control from the
current channel state, the engine drafts + batch-verifies on real weights,
and goodput is accounted with the paper's latency model.

  PYTHONPATH=src python examples/multi_spin_serving.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.core.channel import ChannelConfig
from repro.core.controller import MultiSpinController, VerificationLatencyModel
from repro.core.protocol import DeviceProfile, MultiSpinProtocol
from repro.serving import SpecEngine

K, PROMPT_LEN, ROUNDS = 4, 12, 6
rng = np.random.default_rng(0)

# target: qwen2.5-3b family (reduced); draft: 1-layer sibling
target_cfg = get_config("qwen2.5-3b").smoke().replace(
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256)
draft_cfg = target_cfg.replace(num_layers=1, d_model=64, num_heads=2,
                               num_kv_heads=1, head_dim=32, d_ff=128,
                               name="draft")

engine = SpecEngine(target_cfg, draft_cfg, max_len=256)
engine.init_params(jax.random.PRNGKey(0))
prompts = jax.random.randint(jax.random.PRNGKey(1), (K, PROMPT_LEN), 0,
                             target_cfg.vocab_size)
engine_state = engine.start(prompts)

channel = ChannelConfig(vocab_size=target_cfg.vocab_size)
controller = MultiSpinController(
    scheme="hete", q_tok_bits=channel.q_tok_bits,
    bandwidth_hz=channel.total_bandwidth_hz,
    t_ver_model=VerificationLatencyModel(0.035, 0.0177), L_max=8)
devices = [DeviceProfile(T_S=0.009 * f, alpha=0.8, task="mixed")
           for f in rng.uniform(0.85, 1.15, K)]

proto = MultiSpinProtocol(controller, channel, devices, rng, engine=engine,
                          engine_state=engine_state, use_estimator=True)

print(f"serving {K} devices, target={target_cfg.name}, draft={draft_cfg.name}")
for i in range(ROUNDS):
    rec = proto.run_round()
    print(f"round {i}: L={rec.lengths} accepted={rec.accepted} "
          f"goodput={rec.realized_goodput:.1f} tok/s  "
          f"alpha_hat={np.round(proto.estimator.alpha_hat, 2)}")

print("\nfinal stream lengths:",
      [len(c) for c in proto.engine_state.committed])
print("summary:", {k: round(v, 2) if isinstance(v, float) else v
                   for k, v in proto.summary().items()})
