"""Train a ~100M-parameter LM for a few hundred steps on synthetic data.

Exercises the full training substrate end-to-end on CPU: model zoo config,
synthetic Markov data, AdamW + schedule, microbatched train step,
checkpoint/restart.  The loss demonstrably decreases (the data has learnable
bigram structure).

  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.models import build_model
from repro.training import (
    DataConfig,
    OptimizerConfig,
    SyntheticLMDataset,
    init_optimizer,
    make_train_step,
)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
args = ap.parse_args()

# ~100M params: a narrow tinyllama-family config
cfg = get_config("tinyllama-1.1b").replace(
    name="tinyllama-100m", num_layers=8, d_model=640, num_heads=10,
    num_kv_heads=2, head_dim=64, d_ff=1792, vocab_size=8192)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
n_params = model.num_params(params)
print(f"model: {cfg.name}  params: {n_params / 1e6:.1f}M")

opt_cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=20,
                          decay_steps=args.steps)
opt_state = init_optimizer(opt_cfg, params)
data = SyntheticLMDataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                     global_batch=8))
step_fn = jax.jit(make_train_step(model, opt_cfg, microbatches=2))
mgr = CheckpointManager(args.ckpt_dir, keep=2)

# resume if a checkpoint exists
start = 0
restored = mgr.restore_latest({"params": params, "opt": opt_state})
if restored is not None:
    tree, meta = restored
    params, opt_state = tree["params"], tree["opt"]
    start = meta["step"]
    print(f"resumed from step {start}")

t0 = time.time()
for step in range(start, args.steps):
    batch = {"tokens": jnp.asarray(data.batch(step)["tokens"])}
    params, opt_state, metrics = step_fn(params, opt_state, batch)
    if step % 25 == 0 or step == args.steps - 1:
        print(f"step {step:4d}  loss={float(metrics['loss']):.4f}  "
              f"lr={float(metrics['lr']):.2e}  "
              f"gnorm={float(metrics['grad_norm']):.2f}  "
              f"{(time.time() - t0):.0f}s")
    if step and step % 100 == 0:
        mgr.save_async(step, {"params": params, "opt": opt_state})

mgr.wait()
mgr.save(args.steps, {"params": params, "opt": opt_state})
print("done; checkpoints:", mgr.steps())
