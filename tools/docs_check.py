"""Fail on broken relative links in the repo's markdown docs.

Scans README.md and docs/**/*.md for markdown links, resolves every
relative target (path plus optional ``#fragment``) against the linking
file, and exits non-zero listing each target that does not exist.
Fragments are checked against the target's headings (GitHub anchor
slugs).  External links (``http(s)://``, ``mailto:``) are ignored — this
gate is about repo-internal rot, not the network.

Stdlib only.  Usage::

    python tools/docs_check.py            # from the repo root
    python tools/docs_check.py --root DIR
"""

from __future__ import annotations

import argparse
import os
import re
import sys

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def _anchor(heading: str) -> str:
    """GitHub's heading -> anchor slug (lowercase, drop punctuation,
    spaces to dashes).  Inline code/emphasis markers are stripped."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(md_path: str) -> set:
    with open(md_path, encoding="utf-8") as f:
        body = CODE_FENCE_RE.sub("", f.read())
    return {_anchor(m.group(1)) for m in HEADING_RE.finditer(body)}


def _doc_files(root: str) -> list:
    files = []
    readme = os.path.join(root, "README.md")
    if os.path.exists(readme):
        files.append(readme)
    docs = os.path.join(root, "docs")
    for dirpath, _dirnames, filenames in os.walk(docs):
        files += [os.path.join(dirpath, f) for f in sorted(filenames)
                  if f.endswith(".md")]
    return files


def check(root: str) -> list:
    """[(file, link, reason)] for every broken relative link."""
    errors = []
    for md in _doc_files(root):
        with open(md, encoding="utf-8") as f:
            body = CODE_FENCE_RE.sub("", f.read())
        for link in LINK_RE.findall(body):
            if link.startswith(("http://", "https://", "mailto:")):
                continue
            path, _, frag = link.partition("#")
            target = (md if not path
                      else os.path.normpath(
                          os.path.join(os.path.dirname(md), path)))
            rel = os.path.relpath(md, root)
            if not os.path.exists(target):
                errors.append((rel, link, "target does not exist"))
                continue
            if frag:
                if not target.endswith(".md"):
                    continue                    # only md fragments checkable
                if _anchor(frag) not in _anchors(target):
                    errors.append((rel, link, "missing anchor"))
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    default_root = os.path.normpath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))
    ap.add_argument("--root", default=default_root,
                    help="repo root (default: this script's parent)")
    args = ap.parse_args()
    errors = check(args.root)
    for fname, link, reason in errors:
        print(f"BROKEN {fname}: ({link}) — {reason}")
    n_files = len(_doc_files(args.root))
    print(f"docs-check: {n_files} file(s) scanned, {len(errors)} broken "
          f"link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
