"""End-to-end CLI smoke tests for the launch drivers (subprocess)."""

import subprocess
import sys


def _run(args, timeout=1200):
    # CPU-only hosts spend most of the wall-clock in XLA compilation for
    # these subprocesses (~8-9 min measured for the serve driver), so the
    # budget is deliberately generous.
    out = subprocess.run(
        [sys.executable, "-m"] + args, capture_output=True, text=True,
        cwd=".", timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"},
    )
    return out


def test_train_cli_smoke():
    out = _run(["repro.launch.train", "--arch", "qwen2.5-3b", "--smoke",
                "--steps", "8", "--seq-len", "32", "--batch", "4"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss=" in out.stdout


def test_serve_cli_smoke():
    out = _run(["repro.launch.serve", "--arch", "deepseek-7b", "--smoke",
                "--devices", "2", "--rounds", "2", "--max-new-tokens", "6"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "goodput=" in out.stdout


def test_serve_cli_scheme_fixed():
    out = _run(["repro.launch.serve", "--arch", "qwen2.5-3b", "--smoke",
                "--devices", "2", "--rounds", "1", "--scheme", "fixed"])
    assert out.returncode == 0, out.stderr[-2000:]
