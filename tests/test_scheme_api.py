"""The structured Observation→RoundPlan scheme API: registry schemas,
capability flags, CLI parsing, and exact parity between cell-planned rounds
and the underlying analytic solvers."""

import dataclasses

import numpy as np
import pytest

from repro.api import (
    CellConfig,
    CellObservation,
    ChannelState,
    MultiSpinCell,
    Request,
    RoundPlan,
    Scheme,
    SchemeCapabilities,
    available_schemes,
    build_scheme,
    get_scheme,
    scheme_table_markdown,
)
from repro.core.beyond import (
    TokenBudgetVerifier,
    solve_heterogeneous_packed,
    solve_uniform_multidraft,
)
from repro.core.channel import ChannelConfig
from repro.core.draft_control import (
    solve_centralized,
    solve_heterogeneous,
    solve_p2p,
)
from repro.core.schemes import parse_scheme_args, scheme_help_text


def _obs(K=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    cfg = ChannelConfig()
    ch = ChannelState.sample(cfg, K, rng)
    base = dict(alphas=rng.choice([0.71, 0.74, 0.86, 0.93], K),
                T_S=rng.uniform(0.85, 1.15, K) * 0.009, rates=ch.rates,
                q_tok_bits=cfg.q_tok_bits, bandwidth_hz=cfg.total_bandwidth_hz,
                t_ver_fix=0.035, t_ver_lin=0.0177,
                t_draft_fix=0.005, t_draft_lin=0.01)
    base.update(kw)
    return CellObservation(**base)


# ---------------------------------------------------------------------------
# Records
# ---------------------------------------------------------------------------

def test_observation_latency_models():
    obs = _obs(K=4)
    assert obs.K == 4
    assert obs.t_ver() == pytest.approx(0.035 + 4 * 0.0177)
    assert obs.t_ver(1) == pytest.approx(0.035 + 0.0177)
    assert obs.t_draft_per_token() == pytest.approx(0.005 + 4 * 0.01)
    sub = obs.take(np.array([0, 2]))
    assert sub.K == 2
    np.testing.assert_array_equal(sub.alphas, obs.alphas[[0, 2]])
    assert sub.bandwidth_hz == obs.bandwidth_hz   # cell-level fields ride


def test_round_plan_defaults_and_expected_tokens():
    plan = build_scheme("hete").plan(_obs())
    assert isinstance(plan, RoundPlan)
    assert plan.verification_mode == "padded"
    assert plan.draft_width == 1
    assert plan.t_ver is None                     # cell's affine model applies
    assert plan.expected_tokens == pytest.approx(
        plan.goodput * (plan.equalized_latency + _obs().t_ver()), rel=1e-9)


# ---------------------------------------------------------------------------
# Exact solver parity (the classes are adapters, not re-implementations)
# ---------------------------------------------------------------------------

def test_hete_scheme_matches_solver():
    obs = _obs()
    plan = build_scheme("hete").plan(obs)
    sol = solve_heterogeneous(obs.alphas, obs.T_S, obs.rates, obs.q_tok_bits,
                              obs.bandwidth_hz, obs.t_ver(), L_max=25)
    np.testing.assert_array_equal(plan.lengths, sol.lengths)
    np.testing.assert_allclose(plan.bandwidth, sol.bandwidth)
    assert plan.goodput == sol.goodput


def test_cen_scheme_matches_solver_and_has_no_uplink():
    obs = _obs()
    plan = build_scheme("cen").plan(obs)
    sol = solve_centralized(obs.alphas, obs.t_ver(), obs.t_draft_fix,
                            obs.t_draft_lin, L_max=25)
    np.testing.assert_array_equal(plan.lengths, sol.lengths)
    assert plan.goodput == sol.goodput
    assert np.all(plan.bandwidth == 0.0)
    # server drafting overrides the uplink latency model uniformly
    np.testing.assert_allclose(
        plan.per_device_latency,
        plan.lengths * (obs.t_draft_fix + obs.K * obs.t_draft_lin))


def test_cen_scheme_requires_draft_model():
    with pytest.raises(ValueError, match="draft-latency"):
        build_scheme("cen").plan(_obs(t_draft_fix=0.0, t_draft_lin=0.0))


def test_p2p_scheme_matches_solver():
    obs = _obs(K=1)
    plan = build_scheme("p2p").plan(obs)
    sol = solve_p2p(float(obs.alphas[0]), float(obs.T_S[0]),
                    float(obs.rates[0]), obs.q_tok_bits, obs.bandwidth_hz,
                    obs.t_ver(1), L_max=25)
    np.testing.assert_array_equal(plan.lengths, sol.lengths)
    assert plan.goodput == sol.goodput


def test_packed_scheme_sets_mode_and_t_ver():
    obs = _obs()
    plan = build_scheme("hete-packed").plan(obs)
    verifier = TokenBudgetVerifier.from_affine(obs.t_ver_fix, obs.t_ver_lin,
                                               L_ref=8, kv_fraction=0.7)
    sol = solve_heterogeneous_packed(obs.alphas, obs.T_S, obs.rates,
                                     obs.q_tok_bits, obs.bandwidth_hz,
                                     verifier, L_max=25)
    assert plan.verification_mode == "packed"
    assert plan.goodput == sol.goodput
    assert plan.t_ver == pytest.approx(sol.meta["t_ver"])


def test_padded_tokenbudget_scheme_carries_its_t_ver():
    """The padded token-budget scheme must bill executed rounds with its
    OWN verifier cost, not the affine model (same contract as packed)."""
    obs = _obs()
    plan = build_scheme("hete-padded-tokenbudget").plan(obs)
    verifier = TokenBudgetVerifier.from_affine(obs.t_ver_fix, obs.t_ver_lin,
                                               L_ref=8, kv_fraction=0.7)
    assert plan.t_ver == pytest.approx(
        verifier.padded(obs.K, int(np.max(plan.lengths))))


def test_server_drafting_scheme_rejects_pipelined_schedule():
    """A two-half pipeline would overlap the server's own drafting with its
    own verification — both run on the same server."""
    with pytest.raises(ValueError, match="server"):
        CellConfig(scheme="cen", schedule="pipelined")
    from repro.core.beyond import pipelined_plan
    with pytest.raises(ValueError, match="server"):
        pipelined_plan(build_scheme("cen"), _obs())


def test_missing_required_param_not_reported_as_unknown():
    """A Params field without a default must surface the real cause."""

    @dataclasses.dataclass(frozen=True)
    class NeedsCoef:
        coef: float

    cls = type("Needy", (Scheme,), {"name": "needy-test", "Params": NeedsCoef})
    with pytest.raises(ValueError, match="coef"):
        cls()          # no 'unknown parameter []' nonsense
    assert "needy-test" not in available_schemes()   # never registered


def test_multidraft_scheme_matches_solver():
    obs = _obs()
    plan = build_scheme("multidraft").plan(obs)
    verifier = TokenBudgetVerifier.from_affine(obs.t_ver_fix, obs.t_ver_lin,
                                               L_ref=8, kv_fraction=0.7)
    out = solve_uniform_multidraft(float(np.mean(obs.alphas)), obs.T_S,
                                   obs.rates, obs.q_tok_bits,
                                   obs.bandwidth_hz, verifier, obs.K)
    assert plan.goodput == out["best"]["goodput"]
    assert plan.draft_width == out["best"]["J"]
    assert np.all(plan.lengths == out["best"]["L"])
    assert plan.expected_tokens == pytest.approx(obs.K * out["best"]["E_N"])


# ---------------------------------------------------------------------------
# Capabilities / schema surface
# ---------------------------------------------------------------------------

def test_capability_flags_declared():
    assert get_scheme("p2p").capabilities.single_user_only
    assert get_scheme("cen").capabilities.server_drafting
    assert get_scheme("hete-packed").capabilities.packed_verification
    assert get_scheme("multidraft").capabilities.multi_draft
    assert get_scheme("hete").capabilities == SchemeCapabilities()
    assert get_scheme("cen").capabilities.flags() == ("server_drafting",)


def test_parse_scheme_args_coerces_types():
    out = parse_scheme_args("multidraft", ["J_max=3", "kv_fraction=0.5"])
    assert out == {"J_max": 3, "kv_fraction": 0.5}
    assert isinstance(out["J_max"], int)
    assert parse_scheme_args("hete", None) == {}
    with pytest.raises(ValueError, match="no parameter"):
        parse_scheme_args("fixed", ["nope=1"])
    with pytest.raises(ValueError, match="key=value"):
        parse_scheme_args("fixed", ["L_fixed"])


def test_scheme_help_and_table_cover_registry():
    help_text = scheme_help_text()
    table = scheme_table_markdown()
    for name in available_schemes():
        assert name in help_text
        assert f"`{name}`" in table
    assert "single_user_only" in table        # p2p row carries its flag


def test_register_scheme_rejects_bad_declarations():
    from repro.core.schemes import register_scheme

    with pytest.raises(ValueError, match="name"):
        register_scheme(type("Anon", (Scheme,), {}))
    with pytest.raises(ValueError, match="already registered"):
        register_scheme(type("Dup", (Scheme,), {"name": "hete"}))


# ---------------------------------------------------------------------------
# Through the cell: the new schemes run end to end
# ---------------------------------------------------------------------------

def _drain_cell(scheme, K, scheme_params=None, **cfg_kw):
    cfg = CellConfig(scheme=scheme, scheme_params=scheme_params or {},
                     max_batch=K, seed=0, **cfg_kw)
    cell = MultiSpinCell(cfg)
    rng = np.random.default_rng(0)
    for i in range(K):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=24,
                            alpha=float(rng.choice([0.74, 0.86])),
                            T_S=0.009 * float(rng.uniform(0.85, 1.15))))
    out = cell.drain()
    assert cell.scheduler.stats.completed == K
    return cell, out


def test_cen_cell_runs_rounds_without_uplink_blowup():
    cell, out = _drain_cell("cen", K=4)
    assert out["goodput"] > 0
    # server drafting: every round's multi-access phase is the batched SLM
    # forward — bounded, not the 1e9-latency zero-bandwidth artifact
    for rec in cell.history:
        assert rec.t_ma < 10.0
        assert np.all(rec.bandwidth == 0.0)


def test_multidraft_cell_draws_wider_acceptance():
    cell, out = _drain_cell("multidraft", K=4)
    assert out["goodput"] > 0
    for rec in cell.history:
        assert np.all(rec.accepted <= rec.lengths + 1)


def test_p2p_cell_single_user_session():
    cell, out = _drain_cell("p2p", K=1)
    assert out["goodput"] > 0


def test_scheme_params_reach_the_planner():
    cell, _ = _drain_cell("fixed", K=3, scheme_params={"L_fixed": 5})
    assert all(np.all(rec.lengths == 5) for rec in cell.history)
    # legacy knob still honored when scheme_params is silent
    cell2, _ = _drain_cell("fixed", K=3, L_fixed=4)
    assert all(np.all(rec.lengths == 4) for rec in cell2.history)


def test_readme_scheme_table_in_sync():
    """The README table is GENERATED (python -m repro.core.schemes); this
    guards against drift after registering a new scheme."""
    import pathlib
    readme = (pathlib.Path(__file__).parent.parent / "README.md").read_text()
    for line in scheme_table_markdown().splitlines():
        assert line in readme, f"README scheme table is stale: missing {line!r}"


def test_schemes_module_prints_table(capsys):
    from repro.core import schemes as schemes_mod
    assert hasattr(schemes_mod, "scheme_table_markdown")
    # dataclass Params of every scheme must be instantiable from defaults
    for name in available_schemes():
        assert dataclasses.is_dataclass(get_scheme(name).Params)
        build_scheme(name)
