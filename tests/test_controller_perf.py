"""Micro-benchmark guard: the vectorized (phi, lambda) grid search and the
other batched solvers must stay at least as fast as the per-grid-point
Python loops they replace, and produce identical solutions.

The loop references below are the straightforward scalar implementations
(one closed-form solve + one bisection per grid point); the shipped solvers
batch the whole grid through one numpy pass.  Margins are generous so a
loaded CI host cannot flake the guard, while a regression back to Python
loops (orders of magnitude) is caught immediately.
"""

import time

import numpy as np
import pytest

from repro.core.bandwidth import solve_equalized_phi, solve_equalized_theta
from repro.core.beyond import (
    TokenBudgetVerifier,
    expected_accepted_multidraft,
    solve_uniform_multidraft,
)
from repro.core.channel import ChannelConfig, ChannelState
from repro.core.draft_control import (
    heterogeneous_lengths,
    round_lengths,
    search_grids,
    solve_heterogeneous,
)
from repro.core.goodput import goodput_from_equalized_latency


def _system(K=12, seed=0):
    rng = np.random.default_rng(seed)
    alphas = rng.choice([0.71, 0.74, 0.86, 0.93], K)
    T_S = rng.uniform(0.85, 1.15, K) * 0.009
    cfg = ChannelConfig()
    ch = ChannelState.sample(cfg, K, rng)
    return alphas, T_S, ch.rates, cfg.q_tok_bits, cfg.total_bandwidth_hz


def _loop_heterogeneous(alphas, T_S, r, Q_tok, B, T_ver, L_max=25,
                        n_phi=40, n_lam=40):
    """Algorithm 1 as a per-grid-point Python loop (the shape the batched
    solver replaces): scalar Proposition-1 lengths + one Lemma-3 bisection
    per (phi, lambda) candidate."""
    phis, lams = search_grids(alphas, T_S, r, Q_tok, B, L_max, n_phi, n_lam)
    best_tau, best_L = -np.inf, None
    for phi in phis:
        for lam in lams:
            L_tilde = heterogeneous_lengths(phi, lam, alphas, T_S, r, Q_tok)
            L = round_lengths(np.nan_to_num(L_tilde, nan=1.0), L_max)
            phi_hat, _ = solve_equalized_phi(L, T_S, r, Q_tok, B)
            tau = float(goodput_from_equalized_latency(alphas, L, phi_hat,
                                                       T_ver))
            if np.isfinite(tau) and tau > best_tau:
                best_tau, best_L = tau, L.astype(np.int64)
    return best_tau, best_L


def _loop_multidraft(alpha, T_S, r, Q_tok, B, verifier, K, L_max=25,
                     J_max=6):
    """The pre-vectorization (J, L) double loop: one scalar Lemma-1
    bisection per J, one E[N] evaluation per (J, L)."""
    best = {"goodput": -1.0}
    base = None
    for J in range(1, J_max + 1):
        theta_J, _ = solve_equalized_theta(T_S, r, Q_tok * J, B)
        for L in range(1, L_max + 1):
            e_n = float(expected_accepted_multidraft(np.float64(alpha), L, J))
            t_ma = L * float(theta_J)
            t_ver = (verifier.t_fix + verifier.c_seq * K * J
                     + verifier.c_tok * K * J * (L + 1))
            tau = K * e_n / (t_ma + t_ver)
            rec = {"goodput": tau, "L": L, "J": J}
            if J == 1 and (base is None or tau > base["goodput"]):
                base = rec
            if tau > best["goodput"]:
                best = rec
    return best, base


def _timed(fn, reps=3):
    best = np.inf
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def test_vectorized_grid_search_matches_and_beats_loop():
    """Acceptance gate: on the n_phi=40, n_lam=40 grid the batched
    Algorithm-1 search returns the loop's solution and is measurably
    faster (the loop pays 1600 Python-level bisections)."""
    alphas, T_S, r, Q, B = _system(K=12)
    T_ver = 0.035 + 12 * 0.0177

    t_vec, sol = _timed(lambda: solve_heterogeneous(
        alphas, T_S, r, Q, B, T_ver, L_max=25, n_phi=40, n_lam=40))
    t_loop, (tau_loop, L_loop) = _timed(lambda: _loop_heterogeneous(
        alphas, T_S, r, Q, B, T_ver), reps=1)

    assert sol.goodput == pytest.approx(tau_loop, rel=1e-9)
    np.testing.assert_array_equal(sol.lengths, L_loop)
    # "no slower than the loop it replaces" with a wide margin; in practice
    # the batched pass is >10x faster on this grid
    assert t_vec < t_loop, (t_vec, t_loop)


def test_vectorized_multidraft_matches_and_beats_loop():
    alphas, T_S, r, Q, B = _system(K=8, seed=1)
    verifier = TokenBudgetVerifier.from_affine(0.035, 0.0177)
    alpha = float(np.mean(alphas))

    t_vec, out = _timed(lambda: solve_uniform_multidraft(
        alpha, T_S, r, Q, B, verifier, 8))
    t_loop, (best, base) = _timed(lambda: _loop_multidraft(
        alpha, T_S, r, Q, B, verifier, 8), reps=1)

    assert out["best"]["goodput"] == pytest.approx(best["goodput"], rel=1e-9)
    assert (out["best"]["J"], out["best"]["L"]) == (best["J"], best["L"])
    assert out["single_draft"]["goodput"] == pytest.approx(base["goodput"],
                                                           rel=1e-9)
    # 150 scalar bisections vs one batched bisection + one grid pass
    assert t_vec < t_loop, (t_vec, t_loop)
