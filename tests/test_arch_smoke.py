"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED config of
the same family, run one forward/train step on CPU, assert output shapes and
finiteness.  Decode-path consistency (forward_window vs full forward) is
asserted for every family — this is the invariant batched speculative
verification relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import build_model


def _prefix(cfg, B, key):
    if cfg.family == "vlm":
        return jax.random.normal(key, (B, cfg.num_patches, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        return jax.random.normal(key, (B, cfg.encoder_seq_len, cfg.d_model)) * 0.1
    return None


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    prefix = _prefix(cfg, B, jax.random.PRNGKey(2))
    logits, aux = model.apply(params, tokens, prefix_embeds=prefix)
    expected_S = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, expected_S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    """One gradient step: loss finite, grads finite and non-trivial."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    prefix = _prefix(cfg, B, jax.random.PRNGKey(2))

    def loss_fn(p):
        logits, aux = model.apply(p, tokens, prefix_embeds=prefix)
        txt = logits[:, -S:]
        ce = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(txt[:, :-1].astype(jnp.float32), axis=-1),
            tokens[:, 1:, None], axis=-1))
        return ce + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert total > 0.0, f"{arch}: all-zero gradients"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_decode_consistency(arch):
    """forward_window with cache must reproduce the full causal forward.

    This is the correctness substrate of speculative verification: scoring a
    draft window against the cache must give the same target distribution as
    rescoring the whole prefix.
    """
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, T = 2, 12, 3
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    draft = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab_size)
    prefix = _prefix(cfg, B, jax.random.PRNGKey(3))

    P = cfg.num_patches if cfg.family == "vlm" else 0
    max_len = S + T + P + 4
    cache = model.init_cache(B, max_len, jnp.float32)
    _, cache, _ = model.prefill(params, tokens, cache, prefix_embeds=prefix)
    pos = jnp.full((B,), S + P, jnp.int32)
    win_logits, _ = model.forward_window(params, draft, cache, pos)

    # MoE reference must also use no-drop dispatch: capacity dropping is
    # batch-coupled, so the dropped-token sets of the two passes differ.
    kw = {"moe_capacity": model.no_drop_capacity} if cfg.num_experts else {}
    full, _ = model.apply(params, jnp.concatenate([tokens, draft], axis=1),
                          prefix_embeds=prefix, **kw)
    want = full[:, S + P: S + P + T]
    np.testing.assert_allclose(np.asarray(win_logits), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_heterogeneous_positions(arch):
    """Per-row cache offsets: rows with different prefix lengths verify
    correctly in one batch (the Multi-SPIN zero-padding scenario)."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S1, S2, T = 6, 10, 2
    P = cfg.num_patches if cfg.family == "vlm" else 0
    max_len = 24 + P

    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, S1), 0, cfg.vocab_size)
    t2 = jax.random.randint(jax.random.PRNGKey(2), (1, S2), 0, cfg.vocab_size)
    draft = jax.random.randint(jax.random.PRNGKey(3), (2, T), 0, cfg.vocab_size)
    pfx1 = _prefix(cfg, 1, jax.random.PRNGKey(4))
    pfx2 = _prefix(cfg, 1, jax.random.PRNGKey(5))

    # ragged prefill: each row prefilled to its true length, caches batched
    # via the model's concat_caches (SSM states make joint padded prefill
    # incorrect), then one batched window at per-row offsets — exactly the
    # Multi-SPIN server's layout for heterogeneous prefixes.
    c1 = model.init_cache(1, max_len, jnp.float32)
    _, c1, _ = model.prefill(params, t1, c1, prefix_embeds=pfx1)
    c2 = model.init_cache(1, max_len, jnp.float32)
    _, c2, _ = model.prefill(params, t2, c2, prefix_embeds=pfx2)
    cache = model.concat_caches([c1, c2])
    pos = jnp.array([S1 + P, S2 + P], jnp.int32)
    win, _ = model.forward_window(params, draft, cache, pos)

    # reference: each row independently (no-drop dispatch for MoE)
    kw = {"moe_capacity": model.no_drop_capacity} if cfg.num_experts else {}
    full1, _ = model.apply(params, jnp.concatenate([t1, draft[:1]], 1),
                           prefix_embeds=pfx1, **kw)
    full2, _ = model.apply(params, jnp.concatenate([t2, draft[1:]], 1),
                           prefix_embeds=pfx2, **kw)
    np.testing.assert_allclose(np.asarray(win[0]),
                               np.asarray(full1[0, S1 + P:S1 + P + T]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(win[1]),
                               np.asarray(full2[0, S2 + P:S2 + P + T]),
                               rtol=2e-4, atol=2e-4)


