"""Training substrate tests: optimizer, data, train loop, checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, load, save
from repro.configs import get_config
from repro.models import build_model
from repro.training import (
    DataConfig,
    OptimizerConfig,
    SyntheticLMDataset,
    apply_gradients,
    init_optimizer,
    make_train_step,
)
from repro.training.optimizer import schedule


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic_loss():
    cfg = OptimizerConfig(learning_rate=0.1, warmup_steps=0, decay_steps=1000,
                          weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_optimizer(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = apply_gradients(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_clipping():
    cfg = OptimizerConfig(grad_clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = init_optimizer(cfg, params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, m = apply_gradients(cfg, params, huge, state)
    assert float(m["grad_norm"]) > 1e6  # reported raw norm


@given(st.integers(0, 20000))
@settings(max_examples=50, deadline=None)
def test_schedule_bounds(step):
    cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=100, decay_steps=10000)
    lr = float(schedule(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.learning_rate * (1 + 1e-6)
    if step >= cfg.decay_steps:
        assert lr == pytest.approx(cfg.learning_rate * cfg.min_lr_ratio, rel=1e-5)


def test_bf16_optimizer_state():
    cfg = OptimizerConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones(8)}
    state = init_optimizer(cfg, params)
    assert state["m"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=101, seq_len=32, global_batch=8, seed=7)
    ds = SyntheticLMDataset(cfg)
    b1, b2 = ds.batch(3), ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert ds.batch(4)["tokens"].shape == (8, 32)
    assert (ds.batch(4)["tokens"] != b1["tokens"]).any()
    sh = ds.shard(b1, worker=1, num_workers=4)
    np.testing.assert_array_equal(sh["tokens"], b1["tokens"][2:4])


def test_data_has_learnable_structure():
    """The Markov structure must make bigrams predictable ~half the time."""
    cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=4, seed=0)
    ds = SyntheticLMDataset(cfg)
    toks = ds.batch(0)["tokens"]
    pattern = (ds.state_shift[ds.state_of[toks[:, :-1]]] + toks[:, :-1]) % cfg.vocab_size
    frac = float(np.mean(pattern == toks[:, 1:]))
    assert 0.35 < frac < 0.75


# ---------------------------------------------------------------------------
# Train loop
# ---------------------------------------------------------------------------

def test_train_step_learns_on_synthetic_data():
    cfg = get_config("tinyllama-1.1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(learning_rate=3e-3, warmup_steps=5, decay_steps=200)
    opt_state = init_optimizer(opt_cfg, params)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    ds = SyntheticLMDataset(dcfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    losses = []
    for step in range(30):
        batch = {"tokens": jnp.asarray(ds.batch(step)["tokens"])}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::10]
    assert np.isfinite(losses).all()


def test_microbatched_grads_match_full_batch():
    cfg = get_config("tinyllama-1.1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(warmup_steps=0)
    ds = SyntheticLMDataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                       global_batch=8))
    batch = {"tokens": jnp.asarray(ds.batch(0)["tokens"])}
    s0 = init_optimizer(opt_cfg, params)
    full = make_train_step(model, opt_cfg, microbatches=1)
    micro = make_train_step(model, opt_cfg, microbatches=4)
    p1, _, m1 = full(params, s0, batch)
    p2, _, m2 = micro(params, init_optimizer(opt_cfg, params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": jnp.array(3, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    p = str(tmp_path / "ck.bin")
    save(p, t, {"step": 7})
    got, meta = load(p, jax.tree.map(jnp.zeros_like, t))
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_detects_corruption(tmp_path):
    p = str(tmp_path / "ck.bin")
    save(p, _tree())
    raw = bytearray(open(p, "rb").read())
    raw[-3] ^= 0xFF
    open(p, "wb").write(bytes(raw))
    with pytest.raises(ValueError, match="corrupt"):
        load(p, _tree())


def test_manager_retention_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3]:
        mgr.save(s, {"w": jnp.full((2,), float(s))})
    assert mgr.steps() == [2, 3]
    got, meta = mgr.restore_latest({"w": jnp.zeros(2)})
    assert meta["step"] == 3
    np.testing.assert_array_equal(np.asarray(got["w"]), [3.0, 3.0])


def test_manager_skips_corrupt_latest(tmp_path):
    """Node dies mid-write of step 3 -> restore falls back to step 2."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(2, {"w": jnp.full((2,), 2.0)})
    mgr.save(3, {"w": jnp.full((2,), 3.0)})
    p3 = mgr._path(3)
    raw = bytearray(open(p3, "rb").read())
    raw[-1] ^= 0xFF
    open(p3, "wb").write(bytes(raw))
    got, meta = mgr.restore_latest({"w": jnp.zeros(2)})
    assert meta["step"] == 2


def test_manager_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save_async(10, {"w": jnp.ones(4)})
    mgr.wait()
    assert mgr.steps() == [10]


def test_restart_resumes_training_bitexact(tmp_path):
    """Kill-and-restart: training from a checkpoint reproduces the
    uninterrupted run exactly (data pipeline is step-indexed)."""
    cfg = get_config("tinyllama-1.1b").smoke()
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(warmup_steps=0)
    ds = SyntheticLMDataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                       global_batch=4))
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_optimizer(opt_cfg, params)
    mgr = CheckpointManager(str(tmp_path))
    # run 6 steps, checkpoint at 3
    for s in range(6):
        if s == 3:
            mgr.save(s, {"params": params, "opt": opt_state})
        batch = {"tokens": jnp.asarray(ds.batch(s)["tokens"])}
        params, opt_state, _ = step_fn(params, opt_state, batch)
    # restart from step 3
    restored, meta = mgr.restore_latest(
        {"params": model.init(jax.random.PRNGKey(1)),
         "opt": init_optimizer(opt_cfg, model.init(jax.random.PRNGKey(1)))})
    p2, o2 = restored["params"], restored["opt"]
    for s in range(meta["step"], 6):
        batch = {"tokens": jnp.asarray(ds.batch(s)["tokens"])}
        p2, o2, _ = step_fn(p2, o2, batch)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
