"""Roofline analysis tests: HLO collective parsing + analytic term model."""

import jax
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config
from repro.models import build_model
from repro.roofline.analysis import count_params, model_flops, parse_collectives
from repro.roofline.analytic import MeshInfo, n_units, roofline_terms, summarize

HLO_SAMPLE = """
HloModule test
ENTRY %main {
  %p0 = bf16[16,4096,3072]{2,1,0} parameter(0)
  %ag = bf16[16,4096,3072]{2,1,0} all-gather(%p0), replica_groups={}
  %ar = f32[256,1024]{1,0} all-reduce(%x), to_apply=%add
  %rs = bf16[8,512]{1,0} reduce-scatter(%y), to_apply=%add
  %cp = bf16[4,128]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_parse_collectives_counts_and_bytes():
    st = parse_collectives(HLO_SAMPLE)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1
    assert st.counts["all-to-all"] == 0
    assert st.bytes_by_op["all-gather"] == 16 * 4096 * 3072 * 2
    assert st.bytes_by_op["all-reduce"] == 256 * 1024 * 4
    assert st.total_bytes > 0


def test_count_params_matches_real_init():
    """Config-derived parameter counts must equal actual init counts."""
    for arch in ["qwen2.5-3b", "mamba2-130m", "moonshot-v1-16b-a3b"]:
        cfg = get_config(arch).smoke()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        total, active = count_params(cfg)
        assert total == model.num_params(params)
        assert 0 < active <= total


def test_moe_active_params_smaller():
    total, active = count_params(get_config("arctic-480b"))
    assert active < total
    assert total > 400e9  # it is the 480B-class config
    t2, a2 = count_params(get_config("deepseek-7b"))
    assert t2 == a2  # dense: all params active


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k"])
def test_analytic_terms_positive_and_consistent(arch, shape_name):
    cfg = get_config(arch).replace(param_dtype="bfloat16",
                                   compute_dtype="bfloat16", remat=True)
    shape = SHAPES[shape_name]
    mesh = MeshInfo(chips=256, dp=16, mp=16)
    tb = roofline_terms(cfg, shape, mesh)
    assert tb.flops > 0
    assert tb.hbm_bytes > 0
    total, active = count_params(get_config(arch))
    mf = model_flops(cfg, shape, total, active)
    s = summarize(tb, mf, 256)
    assert s["bottleneck"] in ("compute", "memory", "collective")
    assert 0 < s["peak_fraction"] <= 1.5, s
    # useful-flops ratio: accounting flops >= model flops per chip (remat,
    # attention quadratic, routers all add overhead)
    if shape_name == "train_4k":
        assert s["flops_ratio"] <= 1.01, s["flops_ratio"]


def test_train_flops_at_least_6nd():
    """Analytic train FLOPs must be >= 6ND/chips (remat adds the extra)."""
    cfg = get_config("deepseek-7b").replace(remat=True)
    shape = SHAPES["train_4k"]
    tb = roofline_terms(cfg, shape, MeshInfo(chips=256, dp=16, mp=16))
    total, _ = count_params(cfg)
    six_nd = 6.0 * total * shape.global_batch * shape.seq_len / 256
    assert tb.flops >= six_nd * 0.95


def test_flash_flag_removes_score_bytes():
    cfg = get_config("phi4-mini-3.8b")
    shape = SHAPES["prefill_32k"]
    mesh = MeshInfo(chips=256, dp=16, mp=16)
    base = roofline_terms(cfg, shape, mesh, flash=False)
    flash = roofline_terms(cfg, shape, mesh, flash=True)
    assert flash.hbm_bytes < base.hbm_bytes * 0.6, \
        (flash.hbm_bytes, base.hbm_bytes)


def test_n_units():
    assert n_units(get_config("zamba2-2.7b")) == 9
    assert n_units(get_config("phi4-mini-3.8b")) == 32
