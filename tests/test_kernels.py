"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles.

Per assignment: for each kernel, sweep shapes/dtypes and assert_allclose
against the ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gather_softmax_prob import gather_softmax_prob_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.residual_sample import residual_sample_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.tree_attention import (
    paged_tree_attention_pallas,
    tree_attention_pallas,
)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Skv,H,KV,D", [
    (1, 128, 128, 4, 4, 64),        # MHA, single tile
    (2, 256, 256, 4, 2, 64),        # GQA, multi-tile
    (1, 96, 96, 4, 1, 64),          # MQA, padded seq (96 < 128)
    (1, 128, 384, 2, 2, 128),       # cross window (kv longer: chunked prefill)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Sq, Skv, H, KV, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, D), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 160, 2, 64))
    k = jax.random.normal(ks[1], (1, 160, 2, 64))
    v = jax.random.normal(ks[2], (1, 160, 2, 64))
    got = flash_attention_pallas(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D,bs", [
    (2, 512, 4, 4, 64, 128),
    (3, 300, 8, 2, 64, 128),        # ragged padding, GQA
    (1, 2048, 4, 1, 128, 512),      # MQA long cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(B, S, H, KV, D, bs, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    got = decode_attention_pallas(q, k, v, lengths, bs=bs, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# paged attention (decode / speculative-verification window)
# ---------------------------------------------------------------------------

def _random_page_table(rng, B, NP, P, ps, lengths, T):
    """Page tables covering lengths + T - 1 positions from a shuffled pool
    (non-contiguous physical pages, like a churned allocator)."""
    pt = np.full((B, NP), -1, np.int32)
    pool_pages = rng.permutation(P)
    n = 0
    for b in range(B):
        need = -(-(int(lengths[b]) + T - 1) // ps)
        pt[b, :need] = pool_pages[n:n + need]
        n += need
    return pt


@pytest.mark.parametrize("B,T,H,KV,D,ps,P,NP", [
    (2, 1, 4, 2, 64, 16, 24, 8),      # decode, GQA
    (3, 5, 4, 1, 64, 16, 40, 6),      # verification window, MQA
    (1, 3, 8, 4, 128, 32, 12, 4),     # MHA-ish, big pages
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_ref(B, T, H, KV, D, ps, P, NP, dtype):
    rng = np.random.default_rng(B * 10 + T)
    ks = jax.random.split(jax.random.PRNGKey(B * 100 + T), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), dtype)
    kp = jax.random.normal(ks[1], (P, ps, KV, D), dtype)
    vp = jax.random.normal(ks[2], (P, ps, KV, D), dtype)
    lengths = rng.integers(1, NP * ps - T + 1, B)
    pt = _random_page_table(rng, B, NP, P, ps, lengths, T)
    got = paged_attention_pallas(q, kp, vp, jnp.asarray(pt),
                                 jnp.asarray(lengths), interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, jnp.asarray(pt),
                                   jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_paged_attention_decode_equals_contiguous_decode():
    """T=1 paged attention over a gathered view == the contiguous decode
    oracle: paging must be a pure layout change."""
    B, H, KV, D, ps, P, NP = 2, 4, 2, 64, 16, 24, 8
    rng = np.random.default_rng(3)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kp = jax.random.normal(ks[1], (P, ps, KV, D))
    vp = jax.random.normal(ks[2], (P, ps, KV, D))
    lengths = rng.integers(1, NP * ps + 1, B)
    pt = _random_page_table(rng, B, NP, P, ps, lengths, 1)
    got = paged_attention_pallas(q, kp, vp, jnp.asarray(pt),
                                 jnp.asarray(lengths), interpret=True)
    kc = np.asarray(kp)[np.maximum(pt, 0)].reshape(B, NP * ps, KV, D)
    vc = np.asarray(vp)[np.maximum(pt, 0)].reshape(B, NP * ps, KV, D)
    want = ref.decode_attention_ref(q[:, 0], jnp.asarray(kc),
                                    jnp.asarray(vc), jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_ops_dispatch(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    from repro.kernels import ops
    rng = np.random.default_rng(5)
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, T, H, KV, D, ps, P, NP = 2, 2, 4, 2, 64, 16, 16, 4
    q = jax.random.normal(ks[0], (B, T, H, D))
    kp = jax.random.normal(ks[1], (P, ps, KV, D))
    vp = jax.random.normal(ks[2], (P, ps, KV, D))
    lengths = rng.integers(1, NP * ps - T + 1, B)
    pt = _random_page_table(rng, B, NP, P, ps, lengths, T)
    got = ops.paged_attention(q, kp, vp, jnp.asarray(pt), jnp.asarray(lengths))
    want = ref.paged_attention_ref(q, kp, vp, jnp.asarray(pt),
                                   jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# tree attention (multi-draft token-tree verification window)
# ---------------------------------------------------------------------------

def _random_tree_mask(rng, B, T):
    """Random ancestor-or-self matrices: a random parent forest over window
    slots (parent index < node index), closed transitively — exactly the
    structure ``core.token_tree`` produces."""
    mask = np.zeros((B, T, T), dtype=bool)
    mask[:, :, 0] = True
    mask[:, 0, 1:] = False
    for b in range(B):
        for i in range(1, T):
            parent = int(rng.integers(0, i))
            mask[b, i] = mask[b, parent]
            mask[b, i, i] = True
    return mask


@pytest.mark.parametrize("B,T,H,KV,D,S,bs", [
    (2, 5, 4, 2, 64, 96, 32),       # GQA, ragged tiles
    (1, 9, 4, 1, 64, 256, 128),     # MQA, deeper tree window
    (3, 3, 8, 4, 128, 64, 64),      # MHA-ish
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tree_attention_matches_ref(B, T, H, KV, D, S, bs, dtype):
    rng = np.random.default_rng(B * 10 + T)
    ks = jax.random.split(jax.random.PRNGKey(B * 100 + T), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    lengths = jnp.asarray(rng.integers(1, S - T + 1, B))
    wm = jnp.asarray(_random_tree_mask(rng, B, T))
    got = tree_attention_pallas(q, k, v, lengths, wm, bs=bs, interpret=True)
    want = ref.tree_attention_ref(q, k, v, lengths, wm)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_tree_attention_chain_equals_causal_window():
    """A lower-triangular win_mask must reproduce the SEQUENTIAL
    verification window: tree attention is a strict generalization."""
    B, T, H, KV, D, S = 2, 4, 4, 2, 64, 128
    rng = np.random.default_rng(7)
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    lengths = jnp.asarray(rng.integers(1, S - T + 1, B))
    tril = np.broadcast_to(np.tril(np.ones((T, T), bool)), (B, T, T))
    got = tree_attention_pallas(q, k, v, lengths, jnp.asarray(tril),
                                interpret=True)
    # sequential semantics: window row t sits at slot lengths + t and
    # attends every slot <= its own, i.e. [0, lengths + t + 1)
    qg = np.asarray(q)
    want = np.zeros_like(qg)
    for b in range(B):
        kc = jnp.asarray(np.asarray(k)[b:b + 1])
        vc = jnp.asarray(np.asarray(v)[b:b + 1])
        for t in range(T):
            w = ref.decode_attention_ref(
                jnp.asarray(qg[b:b + 1, t]), kc, vc,
                jnp.asarray([int(lengths[b]) + t + 1]))
            want[b, t] = np.asarray(w[0])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,T,H,KV,D,ps,P,NP", [
    (2, 4, 4, 2, 64, 16, 24, 8),      # GQA
    (3, 7, 4, 1, 64, 16, 48, 6),      # MQA, J*L+1-ish window
    (1, 3, 8, 4, 128, 32, 12, 4),     # big pages
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_tree_attention_matches_ref(B, T, H, KV, D, ps, P, NP, dtype):
    rng = np.random.default_rng(B * 10 + T)
    ks = jax.random.split(jax.random.PRNGKey(B * 100 + T), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), dtype)
    kp = jax.random.normal(ks[1], (P, ps, KV, D), dtype)
    vp = jax.random.normal(ks[2], (P, ps, KV, D), dtype)
    lengths = rng.integers(1, NP * ps - T + 1, B)
    pt = _random_page_table(rng, B, NP, P, ps, lengths, T + 1)
    wm = jnp.asarray(_random_tree_mask(rng, B, T))
    got = paged_tree_attention_pallas(q, kp, vp, jnp.asarray(pt),
                                      jnp.asarray(lengths), wm,
                                      interpret=True)
    want = ref.paged_tree_attention_ref(q, kp, vp, jnp.asarray(pt),
                                        jnp.asarray(lengths), wm)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_paged_tree_chain_equals_paged_attention():
    """Paged tree attention with a chain mask == the existing paged
    verification-window kernel (same masking law, same layout)."""
    B, T, H, KV, D, ps, P, NP = 2, 3, 4, 2, 64, 16, 20, 6
    rng = np.random.default_rng(9)
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    kp = jax.random.normal(ks[1], (P, ps, KV, D))
    vp = jax.random.normal(ks[2], (P, ps, KV, D))
    lengths = rng.integers(1, NP * ps - T - 1, B)
    pt = _random_page_table(rng, B, NP, P, ps, lengths, T + 1)
    tril = np.broadcast_to(np.tril(np.ones((T, T), bool)), (B, T, T))
    got = paged_tree_attention_pallas(q, kp, vp, jnp.asarray(pt),
                                      jnp.asarray(lengths),
                                      jnp.asarray(tril), interpret=True)
    # paged_attention's lengths convention: row t attends [0, lengths + t);
    # the tree convention adds the row's own slot, so chain(base) ==
    # paged_attention(base + 1)
    want = paged_attention_pallas(q, kp, vp, jnp.asarray(pt),
                                  jnp.asarray(lengths) + 1, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_tree_attention_ops_dispatch(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    from repro.kernels import ops
    rng = np.random.default_rng(11)
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    B, T, H, KV, D, S = 2, 3, 4, 2, 64, 64
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    lengths = jnp.asarray(rng.integers(1, S - T + 1, B))
    wm = jnp.asarray(_random_tree_mask(rng, B, T))
    got = ops.tree_attention(q, k, v, lengths, wm)
    want = ref.tree_attention_ref(q, k, v, lengths, wm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    ps, P, NP = 16, 16, 4
    kp = jax.random.normal(ks[1], (P, ps, KV, D))
    vp = jax.random.normal(ks[2], (P, ps, KV, D))
    l2 = rng.integers(1, NP * ps - T + 1, B)
    pt = _random_page_table(rng, B, NP, P, ps, l2, T)
    got = ops.paged_tree_attention(q, kp, vp, jnp.asarray(pt),
                                   jnp.asarray(l2), wm)
    want = ref.paged_tree_attention_ref(q, kp, vp, jnp.asarray(pt),
                                        jnp.asarray(l2), wm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# gather softmax prob
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,V,bv", [
    (8, 4096, 2048),
    (5, 50280, 8192),      # vocab not a tile multiple (mamba2 vocab)
    (16, 257, 512),        # tiny vocab, heavy padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_softmax_prob_matches_ref(N, V, bv, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    logits = (jax.random.normal(ks[0], (N, V)) * 4.0).astype(dtype)
    ids = jax.random.randint(ks[1], (N,), 0, V)
    got = gather_softmax_prob_pallas(logits, ids, bv=bv, interpret=True)
    want = ref.gather_softmax_prob_ref(logits, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# residual sample
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,V,bv", [(16, 4096, 1024), (7, 1000, 256),
                                    (4, 50280, 8192)])
def test_residual_sample_matches_ref(N, V, bv):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    p = jax.random.dirichlet(ks[0], jnp.ones((V,)) * 0.5, (N,))
    q = jax.random.dirichlet(ks[1], jnp.ones((V,)) * 0.5, (N,))
    u = jax.random.uniform(ks[2], (N,))
    got = residual_sample_pallas(p, q, u, bv=bv, interpret=True)
    want = ref.residual_sample_ref(p, q, u)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_residual_sample_degenerate_rows():
    """p == q rows must fall back to argmax(p) (both impls)."""
    V = 512
    p = jax.random.dirichlet(jax.random.PRNGKey(5), jnp.ones((V,)), (3,))
    u = jnp.array([0.3, 0.6, 0.99])
    got = residual_sample_pallas(p, p, u, bv=256, interpret=True)
    want = ref.residual_sample_ref(p, p, u)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), np.argmax(np.asarray(p), -1))


def test_residual_sample_distribution():
    """Sampled tokens must follow normalize(max(p-q,0)) (chi^2-ish check)."""
    N, V = 4000, 16
    kp, kq, ku = jax.random.split(jax.random.PRNGKey(6), 3)
    p_row = jax.random.dirichlet(kp, jnp.ones((V,)))
    q_row = jax.random.dirichlet(kq, jnp.ones((V,)))
    p = jnp.tile(p_row, (N, 1))
    q = jnp.tile(q_row, (N, 1))
    u = jax.random.uniform(ku, (N,))
    got = np.asarray(residual_sample_pallas(p, q, u, bv=16, interpret=True))
    r = np.maximum(np.asarray(p_row) - np.asarray(q_row), 0)
    r = r / r.sum()
    freq = np.bincount(got, minlength=V) / N
    sigma = np.sqrt(r * (1 - r) / N)
    assert np.all(np.abs(freq - r) < 4 * sigma + 2e-3)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 64, 1, 64, 32),
    (1, 256, 8, 64, 2, 128, 64),    # grouped B/C, big state
    (2, 64, 2, 32, 2, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(b, s, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n), dtype)
    C = jax.random.normal(ks[4], (b, s, g, n), dtype)
    y_got, fs_got = ssd_scan_pallas(x, dt, A, B, C, chunk=chunk, interpret=True)
    y_want, fs_want = ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_got, np.float32),
                               np.asarray(y_want, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(fs_got), np.asarray(fs_want),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_scan_with_initial_state():
    b, s, h, p, g, n, chunk = 1, 64, 2, 32, 1, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(8), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    init = jax.random.normal(ks[5], (b, h, p, n))
    y_got, fs_got = ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                                    initial_state=init, interpret=True)
    y_want, fs_want = ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk,
                                       initial_state=init)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs_got), np.asarray(fs_want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------

def test_ops_interpret_mode_roundtrip(monkeypatch):
    """REPRO_KERNELS=interpret routes through Pallas interpret for every op."""
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    from repro.kernels import ops
    assert ops.kernel_mode() == "interpret"
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    got = ops.flash_attention(q, k, v)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# int8-quantized KV decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D", [(2, 384, 4, 2, 64), (1, 1024, 8, 8, 128)])
def test_decode_attention_q8_matches_ref(B, S, H, KV, D):
    from repro.kernels.decode_attention import decode_attention_q8_pallas

    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    kq, kscale, vq, vscale = ref.quantize_kv(k, v)
    got = decode_attention_q8_pallas(q, kq, vq, kscale, vscale, lengths,
                                     bs=128, interpret=True)
    want = ref.decode_attention_quantized_ref(q, kq, vq, kscale, vscale,
                                              lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_quantized_kv_close_to_exact():
    """int8 KV attention must stay close to the fp path (quantization noise
    only) — the §Perf int8-KV lever's accuracy budget."""
    ks = jax.random.split(jax.random.PRNGKey(12), 4)
    B, S, H, KV, D = 2, 256, 4, 4, 64
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    lengths = jnp.full((B,), S)
    kq, kscale, vq, vscale = ref.quantize_kv(k, v)
    exact = ref.decode_attention_ref(q, k, v, lengths)
    quant = ref.decode_attention_quantized_ref(q, kq, vq, kscale, vscale,
                                               lengths)
    err = np.abs(np.asarray(exact) - np.asarray(quant))
    assert err.max() < 0.05, err.max()


# ---------------------------------------------------------------------------
# fused verify + sample (accept test + residual fallback in one kernel)
# ---------------------------------------------------------------------------

def _fused_inputs(seed, B, L, V, vhat):
    """Valid speculative-verification inputs: drafts actually drawn from the
    uploaded truncated distribution, so acceptance rates are non-trivial."""
    from repro.core.verification import truncate_renormalize

    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    logits = jax.random.normal(ks[0], (B, L + 1, V)) * 2.0
    q = jax.nn.softmax(jax.random.normal(ks[1], (B, L, V)) * 2.0, axis=-1)
    idx, val = truncate_renormalize(q.reshape(B * L, V), vhat)
    idx = idx.reshape(B, L, vhat)
    val = val.reshape(B, L, vhat)
    j = jax.random.categorical(ks[2], jnp.log(jnp.maximum(val, 1e-30)))
    tokens = jnp.take_along_axis(idx, j[..., None], -1)[..., 0]
    probs = jnp.take_along_axis(val, j[..., None], -1)[..., 0]
    u_acc = jax.random.uniform(ks[3], (B, L))
    u_res = jax.random.uniform(ks[4], (B,))
    return logits, tokens, probs, idx, val, u_acc, u_res


@pytest.mark.parametrize("B,L,V,vhat,bv", [
    (2, 4, 512, 16, 256),
    (3, 3, 1000, 32, 512),     # vocab not a tile multiple
    (1, 6, 2048, 8, 2048),     # single row, whole vocab in one tile
])
@pytest.mark.parametrize("seed", [20, 21])
def test_fused_verify_sample_matches_ref(B, L, V, vhat, bv, seed):
    from repro.kernels.fused_verify_sample import fused_verify_sample_pallas

    logits, toks, probs, idx, val, u_acc, u_res = _fused_inputs(
        seed, B, L, V, vhat)
    dlen = jnp.full((B,), L, jnp.int32)
    got = fused_verify_sample_pallas(logits, toks, probs, idx, val, u_acc,
                                     u_res, dlen, bv=bv, interpret=True)
    want = ref.fused_verify_sample_ref(logits, toks, probs, idx, val, u_acc,
                                       u_res, dlen)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_fused_verify_sample_ragged_draft_len():
    """Rows past draft_len must not affect acceptance, and the calibrated
    token must come from position min(n_acc, draft_len - 1)'s residual."""
    from repro.kernels.fused_verify_sample import fused_verify_sample_pallas

    B, L, V, vhat = 3, 5, 640, 16
    logits, toks, probs, idx, val, u_acc, u_res = _fused_inputs(
        22, B, L, V, vhat)
    dlen = jnp.array([L, 2, 1], jnp.int32)
    got = fused_verify_sample_pallas(logits, toks, probs, idx, val, u_acc,
                                     u_res, dlen, bv=256, interpret=True)
    want = ref.fused_verify_sample_ref(logits, toks, probs, idx, val, u_acc,
                                       u_res, dlen)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # no acceptances beyond each row's draft length
    acc = np.asarray(got[0])
    for b, n in enumerate([L, 2, 1]):
        assert not acc[b, n:].any()


def test_fused_verify_sample_ops_dispatch(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    from repro.kernels import ops

    logits, toks, probs, idx, val, u_acc, u_res = _fused_inputs(
        23, 2, 4, 512, 16)
    got = ops.fused_verify_sample(logits, toks, probs, idx, val, u_acc, u_res)
    want = ref.fused_verify_sample_ref(
        logits, toks, probs, idx, val, u_acc, u_res,
        jnp.full((2,), 4, jnp.int32))
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# model-level attention dispatch (attention_apply kernel path vs jnp ref)
# ---------------------------------------------------------------------------

def _dispatch_model(seed=0):
    from repro.configs.base import ModelConfig
    from repro.models import build_model

    cfg = ModelConfig(name="disp", family="dense", vocab_size=128,
                      d_model=32, num_layers=2, num_heads=4, num_kv_heads=2,
                      head_dim=8, d_ff=64)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed)), cfg


def _tree_window(B, T=4):
    """Branching window: parents (-1, 0, 0, 1) -> ancestor-or-self mask."""
    parents = [-1, 0, 0, 1]
    wm = np.zeros((T, T), bool)
    depth = np.zeros((T,), np.int32)
    for t in range(T):
        a = t
        while a >= 0:
            wm[t, a] = True
            a = parents[a]
        p = parents[t]
        depth[t] = 0 if p < 0 else depth[p] + 1
    return (jnp.broadcast_to(jnp.asarray(wm), (B, T, T)),
            jnp.broadcast_to(jnp.asarray(depth), (B, T)))


@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("tree", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_dispatch_matches_ref(paged, tree, dtype, monkeypatch):
    """attention_apply's kernel dispatch (paged / tree / paged-tree and the
    causal-window prefill) must agree with the jnp reference path on the
    same cache layout."""
    model, params, cfg = _dispatch_model()
    B, M, T, ps = 2, 8, 4, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, M), 0,
                              cfg.vocab_size)
    win = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                             cfg.vocab_size)
    wm, depth = _tree_window(B, T) if tree else (None, None)

    def run(mode):
        monkeypatch.setenv("REPRO_KERNELS", mode)
        if paged:
            n_slots = (M + T) // ps + 1
            cache = model.init_paged_cache(B * n_slots, ps, dtype)
            cache["pages"] = jnp.arange(B * n_slots, dtype=jnp.int32) \
                .reshape(B, n_slots)
        else:
            cache = model.init_cache(B, M + T, dtype)
        lp, cache, _ = model.prefill(params, toks, cache)
        pos = jnp.full((B,), M, jnp.int32)
        lw, cache = model.forward_window(params, win, cache, pos,
                                         window_mask=wm, window_depth=depth)
        return lp, lw, cache

    ref_out = run("ref")
    ker_out = run("interpret")
    tol = _tol(dtype)
    for g, w in zip(ker_out[:2], ref_out[:2]):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), **tol)
    for leaf in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(ker_out[2][leaf], np.float32),
            np.asarray(ref_out[2][leaf], np.float32), **tol)
