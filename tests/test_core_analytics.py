"""Unit + property tests for the paper's analytic layer (Sec. II, IV, V)."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.bandwidth import solve_equalized_phi, solve_equalized_theta
from repro.core.channel import ChannelConfig, ChannelState
from repro.core.draft_control import (
    heterogeneous_lengths,
    optimal_uniform_length,
    solve_fixed,
    solve_heterogeneous,
    solve_homogeneous_exhaustive,
    solve_uniform_bandwidth,
)
from repro.core.goodput import (
    expected_accepted_tokens,
    goodput_homogeneous,
    multi_access_latency,
)
from repro.core.lambertw import lambert_w0, lambert_wm1

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# Lambert W
# ---------------------------------------------------------------------------

def test_lambertw_identity_w0():
    xs = np.concatenate([np.linspace(-np.exp(-1) + 1e-9, -1e-6, 500),
                         np.geomspace(1e-9, 1e9, 500)])
    w = lambert_w0(xs)
    np.testing.assert_allclose(w * np.exp(w), xs, rtol=1e-9, atol=1e-12)


def test_lambertw_identity_wm1():
    xs = -np.geomspace(1e-280, np.exp(-1) - 1e-9, 500)
    w = lambert_wm1(xs)
    np.testing.assert_allclose(w * np.exp(w), xs, rtol=1e-8)
    assert np.all(w <= -1.0 + 1e-9)


def test_lambertw_vs_scipy():
    scipy_special = pytest.importorskip("scipy.special")
    xs = np.linspace(-np.exp(-1) + 1e-9, 5.0, 1000)
    np.testing.assert_allclose(lambert_w0(xs), scipy_special.lambertw(xs, 0).real,
                               rtol=1e-9)
    # stay 1e-6 off the branch point: W has a sqrt singularity there, so the
    # achievable relative accuracy at distance d is O(sqrt(d)).
    xm = -np.geomspace(1e-200, np.exp(-1) - 1e-6, 1000)
    np.testing.assert_allclose(lambert_wm1(xm), scipy_special.lambertw(xm, -1).real,
                               rtol=1e-7)


def test_lambertw_domain_nan():
    assert np.isnan(lambert_w0(np.asarray(-1.0)))
    assert np.isnan(lambert_wm1(np.asarray(0.1)))
    assert np.isnan(lambert_wm1(np.asarray(-1.0)))


# ---------------------------------------------------------------------------
# Goodput model (eq. 12-17)
# ---------------------------------------------------------------------------

@given(st.floats(0.01, 0.999), st.integers(1, 100))
@settings(max_examples=60, deadline=None)
def test_expected_accepted_matches_pmf_sum(alpha, L):
    """E[N|L] from eq. 12 must equal the mean of the PMF in eq. 11."""
    ells = np.arange(1, L + 1)
    pmf = alpha ** (ells - 1) * (1 - alpha)
    mean = np.sum(ells * pmf) + (L + 1) * alpha ** L
    np.testing.assert_allclose(expected_accepted_tokens(alpha, L), mean, rtol=1e-9)


def test_expected_accepted_limits():
    np.testing.assert_allclose(expected_accepted_tokens(1.0 - 1e-15, 7), 8.0, rtol=1e-6)
    np.testing.assert_allclose(expected_accepted_tokens(1e-12, 7), 1.0, rtol=1e-6)


def test_multi_access_latency_straggler():
    # eq. 25: max over devices
    L = np.array([2, 10])
    T_S = np.array([0.01, 0.02])
    B = np.array([1e6, 1e6])
    r = np.array([5.0, 5.0])
    t = multi_access_latency(L, T_S, 34816.0, B, r)
    per_tok = T_S + 34816.0 / (B * r)
    assert t == pytest.approx(10 * per_tok[1])


# ---------------------------------------------------------------------------
# Lemma 1 (bandwidth allocation, uniform regime)
# ---------------------------------------------------------------------------

@given(st.integers(2, 24), st.floats(1e6, 50e6))
@settings(max_examples=40, deadline=None)
def test_lemma1_equalizes_and_exhausts(K, B):
    rng = np.random.default_rng(K)
    T_S = rng.uniform(0.01, 0.05, K)
    r = rng.uniform(3.0, 8.0, K)
    Q = 34816.0
    theta, B_star = solve_equalized_theta(T_S, r, Q, B)
    assert np.all(B_star > 0)
    np.testing.assert_allclose(np.sum(B_star), B, rtol=1e-9)
    lat = T_S + Q / (B_star * r)
    np.testing.assert_allclose(lat, theta, rtol=1e-9)
    assert theta > np.max(T_S)


def test_lemma1_theta_decreases_with_bandwidth():
    T_S = np.array([0.02, 0.03, 0.025])
    r = np.array([5.0, 4.0, 6.0])
    thetas = [float(solve_equalized_theta(T_S, r, 34816.0, B)[0])
              for B in [5e6, 10e6, 20e6, 40e6]]
    assert all(a > b for a, b in zip(thetas, thetas[1:]))


def test_lemma1_weak_devices_get_more_bandwidth():
    """Paper insight: uniform regime compensates weaker C2 capabilities."""
    T_S = np.array([0.02, 0.04])   # device 1 slower compute
    r = np.array([5.0, 5.0])
    _, B_star = solve_equalized_theta(T_S, r, 34816.0, 10e6)
    assert B_star[1] > B_star[0]
    # Weaker channel also compensated
    T_S2 = np.array([0.02, 0.02])
    r2 = np.array([6.0, 3.0])
    _, B2 = solve_equalized_theta(T_S2, r2, 34816.0, 10e6)
    assert B2[1] > B2[0]


# ---------------------------------------------------------------------------
# Theorem 1 (optimal uniform draft length)
# ---------------------------------------------------------------------------

@given(st.floats(0.05, 0.98), st.floats(0.001, 0.2), st.floats(0.001, 0.5))
@settings(max_examples=100, deadline=None)
def test_theorem1_matches_bruteforce(alpha, theta, T_ver):
    L_star, L_tilde = optimal_uniform_length(alpha, theta, T_ver)
    Ls = np.arange(1, 3000)
    taus = goodput_homogeneous(alpha, Ls, theta, T_ver, K=1)
    brute = Ls[int(np.argmax(taus))]
    assert int(L_star) == brute


def test_theorem1_boundary_case():
    # T_ver/theta below the threshold => L* = 1
    alpha = 0.5
    thresh = (1 - alpha) / (alpha * abs(np.log(alpha)))
    L_star, _ = optimal_uniform_length(alpha, theta=1.0, T_ver=0.5 * thresh)
    assert int(L_star) == 1


def test_theorem1_monotonicity():
    """Remark 1: L* grows with T_ver and alpha, shrinks with theta."""
    base = dict(alpha=0.8, theta=0.02, T_ver=0.1)
    L0 = float(optimal_uniform_length(**base)[1])
    assert float(optimal_uniform_length(0.8, 0.02, 0.4)[1]) > L0
    assert float(optimal_uniform_length(0.95, 0.02, 0.1)[1]) > L0
    assert float(optimal_uniform_length(0.8, 0.08, 0.1)[1]) < L0


def test_theorem1_alpha_to_one_scaling():
    """Remark 1: L~* + 1 ~ sqrt(2(t-1)/(-ln alpha)) as alpha -> 1."""
    theta, T_ver = 0.02, 0.1
    t = T_ver / theta
    for alpha in [0.999, 0.9999]:
        L_t = float(optimal_uniform_length(alpha, theta, T_ver)[1])
        pred = np.sqrt(2 * (t - 1) / (-np.log(alpha)))
        assert abs((L_t + 1) / pred - 1) < 0.15


# ---------------------------------------------------------------------------
# Lemma 3 (bandwidth under heterogeneous lengths)
# ---------------------------------------------------------------------------

@given(st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_lemma3_equalizes(K):
    rng = np.random.default_rng(K + 100)
    T_S = rng.uniform(0.01, 0.05, K)
    r = rng.uniform(3.0, 8.0, K)
    L = rng.integers(1, 20, K).astype(float)
    Q, B = 34816.0, 10e6
    phi, B_of_L = solve_equalized_phi(L, T_S, r, Q, B)
    np.testing.assert_allclose(np.sum(B_of_L), B, rtol=1e-9)
    lat = L * (T_S + Q / (B_of_L * r))
    np.testing.assert_allclose(lat, phi, rtol=1e-9)
    assert phi > np.max(L * T_S)


def test_lemma3_phi_increases_with_length():
    T_S = np.array([0.02, 0.03])
    r = np.array([5.0, 4.0])
    L1 = np.array([5.0, 5.0])
    L2 = np.array([5.0, 9.0])
    phi1, B1 = solve_equalized_phi(L1, T_S, r, 34816.0, 10e6)
    phi2, B2 = solve_equalized_phi(L2, T_S, r, 34816.0, 10e6)
    assert phi2 > phi1
    assert B2[1] > B1[1]  # longer draft needs more bandwidth


# ---------------------------------------------------------------------------
# Proposition 1 (KKT stationarity of eq. 33)
# ---------------------------------------------------------------------------

@given(st.floats(0.3, 0.97), st.floats(0.005, 0.05), st.floats(2.0, 8.0),
       st.floats(0.05, 2.0), st.floats(1e-7, 1e-2))
@settings(max_examples=100, deadline=None)
def test_prop1_satisfies_kkt_stationarity(alpha, T_S, r, phi, lam):
    """eq. 33 must solve: -a^(L+1) ln a/(1-a) = lam*Q*phi/(r*(phi - L*T)^2)."""
    Q = 34816.0
    L = float(heterogeneous_lengths(phi, lam, np.array([alpha]),
                                    np.array([T_S]), np.array([r]), Q)[0])
    if not np.isfinite(L) or L <= 0 or L >= phi / T_S:
        return  # outside the interior region; nothing to check
    lhs = -(alpha ** (L + 1)) * np.log(alpha) / (1 - alpha)
    rhs = lam * Q * phi / (r * (phi - L * T_S) ** 2)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-6)


# ---------------------------------------------------------------------------
# Algorithm 1 and baseline orderings (Figs. 6-8 structure)
# ---------------------------------------------------------------------------

def _random_system(K, seed=0):
    rng = np.random.default_rng(seed)
    alphas = rng.choice([0.71, 0.74, 0.74, 0.86], K)
    T_S = rng.uniform(0.85, 1.15, K) * 0.03
    r = rng.uniform(4.0, 7.0, K)
    return alphas, T_S, r


@pytest.mark.parametrize("K,seed", [(4, 0), (8, 1), (20, 2)])
def test_hete_beats_homo_beats_fixed(K, seed):
    alphas, T_S, r = _random_system(K, seed)
    Q, B, T_ver = 34816.0, 10e6, 0.03 + K * 0.002
    hete = solve_heterogeneous(alphas, T_S, r, Q, B, T_ver, L_max=25)
    homo = solve_homogeneous_exhaustive(alphas, T_S, r, Q, B, T_ver, L_max=25)
    fixed = solve_fixed(alphas, T_S, r, Q, B, T_ver)
    assert hete.goodput >= homo.goodput * (1 - 1e-6)
    assert homo.goodput >= fixed.goodput * (1 - 1e-6)


def test_unibw_beats_fixed():
    alphas, T_S, r = _random_system(12, 3)
    Q, B, T_ver = 34816.0, 10e6, 0.054
    uni = solve_uniform_bandwidth(alphas, T_S, r, Q, B, T_ver, L_max=25)
    fixed = solve_fixed(alphas, T_S, r, Q, B, T_ver)
    assert uni.goodput >= fixed.goodput * (1 - 1e-6)


def test_algorithm1_near_bruteforce_k2():
    """For K=2 the MINLP is brute-forceable: Algorithm 1 must come close."""
    alphas = np.array([0.74, 0.93])
    T_S = np.array([0.03, 0.025])
    r = np.array([5.0, 6.5])
    Q, B, T_ver, L_max = 34816.0, 4e6, 0.06, 25
    best = -np.inf
    for l1 in range(1, L_max + 1):
        for l2 in range(1, L_max + 1):
            L = np.array([l1, l2], dtype=float)
            phi, _ = solve_equalized_phi(L, T_S, r, Q, B)
            tau = float(np.sum(expected_accepted_tokens(alphas, L)) / (phi + T_ver))
            best = max(best, tau)
    sol = solve_heterogeneous(alphas, T_S, r, Q, B, T_ver, L_max=L_max,
                              n_phi=60, n_lam=60)
    assert sol.goodput >= 0.97 * best


def test_remark2_bandwidth_rewards_high_alpha():
    """Heterogeneous regime: higher acceptance rate => more bandwidth.

    Exhibited in the communication-dominated regime (small B): with identical
    compute and channels, the high-alpha device must get longer drafts AND a
    larger bandwidth share (verified against 2-device brute force separately).
    """
    alphas = np.array([0.6, 0.95])
    T_S = np.array([0.005, 0.005])   # identical compute
    r = np.array([5.0, 5.0])         # identical channel
    sol = solve_heterogeneous(alphas, T_S, r, 34816.0, 1e6, 0.06, L_max=25,
                              n_phi=60, n_lam=60)
    assert sol.lengths[1] > sol.lengths[0]
    assert sol.bandwidth[1] > sol.bandwidth[0]


# ---------------------------------------------------------------------------
# Channel model
# ---------------------------------------------------------------------------

def test_channel_q_tok_default():
    cfg = ChannelConfig()
    # |V^hat| (Q_B + ceil(log2 32000)) = 1024 * (16 + 15) = 31744
    assert cfg.q_tok_bits == 1024 * (16 + 15)


def test_channel_snr_range():
    cfg = ChannelConfig()
    rng = np.random.default_rng(0)
    st_ = ChannelState.sample(cfg, 1000, rng)
    snr_db = 10 * np.log10(cfg.power_psd * st_.avg_gains / cfg.noise_psd)
    assert snr_db.min() >= cfg.snr_lo_db - 1e-6
    assert snr_db.max() <= cfg.snr_hi_db + 1e-6
    assert np.all(st_.rates > 0)


def test_channel_rate_independent_of_bandwidth_split():
    """Constant-PSD transmission: spectrum efficiency is bandwidth-free."""
    cfg = ChannelConfig()
    rng = np.random.default_rng(1)
    s = ChannelState.sample(cfg, 4, rng)
    R1 = s.uplink_rate_bps(np.full(4, cfg.total_bandwidth_hz / 4))
    R2 = s.uplink_rate_bps(np.full(4, cfg.total_bandwidth_hz / 8))
    np.testing.assert_allclose(R1 / R2, 2.0)
