"""Continuous-batching engine tests: per-stream state machines, batch
assembly, forced-barrier bit-identity, and churn safety.

The heavy rows share one smoke-scale paged ``SpecEngine`` configuration;
the FSM/assembler/scheduler tests are pure-host and fast.
"""

import asyncio

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.backends import ContinuousBackend
from repro.serving.cell import CellConfig, MultiSpinCell
from repro.serving.continuous import (
    COMMITTING,
    DRAFTING,
    FINISHED,
    PHASES,
    READY,
    RETIRED,
    VERIFYING,
    BatchAssembler,
    ContinuousEngine,
    IllegalTransition,
    StreamFSM,
)
from repro.serving.scheduler import Request, RoundScheduler
from repro.serving.spec_engine import SpecEngine


def _engine(B=3, max_len=96, seed=0):
    tcfg = get_config("qwen2.5-3b").smoke()
    dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2,
                        num_kv_heads=1, head_dim=16, d_ff=64,
                        name="draft-smoke")
    eng = SpecEngine(tcfg, dcfg, max_len=max_len, cache_kind="paged",
                     num_pages=B * 2 * (max_len // 16))
    eng.init_params(jax.random.PRNGKey(seed))
    return eng, tcfg


def _prompts(tcfg, B=3, M=10, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, M), 0,
                              tcfg.vocab_size)


# ---------------------------------------------------------------------------
# state machine
# ---------------------------------------------------------------------------

def test_fsm_legal_round_cycle():
    f = StreamFSM(row=0)
    for phase in (READY, VERIFYING, COMMITTING, DRAFTING,
                  READY, VERIFYING, COMMITTING, FINISHED, RETIRED):
        f.to(phase)
    assert not f.live


def test_fsm_illegal_transitions_raise():
    illegal = [
        (DRAFTING, VERIFYING), (DRAFTING, COMMITTING), (DRAFTING, FINISHED),
        (READY, DRAFTING), (READY, COMMITTING),
        (VERIFYING, READY), (VERIFYING, DRAFTING), (VERIFYING, FINISHED),
        (COMMITTING, READY), (COMMITTING, VERIFYING),
        (FINISHED, DRAFTING), (RETIRED, DRAFTING),
    ]
    for src, dst in illegal:
        f = StreamFSM(row=0, phase=src)
        with pytest.raises(IllegalTransition):
            f.to(dst)


def test_fsm_retire_legal_from_every_live_phase():
    for src in PHASES:
        f = StreamFSM(row=0, phase=src)
        if src == RETIRED:
            with pytest.raises(IllegalTransition):
                f.to(RETIRED)
        else:
            assert f.to(RETIRED).phase == RETIRED


# ---------------------------------------------------------------------------
# batch assembler (shape bucketing — the prefill-bucketing idiom)
# ---------------------------------------------------------------------------

def test_assembler_retrace_bound_over_churny_ready_sets():
    """12 distinct (K, L) ready-set shapes must collapse to the pow2 bucket
    grid, and the trace hook must fire once per NEW shape only."""
    asm = BatchAssembler(max_batch=8)
    traced = []
    asm.on_assemble_trace = traced.append
    ready_sets = [(k, ln) for k in (1, 2, 3, 5) for ln in (3, 4, 6)]
    assert len(ready_sets) == 12
    for k, ln in ready_sets:
        asm.assemble([(object(), ln)] * k)
    # buckets: K in {1,2,4,8} x L in {4,8} -> at most 8 dispatch shapes
    assert len(asm.shapes) <= 8 < len(ready_sets)
    assert len(traced) == len(asm.shapes)      # one trace per new shape
    assert all(s[0] in (1, 2, 4, 8) and s[1] in (4, 8) for s in asm.shapes)
    # replaying the same churn adds no shapes and no traces
    for k, ln in ready_sets:
        asm.assemble([(object(), ln)] * k)
    assert len(traced) == len(asm.shapes)


def test_assembler_exact_mode_and_max_batch_split():
    asm = BatchAssembler(max_batch=2, exact=True)
    batches = asm.assemble([(i, 3) for i in range(5)])
    assert [len(b) for b in batches] == [2, 2, 1]
    assert (2, 3) in asm.shapes and (1, 3) in asm.shapes


# ---------------------------------------------------------------------------
# forced-barrier bit-identity (the correctness anchor)
# ---------------------------------------------------------------------------

def test_forced_barrier_bit_identical_to_lockstep():
    B, M, L, R = 3, 10, 4, 4
    base = jax.random.PRNGKey(42)
    eng1, tcfg = _engine(B=B)
    prompts = _prompts(tcfg, B=B, M=M)
    st1 = eng1.start(prompts)
    for r in range(R):
        st1, _, _ = eng1.spin_round(st1, np.full(B, L),
                                    jax.random.fold_in(base, r))

    eng2, _ = _engine(B=B)
    cont = ContinuousEngine(eng2, eng2.start(prompts), base,
                            max_inflight=1, exact_shapes=True)
    for b in range(B):
        cont.add_stream(b, length=L)
    for _ in range(R):
        cont.step()

    for b in range(B):
        assert st1.committed[b] == cont.state.committed[b], \
            f"stream {b} diverged under the forced barrier"
    # a single dispatch shape: the barrier config never rebuckets
    assert cont.assembler.shapes == {(B, L)}


def test_overlapped_mode_commits_and_respects_budgets():
    eng, tcfg = _engine(B=4)
    prompts = _prompts(tcfg, B=4, M=10)
    cont = ContinuousEngine(eng, eng.start(prompts), jax.random.PRNGKey(7),
                            max_inflight=2)
    for b in range(4):
        cont.add_stream(b, length=3 + (b % 2), budget=8)
    cont.drain()
    for f in cont.fsm.values():
        assert f.phase == FINISHED and f.generated >= 8
    assert cont.commits and all(ev.occupancy > 0 for ev in cont.commits)
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()


# ---------------------------------------------------------------------------
# churn: retire from every phase returns pages; mid-verify disconnect
# ---------------------------------------------------------------------------

def test_retire_returns_pages_from_every_phase():
    eng, tcfg = _engine(B=3)
    prompts = _prompts(tcfg, B=3, M=10)
    cont = ContinuousEngine(eng, eng.start(prompts), jax.random.PRNGKey(3),
                            max_inflight=2)
    fsms = [cont.add_stream(b, length=3) for b in range(3)]
    # row 0: retire straight from DRAFTING
    assert fsms[0].phase == DRAFTING
    used_before = eng.t_pages.num_allocated_pages
    cont.retire(0)
    assert eng.t_pages.num_allocated_pages < used_before
    # rows 1-2: drive to READY then VERIFYING, retiring one at each phase
    cont._dispatch_draft_group([fsms[1], fsms[2]], np.array([3, 3]))
    assert fsms[1].phase == READY
    used_before = eng.t_pages.num_allocated_pages
    cont.retire(1)
    assert fsms[1].phase == RETIRED
    assert eng.t_pages.num_allocated_pages < used_before
    cont._dispatch_verify([fsms[2]])
    assert fsms[2].phase == VERIFYING
    used_before = eng.t_pages.num_allocated_pages
    cont.retire(2)
    assert eng.t_pages.num_allocated_pages < used_before
    # the in-flight batch still lands without corruption
    cont._commit_batch(cont._inflight.popleft())
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()
    assert eng.t_pages.num_allocated_pages == 0


def test_mid_verify_disconnect_does_not_corrupt_batch():
    """A stream retired while its batch is in flight commits nothing and
    returns its pages immediately; the other members commit normally."""
    eng, tcfg = _engine(B=3)
    prompts = _prompts(tcfg, B=3, M=10)
    cont = ContinuousEngine(eng, eng.start(prompts), jax.random.PRNGKey(5),
                            max_inflight=2)
    handle = cont.dispatch_round([0, 1, 2], np.array([3, 3, 3]))
    cont.retire(1)                        # disconnect mid-verify
    acc = cont.commit(handle)
    assert acc[1] == 0
    assert acc[0] >= 1 and acc[2] >= 1
    # survivors' streams advanced; the retired row did not
    assert len(cont.state.committed[0]) > 10
    assert len(cont.state.committed[1]) == 10
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()
    # the retired row is recyclable and a second retire is a no-op
    cont.retire(1)


def test_continuous_backend_serves_cell_with_churn():
    """End-to-end: ContinuousBackend under schedule='continuous' with a
    mid-session leave, against a real paged engine."""
    eng, tcfg = _engine(B=4, max_len=96)
    be = ContinuousBackend(eng, eng.start(_prompts(tcfg, B=4, M=8)),
                           max_inflight=2)
    cfg = CellConfig(scheme="fixed", L_fixed=4, L_max=8, max_batch=4,
                     schedule="continuous", seed=0)
    cell = MultiSpinCell(cfg, backend=be)
    rng = np.random.default_rng(9)
    for i in range(5):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=8,
                            alpha=0.8, T_S=float(rng.choice([0.004, 0.03]))))
    cell.step()
    cell.step()
    # one device disconnects mid-session
    gone = cell.scheduler.active[0].rid
    cell.leave(gone)
    summary = cell.drain()
    assert cell.scheduler.stats.completed >= 4
    assert summary["tokens"] > 0
    assert all(r.batch_occupancy is not None and r.ready_depth is not None
               for r in cell.history)
    eng.t_pages.check_invariants()


# ---------------------------------------------------------------------------
# cell-level continuous schedule (synthetic, fast)
# ---------------------------------------------------------------------------

def test_continuous_schedule_synthetic_drains_and_records():
    cfg = CellConfig(scheme="hete", max_batch=4, schedule="continuous",
                     seed=0)
    cell = MultiSpinCell(cfg)
    rng = np.random.default_rng(2)
    for i in range(6):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=16,
                            alpha=0.8, T_S=float(rng.choice([0.004, 0.03]))))
    summary = cell.drain()
    assert cell.scheduler.stats.completed == 6
    assert summary["tokens"] > 0 and summary["goodput"] > 0
    # per-batch records: occupancy in (0, 1], monotone non-negative gaps
    for r in cell.history:
        assert 0 < r.batch_occupancy <= 1
        assert r.t_round >= 0
        assert r.queue_depth is not None
    # summary wall-clock telescopes to at least the last commit time
    assert summary["seconds"] >= cell._cont_last_commit - 1e-9


def test_continuous_config_validation():
    with pytest.raises(ValueError, match="server"):
        CellConfig(scheme="cen", max_batch=1, schedule="continuous")
    with pytest.raises(ValueError, match="multi-draft"):
        CellConfig(scheme="multidraft", schedule="continuous")
    with pytest.raises(ValueError, match="deadline"):
        CellConfig(scheme="hete", schedule="continuous", deadline_factor=2.0)
    with pytest.raises(ValueError, match="max_inflight"):
        CellConfig(scheme="hete", max_inflight=0)


# ---------------------------------------------------------------------------
# scheduler satellites: post-admission queue depth + head-of-line metric
# ---------------------------------------------------------------------------

def test_round_record_reports_post_admission_queue_depth():
    cfg = CellConfig(scheme="fixed", max_batch=2, seed=0)
    cell = MultiSpinCell(cfg)
    for i in range(5):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=64,
                            alpha=0.8, T_S=0.009))
    rec = cell.step()
    # 2 admitted, 3 queued: the record must carry the POST-admission depth
    assert rec.queue_depth == 3
    assert rec.queue_depth == len(cell.scheduler.queue)


def test_scheduler_hol_wait_tracks_blocked_servable_head():
    s = RoundScheduler(max_batch=1)
    s.submit(Request(rid=0, prompt_len=8, max_new_tokens=64))
    s.submit(Request(rid=1, prompt_len=8, max_new_tokens=64))
    s.admit()
    assert s.stats.hol_wait_max == 0.0      # head blocked but no time passed
    s.clock = 3.5
    s.admit()
    assert s.stats.hol_wait_max == pytest.approx(3.5)
    s.clock = 5.0
    s.admit()
    assert s.stats.hol_wait_max == pytest.approx(5.0)
    # head admitted -> empty queue contributes nothing further
    s.active.clear()
    s.admit()
    assert s.stats.hol_wait_max == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# closed-loop loadgen satellite
# ---------------------------------------------------------------------------

def test_loadgen_closed_loop_concurrent_clients():
    from repro.serving.gateway import (
        GatewayConfig,
        LoadGenConfig,
        MultiSpinGateway,
        run_loadgen,
    )

    async def run():
        cfg = CellConfig(scheme="hete", max_batch=4, schedule="continuous",
                         seed=0)
        gw = MultiSpinGateway(MultiSpinCell(cfg),
                              GatewayConfig(port=0, idle_wait_s=0.02))
        await gw.start()
        try:
            return await run_loadgen(
                "127.0.0.1", gw.port,
                LoadGenConfig(mode="closed", n_clients=3, think_time_s=0.005,
                              n_requests=7, max_new_tokens_choices=(4, 8),
                              seed=0))
        finally:
            await gw.stop()

    report = asyncio.run(run())
    assert report["mode"] == "closed" and report["n_clients"] == 3
    assert report["n_error"] == 0
    assert report["n_ok"] == 7
    assert report["tokens"] > 0
    # every request produced a TTFT and the sample is complete
    assert report["ttft_s"]["n"] == 7


def test_loadgen_rejects_unknown_mode():
    from repro.serving.gateway import LoadGenConfig, run_loadgen

    with pytest.raises(ValueError, match="mode"):
        asyncio.run(run_loadgen("127.0.0.1", 1,
                                LoadGenConfig(mode="burst")))
