"""Tests for the MultiSpinCell session API, scheme registry, and pluggable
verification backends."""

import dataclasses

import numpy as np
import pytest

from repro.api import (
    CellConfig,
    ChannelConfig,
    MultiSpinCell,
    MultiSpinController,
    Request,
    RoundPlan,
    SyntheticBackend,
    VerificationLatencyModel,
    available_schemes,
    build_scheme,
    get_scheme,
)
from repro.core.controller import SCHEMES, AcceptanceEstimator


def _req(rid, alpha=0.8, T_S=0.01, max_new_tokens=10 ** 9, task=""):
    return Request(rid=rid, prompt_len=8, max_new_tokens=max_new_tokens,
                   alpha=alpha, T_S=T_S, task=task)


def _cell(scheme="hete", K=4, seed=0, **cfg_kw):
    cfg = CellConfig(scheme=scheme, max_batch=K, seed=seed, **cfg_kw)
    cell = MultiSpinCell(cfg)
    rng = np.random.default_rng(seed)
    for i in range(K):
        cell.submit(_req(i, alpha=float(rng.choice([0.71, 0.74, 0.86])),
                         T_S=0.009 * float(rng.uniform(0.85, 1.15))))
    return cell


# ---------------------------------------------------------------------------
# CellConfig
# ---------------------------------------------------------------------------

def test_cellconfig_json_round_trip():
    cfg = CellConfig(scheme="hete-packed",
                     channel=ChannelConfig(total_bandwidth_hz=2e6,
                                           vocab_size=151936),
                     t_ver_fix=0.05, t_ver_lin=0.01, L_max=12, L_fixed=5,
                     max_batch=7, use_estimator=True, deadline_factor=1.5,
                     schedule="pipelined", seed=3)
    back = CellConfig.from_json(cfg.to_json())
    assert back == cfg
    assert isinstance(back.channel, ChannelConfig)
    assert back.channel.total_bandwidth_hz == 2e6


def test_cellconfig_rejects_unknown_scheme_and_schedule():
    with pytest.raises(ValueError):
        CellConfig(scheme="nope")
    with pytest.raises(ValueError):
        CellConfig(schedule="nope")


def _nondefault_params(scheme: str) -> dict:
    """One non-default value per declared parameter, so the round trip
    actually carries information."""
    import dataclasses
    out = {}
    for f in dataclasses.fields(get_scheme(scheme).Params):
        if isinstance(f.default, bool):
            out[f.name] = not f.default
        elif isinstance(f.default, int):
            out[f.name] = f.default + 1
        elif isinstance(f.default, float):
            out[f.name] = f.default * 0.5
    return out


@pytest.mark.parametrize("scheme", sorted(available_schemes()))
def test_cellconfig_json_round_trip_every_scheme(scheme):
    """to_json/from_json must round-trip scheme_params for every registered
    scheme (satellite: the config is the serialized deployment surface)."""
    caps = get_scheme(scheme).capabilities
    cfg = CellConfig(scheme=scheme, scheme_params=_nondefault_params(scheme),
                     max_batch=1 if caps.single_user_only else 8,
                     t_draft_fix=0.004, t_draft_lin=0.009)
    back = CellConfig.from_json(cfg.to_json())
    assert back == cfg
    assert back.scheme_params == cfg.scheme_params


def test_cellconfig_rejects_unknown_scheme_param():
    with pytest.raises(ValueError, match="L_fixed"):
        CellConfig(scheme="fixed", scheme_params={"bogus": 3})


def test_p2p_cell_with_multiple_devices_raises_clear_error():
    """Capability enforcement: P2P is single-user, so a multi-device cell
    must fail loudly at CONFIG time, not mid-session."""
    with pytest.raises(ValueError, match="single-user"):
        CellConfig(scheme="p2p", max_batch=4)
    # ... and the scheme itself refuses a multi-device observation
    from repro.api import SchemeCapabilityError
    ctrl = MultiSpinController(
        scheme="p2p", q_tok_bits=31744.0, bandwidth_hz=10e6,
        t_ver_model=VerificationLatencyModel(0.035, 0.0177))
    with pytest.raises(SchemeCapabilityError, match="single-user"):
        ctrl.plan(np.array([0.8, 0.8]), np.array([0.01, 0.01]),
                  np.array([5.0, 5.0]))


# ---------------------------------------------------------------------------
# Scheme registry
# ---------------------------------------------------------------------------

ALL_SCHEMES = {"hete", "homo", "uni-bw", "fixed", "hete-packed",
               "hete-padded-tokenbudget", "cen", "p2p", "multidraft"}


def test_registry_lists_all_schemes():
    assert set(available_schemes()) == ALL_SCHEMES
    # the controller's legacy SCHEMES tuple is derived, so it cannot drift
    assert set(SCHEMES) == set(available_schemes())


@pytest.mark.parametrize("scheme", sorted(ALL_SCHEMES))
def test_registry_matches_controller_dispatch(scheme):
    """controller.plan == building the registered scheme and planning the
    controller's own observation directly."""
    rng = np.random.default_rng(0)
    K = 1 if get_scheme(scheme).capabilities.single_user_only else 6
    alphas = rng.choice([0.71, 0.74, 0.86], K)
    T_S = rng.uniform(0.85, 1.15, K) * 0.009
    rates = rng.uniform(4.0, 8.0, K)
    ctrl = MultiSpinController(
        scheme=scheme, q_tok_bits=31744.0, bandwidth_hz=10e6,
        t_ver_model=VerificationLatencyModel(0.035, 0.0177),
        t_draft_model=VerificationLatencyModel(0.005, 0.01), L_max=12)
    via_plan = ctrl.plan(alphas, T_S, rates)
    direct = build_scheme(scheme).plan(ctrl.observe(alphas, T_S, rates))
    assert isinstance(via_plan, RoundPlan)
    np.testing.assert_array_equal(via_plan.lengths, direct.lengths)
    np.testing.assert_allclose(via_plan.bandwidth, direct.bandwidth)
    assert via_plan.goodput == pytest.approx(direct.goodput)
    assert via_plan.draft_width == direct.draft_width
    assert via_plan.verification_mode == direct.verification_mode


def test_unknown_scheme_raises_with_choices():
    with pytest.raises(KeyError, match="hete"):
        get_scheme("does-not-exist")


# ---------------------------------------------------------------------------
# Session lifecycle
# ---------------------------------------------------------------------------

def test_cell_submit_step_retire_refill():
    cfg = CellConfig(scheme="fixed", L_fixed=4, max_batch=2, seed=0)
    cell = MultiSpinCell(cfg)
    for i in range(5):
        cell.submit(_req(i, max_new_tokens=8))
    rec = cell.step()
    assert len(rec.lengths) == 2                      # batch capped
    total_rounds = 1
    while cell.step() is not None:
        total_rounds += 1
        assert total_rounds < 100
    assert cell.idle
    assert cell.step() is None                        # idle cell no-ops
    s = cell.scheduler.stats
    assert s.completed == 5
    assert s.total_tokens == 5 * 8                    # capped per request
    assert s.goodput > 0
    # channel/estimator rows track the active set down to empty
    assert len(cell.avg_gains) == 0


def test_cell_replans_on_join_and_leave():
    cell = _cell(K=3, scheme="fixed")
    r1 = cell.step()
    assert len(r1.lengths) == 3
    # a fourth device joins mid-session: next round plans for 4 (the legacy
    # protocol froze its device list at construction)
    cfg_batch = cell.config.max_batch
    cell.config.max_batch = 4        # single source of truth for capacity
    cell.submit(_req(99))
    r2 = cell.step()
    assert len(r2.lengths) == 4
    assert 99 in set(r2.rids.tolist())
    assert len(cell.avg_gains) == 4
    # a device drops: survivors re-planned, channel rows pruned
    cell.leave(99)
    r3 = cell.step()
    assert len(r3.lengths) == 3
    assert 99 not in set(r3.rids.tolist())
    assert len(cell.avg_gains) == 3
    cell.config.max_batch = cfg_batch
    with pytest.raises(KeyError):
        cell.leave(1234)


def test_cell_checkpoint_restore():
    cell = _cell(K=5, use_estimator=True)
    cell.run(5)
    snap = cell.state_dict()
    cell2 = _cell(K=5, use_estimator=True)
    cell2.load_state_dict(snap)
    assert cell2._round_idx == 5
    np.testing.assert_allclose(cell2.avg_gains, cell.avg_gains)
    np.testing.assert_allclose(cell2.estimator.alpha_hat,
                               cell.estimator.alpha_hat)


def test_cell_summary_and_predicted_goodput_agree():
    cell = _cell(K=8)
    out = cell.run(30)
    assert out["tokens"] > 0
    assert abs(out["goodput"] - out["mean_predicted_goodput"]) \
        / out["mean_predicted_goodput"] < 0.3


# ---------------------------------------------------------------------------
# Estimator feedback (satellite: masked update)
# ---------------------------------------------------------------------------

def test_estimator_update_masks_inactive_devices():
    est = AcceptanceEstimator(3)
    before = est.alpha_hat.copy()
    est.update(np.array([0, 3, 1]), np.array([4, 4, 4]),
               mask=np.array([False, True, True]))
    after = est.alpha_hat
    # deadline-dropped device 0 (accepted=0 because it was SKIPPED, not
    # rejected) keeps its prior; participants move
    assert after[0] == pytest.approx(before[0])
    assert after[1] != pytest.approx(before[1])
    assert after[2] != pytest.approx(before[2])


def test_deadline_dropped_devices_do_not_bias_estimator():
    """A device that misses every deadline must keep alpha_hat at its prior
    instead of being dragged toward zero by phantom rejections."""
    cfg = CellConfig(scheme="fixed", L_fixed=6, max_batch=4,
                     use_estimator=True, deadline_factor=1.01, seed=0)
    cell = MultiSpinCell(cfg)
    # device 3 is a 100x straggler: always dropped by the deadline
    for i in range(4):
        cell.submit(_req(i, alpha=0.9, T_S=0.01 * (100.0 if i == 3 else 1.0)))
    cell.admit()                 # provision estimator rows
    prior = cell.estimator.alpha_hat.copy()
    dropped_rounds = 0
    for _ in range(25):
        rec = cell.step()
        dropped_rounds += int(~rec.active[3])
    assert dropped_rounds > 0
    assert cell.estimator.alpha_hat[3] == pytest.approx(prior[3])
    # participating devices' estimates moved off the prior
    assert abs(cell.estimator.alpha_hat[0] - prior[0]) > 1e-3


# ---------------------------------------------------------------------------
# Pipelined schedule through the cell (backend-agnostic fold of the legacy
# synthetic-only run_pipelined fork)
# ---------------------------------------------------------------------------

def test_pipelined_schedule_beats_sync_goodput():
    sync = _cell(K=12, seed=1).run(40)
    pipe_cell = _cell(K=12, seed=1, schedule="pipelined")
    piped = pipe_cell.run(80)
    assert piped["goodput"] > sync["goodput"]
    # drain: trailing in-flight verification is charged to wall-clock
    assert piped["seconds"] > sum(r.t_round for r in pipe_cell.history)


def test_pipelined_alternates_halves():
    cell = _cell(K=6, schedule="pipelined")
    r1, r2 = cell.step(), cell.step()
    assert r1.active.sum() == 3 and r2.active.sum() == 3
    assert not np.any(r1.active & r2.active)          # disjoint halves
    assert np.all(r1.accepted[~r1.active] == 0)


# ---------------------------------------------------------------------------
# Backend parity (synthetic vs real engine accounting)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_backend_parity_accepted_token_accounting():
    """With a self-drafting engine (alpha == 1) and a synthetic cell at
    alpha == 1, both backends must account exactly L+1 accepted tokens per
    device per round through the identical cell loop."""
    jax = pytest.importorskip("jax")
    from repro.api import EngineBackend, SpecEngine
    from repro.configs import get_config

    tcfg = get_config("qwen2.5-3b").smoke()
    eng = SpecEngine(tcfg, tcfg, max_len=96)
    kt, _ = jax.random.split(jax.random.PRNGKey(0))
    eng.t_params = eng.target.init(kt)
    eng.d_params = eng.t_params          # identical weights: accept-all
    K = 2
    prompts = jax.random.randint(jax.random.PRNGKey(1), (K, 8), 0,
                                 tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts), vhat=tcfg.vocab_size)

    def build(b):
        cfg = CellConfig(scheme="fixed", L_fixed=3, max_batch=K, seed=0)
        cell = MultiSpinCell(cfg, backend=b)
        for i in range(K):
            cell.submit(_req(i, alpha=1.0, T_S=0.01))
        return cell

    cell_e, cell_s = build(backend), build(SyntheticBackend())
    for _ in range(3):
        rec_e, rec_s = cell_e.step(), cell_s.step()
        np.testing.assert_array_equal(rec_e.accepted, rec_e.lengths + 1)
        np.testing.assert_array_equal(rec_s.accepted, rec_s.lengths + 1)
        np.testing.assert_array_equal(rec_e.accepted, rec_s.accepted)
    assert cell_e.summary()["tokens"] == cell_s.summary()["tokens"]
    # the engine really committed those tokens
    assert all(len(c) == 8 + 3 * 4 for c in backend.state.committed)

    # pipelined schedule with the engine: the off half is FROZEN — its
    # stream must not advance, so content always matches accounting
    cell_e.config.schedule = "pipelined"
    r1 = cell_e.step()
    np.testing.assert_array_equal(np.sort(r1.accepted), [0, 4])
    lens = [len(c) for c in backend.state.committed]
    assert sorted(l - (8 + 12) for l in lens) == [0, 4]
    r2 = cell_e.step()
    assert not np.any(r1.active & r2.active)          # other half this time
    assert all(len(c) == 8 + 12 + 4 for c in backend.state.committed)


# ---------------------------------------------------------------------------
# Legacy shim is gone (PR-1 migration window closed)
# ---------------------------------------------------------------------------

def test_protocol_shim_removed():
    with pytest.raises(ModuleNotFoundError):
        import repro.core.protocol  # noqa: F401
    with pytest.raises(AttributeError):
        import repro.api
        repro.api.MultiSpinProtocol  # noqa: B018


def test_pipelined_schedule_honors_deadline_factor():
    """The pipelined schedule must apply the same straggler masking as the
    sync schedule (it previously ignored deadline_factor entirely): a 100x
    straggler gets dropped from its half's verification and commits 0."""
    cfg = CellConfig(scheme="fixed", L_fixed=6, max_batch=4,
                     schedule="pipelined", deadline_factor=1.01, seed=0)
    cell = MultiSpinCell(cfg)
    for i in range(4):
        cell.submit(_req(i, alpha=0.9, T_S=0.01 * (100.0 if i == 3 else 1.0)))
    dropped = participated = 0
    for _ in range(16):
        rec = cell.step()
        i3 = rec.rids.tolist().index(3)
        half = rec.lengths > 0                    # planned this half-round
        if half[i3]:
            participated += 1
            if not rec.active[i3]:
                dropped += 1
                assert rec.accepted[i3] == 0
                # the straggler no longer gates the half's upload phase
                assert rec.t_ma < 0.01 * 100 * rec.lengths[i3]
    assert participated > 0
    assert dropped == participated                # always over deadline


# ---------------------------------------------------------------------------
# Telemetry satellites: phase breakdown, the two goodput views, listeners
# ---------------------------------------------------------------------------

def test_round_record_phase_breakdown():
    """Every round carries t_draft/t_upload with the documented geometry:
    phases overlap across devices, so t_draft + t_upload >= t_ma, and the
    full round is the multi-access phase plus verification."""
    cell = _cell(K=4)
    cell.run(n_rounds=5)
    for rec in cell.history:
        assert rec.t_draft > 0 and rec.t_upload > 0
        assert rec.t_draft + rec.t_upload >= rec.t_ma - 1e-12
        assert max(rec.t_draft, rec.t_upload) <= rec.t_ma + 1e-12
        assert rec.t_round == pytest.approx(rec.t_ma + rec.t_ver)
        assert rec.pool_stats is None          # synthetic: no page pool
    s = cell.summary()
    assert s["seconds_draft"] == pytest.approx(
        sum(r.t_draft for r in cell.history))
    assert s["seconds_upload"] == pytest.approx(
        sum(r.t_upload for r in cell.history))
    assert s["seconds_verify"] == pytest.approx(
        sum(r.t_ver for r in cell.history))


def test_summary_exposes_both_goodput_views():
    """`goodput_committed` counts RAW accepted tokens (a finishing device's
    final round can overshoot its budget) over the protocol wall;
    `goodput_capped` is the scheduler's budget-capped account.  Committed
    always dominates."""
    cell = _cell(K=4)
    for r in list(cell.scheduler.queue):
        r.max_new_tokens = 10                   # force final-round overshoot
    cell.drain()
    s = cell.summary()
    assert s["goodput_committed"] == pytest.approx(s["goodput"])
    assert s["goodput_capped"] == pytest.approx(cell.scheduler.stats.goodput)
    assert s["goodput_committed"] >= s["goodput_capped"] > 0
    raw = sum(int(r.accepted.sum()) for r in cell.history)
    assert raw >= cell.scheduler.stats.total_tokens
    assert cell.scheduler.stats.total_tokens == 4 * 10


def test_cell_listener_surface():
    """on_admit/on_round/on_reject fire at the documented points; partial
    listeners (missing methods) are fine; remove_listener detaches."""
    events = []

    class Probe:
        def on_admit(self, reqs):
            events.append(("admit", [r.rid for r in reqs]))

        def on_round(self, rec):
            events.append(("round", int(rec.accepted.sum())))

    class RoundOnly:
        def on_round(self, rec):
            events.append(("round2", None))

    cell = _cell(K=2)
    probe = cell.add_listener(Probe())
    cell.add_listener(RoundOnly())
    rec = cell.step()
    assert events[0] == ("admit", [0, 1])
    assert events[1] == ("round", int(rec.accepted.sum()))
    assert events[2] == ("round2", None)
    cell.remove_listener(probe)
    cell.step()
    assert events[3] == ("round2", None)       # probe detached

    # scheduler TTFT satellite: first-commit times were recorded
    assert len(cell.scheduler.stats.ttft_s) == 2
    assert all(t > 0 for t in cell.scheduler.stats.ttft_s)
