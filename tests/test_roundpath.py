"""Compiled round path (``repro.serving.compiled``): jitted step parity,
donation safety, retrace bounds, and host-transfer accounting.

The engine's compiled modes must be *bit-identical* to eager dispatch — the
jitted steps run the same ops at the same shapes with the same RNG stream —
so every parity assertion here is exact equality on committed token ids,
not a tolerance.
"""

import jax
import numpy as np
import pytest

from repro.api import CellConfig, EngineBackend, MultiSpinCell, Request
from repro.configs import get_config
from repro.serving import SpecEngine
from repro.serving.compiled import COMPILE_MODES

B, L, VHAT = 3, 4, 64
MAX_LEN = 96


def _engine(mode, cache_kind="paged", seed=0):
    tcfg = get_config("qwen2.5-3b").smoke()
    dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                        head_dim=16, d_ff=64, name="draft-smoke")
    kw = {"num_pages": B * 2 * (MAX_LEN // 16)} if cache_kind == "paged" else {}
    eng = SpecEngine(tcfg, dcfg, max_len=MAX_LEN, cache_kind=cache_kind,
                     compile_mode=mode, **kw)
    eng.init_params(jax.random.PRNGKey(seed))
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, 10), 0,
                                 tcfg.vocab_size)
    return eng, prompts


def _run(mode, cache_kind, rounds=4, widths=(1,)):
    """Run ``rounds`` rounds (draft_width cycling through ``widths``) and
    return (engine, state, host_syncs_per_round for the J=1 rounds)."""
    eng, prompts = _engine(mode, cache_kind)
    st = eng.start(prompts)
    if mode != "eager":
        st, info = eng.warmup(st, [(B, L)], vhat=VHAT)
        assert info, "warmup compiled nothing"
    base = jax.random.PRNGKey(42)
    lin_syncs = []
    for r in range(rounds):
        J = widths[r % len(widths)]
        h0 = eng.host_syncs
        st, _, _ = eng.spin_round(st, np.full(B, L), jax.random.fold_in(base, r),
                                  vhat=VHAT, draft_width=J)
        if J == 1:
            lin_syncs.append(eng.host_syncs - h0)
    if cache_kind == "paged":
        eng.t_pages.check_invariants()
        eng.d_pages.check_invariants()
    return eng, st, lin_syncs


_COMMITTED = {}


def _committed(mode, cache_kind, widths=(1,)):
    key = (mode, cache_kind, widths)
    if key not in _COMMITTED:
        _, st, _ = _run(mode, cache_kind, widths=widths)
        _COMMITTED[key] = [list(map(int, c)) for c in st.committed]
    return _COMMITTED[key]


@pytest.mark.parametrize("cache_kind", ["paged", "contiguous"])
@pytest.mark.parametrize("mode", ["jit", "jit+donate"])
def test_compiled_bit_identical_to_eager(mode, cache_kind):
    assert _committed(mode, cache_kind) == _committed("eager", cache_kind)


def test_mixed_width_rounds_bit_identical():
    """J=1 rounds run the compiled steps; J>1 rounds take the tree path
    (eager dispatch).  Alternating them through one engine must still match
    eager exactly — the caches the jitted steps adopt and the ones the tree
    path rebuilds have to interoperate."""
    widths = (1, 2, 1, 2)
    assert _committed("jit+donate", "paged", widths) \
        == _committed("eager", "paged", widths)


@pytest.mark.parametrize("mode", COMPILE_MODES)
def test_one_host_sync_per_linear_round(mode):
    _, _, lin_syncs = _run(mode, "paged")
    assert lin_syncs == [1] * len(lin_syncs), lin_syncs


def test_warmup_bounds_retraces():
    """``warmup(buckets)`` pre-traces draft/verify/commit at each bucket;
    real rounds at those shapes must not retrace (shape-keyed, counted by
    the trace-time ``on_step_trace`` hook)."""
    eng, prompts = _engine("jit+donate")
    st = eng.start(prompts)
    st, _ = eng.warmup(st, [(B, L)], vhat=VHAT)
    assert eng.step_shapes == {("draft", B, L), ("verify", B, L),
                               ("commit", B, L)}
    retraced = []
    eng.on_step_trace = retraced.append
    base = jax.random.PRNGKey(7)
    for r in range(3):
        st, _, _ = eng.spin_round(st, np.full(B, L), jax.random.fold_in(base, r),
                                  vhat=VHAT)
    assert retraced == [], f"retraced after warmup: {retraced}"


def test_dispatch_is_transfer_free():
    """After warmup, the draft+verify dispatch path moves nothing between
    host and device: stream state is device-resident and the page table's
    device mirror updates incrementally.  (Commit is excluded — its packed
    emission is the round's ONE intentional device->host fetch.)"""
    eng, prompts = _engine("jit+donate")
    st = eng.start(prompts)
    st, _ = eng.warmup(st, [(B, L)], vhat=VHAT)
    kd, kv = jax.random.split(jax.random.PRNGKey(99))
    with jax.transfer_guard("disallow"):
        ticket = eng.draft_rows(st, list(range(B)), np.full(B, L), kd,
                                vhat=VHAT)
        ticket = eng.verify_rows(ticket, kv)
    st, accepted = eng.commit_rows(st, ticket)
    assert np.all(accepted >= 1)


def test_roundrecord_reports_host_syncs():
    """The cell's per-round telemetry carries the engine's host-transfer
    count: exactly one device->host fetch per committed round.  Eager mode
    keeps this test cheap — the commit math and its packed-emission fetch
    are shared by every compile mode (the per-mode sync count is asserted
    by ``test_one_host_sync_per_linear_round``)."""
    eng, prompts = _engine("eager")
    backend = EngineBackend(eng, eng.start(prompts))
    cfg = CellConfig(scheme="hete", t_ver_fix=0.03, t_ver_lin=0.002,
                     L_max=L, max_batch=B, seed=0)
    cell = MultiSpinCell(cfg, backend=backend, rng=np.random.default_rng(0))
    for i in range(B):
        cell.submit(Request(rid=i, prompt_len=6, max_new_tokens=10 ** 9,
                            alpha=0.8, T_S=0.03))
    cell.admit()
    for _ in range(3):
        rec = cell.step()
        assert rec.n_host_syncs == 1, rec.n_host_syncs


def test_invalid_compile_mode_rejected():
    tcfg = get_config("qwen2.5-3b").smoke()
    with pytest.raises(ValueError):
        SpecEngine(tcfg, tcfg, max_len=32, compile_mode="aot")
