"""Live serving gateway: SSE streaming vs batch bit-identity, telemetry,
disconnect/retire lifecycle on the paged engine, and loadgen math.

Async tests run through ``asyncio.run`` inside sync test functions (no
pytest-asyncio dependency).
"""

import asyncio
import json
import re

import numpy as np
import pytest

from repro.api import CellConfig, MultiSpinCell, Request
from repro.serving.gateway import (
    GatewayClient,
    GatewayConfig,
    GatewayError,
    MetricsHub,
    MultiSpinGateway,
    percentile,
    summarize,
)

REQ_FIELDS = [dict(prompt_len=8, max_new_tokens=16, alpha=a, T_S=0.009)
              for a in (0.71, 0.74, 0.86, 0.8, 0.71, 0.74, 0.86, 0.8)]


def _cell(seed=0, max_batch=8, **kw):
    cfg = CellConfig(scheme="hete", max_batch=max_batch, seed=seed,
                     t_ver_fix=0.035, t_ver_lin=0.0177, L_max=8, **kw)
    return MultiSpinCell(cfg)


async def _start(cell, **gw_kw):
    gw = MultiSpinGateway(cell, GatewayConfig(port=0, idle_wait_s=0.02,
                                              **gw_kw))
    await gw.start()
    return gw, GatewayClient(port=gw.port)


# ---------------------------------------------------------------------------
# the acceptance test: >= 8 concurrent SSE clients, bit-identical to batch
# ---------------------------------------------------------------------------

def test_concurrent_sse_clients_bit_identical_to_batch():
    """8 concurrent SSE clients against a live gateway produce EXACTLY the
    round sequence and per-request token counts of ``cell.run()`` on an
    identically-seeded batch cell: same seed + same submission order +
    first-step barrier => same rng stream => same plans/draws/rounds."""

    async def live():
        gw, cli = await _start(_cell(), step_barrier=len(REQ_FIELDS))
        # submit sequentially — each client waits for its `queued` event so
        # rid assignment (and cell submission order) is deterministic
        streams = []
        for f in REQ_FIELDS:
            gen = cli.stream_generate(**f)
            ev = await gen.__anext__()
            assert ev.event == "queued"
            streams.append((ev.data["rid"], gen))
        assert [rid for rid, _ in streams] == list(range(len(REQ_FIELDS)))

        async def collect(rid, gen):
            toks, per_round, done = [], [], False
            async for ev in gen:
                if ev.event == "round":
                    toks.extend(ev.data["tokens"])
                    per_round.append(ev.data["n"])
                elif ev.event == "done":
                    done = True
            await gen.aclose()
            return rid, toks, per_round, done
        results = await asyncio.gather(
            *(collect(rid, gen) for rid, gen in streams))
        history = list(gw.cell.history)
        stats = gw.cell.scheduler.stats
        await gw.stop()
        return results, history, stats

    results, live_history, live_stats = asyncio.run(live())
    assert all(done for _, _, _, done in results)

    batch = _cell()
    reqs = [Request(rid=i, **f) for i, f in enumerate(REQ_FIELDS)]
    for r in reqs:
        batch.submit(r)
    batch.run()

    # identical round-by-round protocol execution
    assert len(live_history) == len(batch.history)
    for a, b in zip(live_history, batch.history):
        np.testing.assert_array_equal(a.lengths, b.lengths)
        np.testing.assert_array_equal(a.accepted, b.accepted)
        np.testing.assert_array_equal(a.rids, b.rids)
        assert a.t_round == b.t_round
        assert a.draft_width == b.draft_width
    # identical per-request outcomes; streamed counts respect the cap
    by_rid = {r.rid: r for r in reqs}
    for rid, toks, per_round, _ in results:
        assert len(toks) == by_rid[rid].generated == 16
        assert per_round == [n for n in per_round if n > 0]
    assert live_stats.total_tokens == batch.scheduler.stats.total_tokens
    assert live_stats.completed == len(REQ_FIELDS)


# ---------------------------------------------------------------------------
# /metrics + /v1/stats
# ---------------------------------------------------------------------------

def test_metrics_scrape_parses_and_reports_required_families():
    async def run():
        gw, cli = await _start(_cell(max_batch=4))
        rs = await asyncio.gather(
            *(cli.generate(prompt_len=8, max_new_tokens=8, alpha=0.8,
                           T_S=0.009) for _ in range(4)))
        text = await cli.metrics()
        stats = await cli.stats()
        await gw.stop()
        return rs, text, stats

    rs, text, stats = asyncio.run(run())
    assert all(r.done for r in rs)

    # every exposition line parses as comment or `name{labels} value`
    line_re = re.compile(r"^(#.*|[a-z_]+(\{[^}]*\})? [0-9.eE+-]+)$")
    for line in text.strip().splitlines():
        assert line_re.match(line), f"unparseable metrics line: {line!r}"

    def value(name):
        m = re.search(rf"^{name} ([0-9.eE+-]+)$", text, re.M)
        assert m, f"metric {name} missing"
        return float(m.group(1))

    assert value("multispin_rounds_total") >= 1
    assert value("multispin_tokens_committed_total") >= 4 * 8
    assert 0.0 < value("multispin_acceptance_rate") < 1.0
    assert value("multispin_queue_depth") == 0
    assert value("multispin_draft_width") >= 1
    assert value("multispin_goodput_committed_tokens_per_s") > 0
    assert value("multispin_goodput_capped_tokens_per_s") > 0
    assert value("multispin_pool_free_pages") == 0      # synthetic: no pool
    assert re.search(r'^multispin_round_phase_seconds\{phase="draft"\} ',
                     text, re.M)
    assert re.search(r'^multispin_device_goodput_tokens_per_s\{rid="\d+"\} ',
                     text, re.M)

    # histogram families: cumulative le buckets, +Inf == _count, sum sane
    for fam in ("multispin_ttft_seconds", "multispin_round_seconds"):
        buckets = [
            (le, int(c)) for le, c in
            re.findall(rf'^{fam}_bucket{{le="([^"]+)"}} (\d+)$', text, re.M)]
        assert buckets and buckets[-1][0] == "+Inf"
        counts = [c for _, c in buckets]
        assert counts == sorted(counts), f"{fam} buckets not cumulative"
        assert counts[-1] == int(value(rf"{fam}_count"))
        assert value(rf"{fam}_sum") > 0
    assert value("multispin_ttft_seconds_count") == 4

    assert stats["rounds_total"] >= 1
    assert stats["scheduler"]["completed"] == 4
    assert stats["scheduler"]["goodput_capped"] > 0
    assert stats["ttft_sim_s"]["n"] == 4
    last = stats["last_round"]
    assert last["goodput_committed"] > 0
    assert last["t_draft"] >= 0 and last["t_round"] > 0


# ---------------------------------------------------------------------------
# error paths
# ---------------------------------------------------------------------------

class _NeverServable:
    """Stub backend: draws like SyntheticBackend but refuses everything."""

    def servable(self, request):
        return False

    def verify(self, lengths, requests, rng, key=None, mask=None,
               draft_width=1):  # pragma: no cover - nothing gets admitted
        raise AssertionError("unreachable")


def test_http_error_paths():
    async def run():
        gw, cli = await _start(_cell(max_batch=2))
        out = {}
        # unknown route -> 404
        with pytest.raises(GatewayError) as e404:
            await cli._call("GET", "/nope")
        out["404"] = e404.value
        # malformed generate -> 400
        with pytest.raises(GatewayError) as e400:
            await cli.generate(max_new_tokens=-3)
        out["400"] = e400.value
        with pytest.raises(GatewayError) as e400b:
            await cli.generate(alpha=7.5)
        out["400b"] = e400b.value
        # unknown stream -> 404
        with pytest.raises(GatewayError) as edel:
            await cli.delete_stream(12345)
        out["del"] = edel.value
        await gw.stop()
        return out

    out = asyncio.run(run())
    assert out["404"].status == 404
    assert out["400"].status == 400 and "max_new_tokens" in str(out["400"])
    assert out["400b"].status == 400 and "alpha" in str(out["400b"])
    assert out["del"].status == 404


def test_unservable_request_rejected_with_422():
    async def run():
        cell = MultiSpinCell(CellConfig(scheme="hete", max_batch=2, seed=0),
                             backend=_NeverServable())
        gw, cli = await _start(cell)
        with pytest.raises(GatewayError) as exc:
            await cli.generate(prompt_len=8, max_new_tokens=4)
        await gw.stop()
        return exc.value

    err = asyncio.run(run())
    assert err.status == 422
    assert err.body["error"] == "unservable"


# ---------------------------------------------------------------------------
# explicit stream retirement (DELETE) on the synthetic backend
# ---------------------------------------------------------------------------

def test_delete_stream_retires_mid_session():
    async def run():
        gw, cli = await _start(_cell(max_batch=2))
        gen = cli.stream_generate(prompt_len=8, max_new_tokens=10 ** 6,
                                  alpha=0.8, T_S=0.009)
        ev = await gen.__anext__()
        rid = ev.data["rid"]
        # wait for at least one streamed round, then retire
        got_round = False
        retired = None
        async for ev in gen:
            if ev.event == "round" and not got_round:
                got_round = True
                resp = await cli.delete_stream(rid)
                assert resp["status"] == "retired"
            elif ev.event == "retired":
                retired = ev.data
                break
        await gen.aclose()
        active = [r.rid for r in gw.cell.scheduler.active]
        await gw.stop()
        return got_round, retired, active, rid

    got_round, retired, active, rid = asyncio.run(run())
    assert got_round and retired["rid"] == rid
    assert rid not in active


# ---------------------------------------------------------------------------
# MetricsHub unit behaviour (batch cell, no server)
# ---------------------------------------------------------------------------

def test_metrics_hub_on_batch_cell(tmp_path):
    trace = tmp_path / "trace.jsonl"
    cell = _cell(max_batch=4)
    hub = MetricsHub(window=3, trace_path=str(trace))
    hub.attach(cell)
    for i in range(4):
        cell.submit(Request(rid=i, prompt_len=8, max_new_tokens=12,
                            alpha=0.8, T_S=0.009))
    cell.run()
    hub.close()

    n_rounds = len(cell.history)
    assert hub.rounds_total == n_rounds
    assert len(hub.ring) == min(3, n_rounds)          # bounded ring
    committed = sum(int(r.accepted.sum()) for r in cell.history)
    assert hub.tokens_committed_total == committed
    assert hub.admitted_total == 4
    # acceptance identity vs raw history
    drafted = sum(int(r.lengths[r.active].sum()) for r in cell.history)
    positions = sum(int(np.maximum(r.accepted - 1, 0)[r.active].sum())
                    for r in cell.history)
    snap = hub.snapshot()
    assert snap["acceptance_total"] == pytest.approx(positions / drafted)
    # both goodput views surface and differ in the documented direction
    last = hub.latest
    s = cell.summary()
    assert last.goodput_committed == pytest.approx(s["goodput_committed"])
    assert last.goodput_capped == pytest.approx(s["goodput_capped"])
    assert s["goodput_committed"] >= s["goodput_capped"] > 0
    # JSONL trace: one parseable record per round
    rows = [json.loads(ln) for ln in trace.read_text().splitlines()]
    assert len(rows) == n_rounds
    assert rows[-1]["round_idx"] == n_rounds - 1
    assert rows[0]["accepted_tokens"] == int(cell.history[0].accepted.sum())


# ---------------------------------------------------------------------------
# loadgen percentile math
# ---------------------------------------------------------------------------

def test_percentile_matches_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 10, 101):
        xs = rng.uniform(0, 100, n).tolist()
        for q in (0, 25, 50, 90, 95, 99, 100):
            assert percentile(xs, q) == pytest.approx(
                float(np.percentile(xs, q)), rel=1e-12)
    with pytest.raises(ValueError):
        percentile([], 50)
    s = summarize([3.0, 1.0, 2.0])
    assert s["n"] == 3 and s["p50"] == 2.0 and s["max"] == 3.0
    assert summarize([]) == {"p50": 0.0, "p90": 0.0, "p95": 0.0, "p99": 0.0,
                             "mean": 0.0, "max": 0.0, "n": 0}


# ---------------------------------------------------------------------------
# paged engine: disconnect retires the stream and returns its pages
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_disconnect_returns_pages_on_paged_engine():
    jax = pytest.importorskip("jax")
    from repro.api import EngineBackend, SpecEngine
    from repro.configs import get_config

    tcfg = get_config("qwen2.5-3b").smoke()
    dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                        head_dim=16, d_ff=64, name="draft-smoke")
    eng = SpecEngine(tcfg, dcfg, max_len=128, cache_kind="paged")
    eng.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts),
                            keep_finished_tokens=True)
    cell = MultiSpinCell(CellConfig(scheme="fixed", L_fixed=3, max_batch=2,
                                    seed=0), backend=backend)

    async def run():
        gw, cli = await _start(cell, step_barrier=2)
        # stream A runs to completion; stream B disconnects after one round
        a = asyncio.create_task(cli.generate(
            prompt_len=8, max_new_tokens=8, alpha=0.9, T_S=0.009))
        b = asyncio.create_task(cli.generate(
            prompt_len=8, max_new_tokens=10 ** 6, alpha=0.9, T_S=0.009,
            disconnect_after_rounds=1))
        res_a, res_b = await asyncio.gather(a, b)
        # the gateway notices the dropped socket and retires B
        for _ in range(200):
            if not any(r.rid == res_b.rid
                       for r in gw.cell.scheduler.active):
                break
            await asyncio.sleep(0.02)
        active = [r.rid for r in gw.cell.scheduler.active]
        await gw.stop()
        return res_a, res_b, active

    res_a, res_b, active = asyncio.run(run())
    assert res_a.done and len(res_a.tokens) == 8
    # real committed token ids, not positional surrogates
    assert all(isinstance(t, int) for t in res_a.tokens)
    vocab = get_config("qwen2.5-3b").smoke().vocab_size
    assert all(0 <= t < vocab for t in res_a.tokens)
    assert res_b.n_rounds == 1 and not res_b.done
    assert res_b.rid not in active
    # B's row was retired: its pages are back and the allocator is clean
    assert res_b.rid not in backend._row_of
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()
    # only retired/finished rows may still hold pages; B's stream id is gone
    row_b = None  # retired — stream id no longer in the page manager
    assert row_b is None
    # the finished stream A's tokens match the engine's committed suffix
    # accounting (capped at max_new_tokens by the scheduler)
    assert len(res_a.tokens) == 8
