"""Shared test configuration.

jax holds every compiled executable for the life of the process; across 200+
tests (40 arch-smoke model variants, kernel interpret runs, engine loops) the
LLVM JIT footprint grows to several GB and can abort the suite on smaller
hosts.  Dropping the compilation caches between test modules caps the peak.
"""

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()
