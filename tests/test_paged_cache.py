"""Paged KV-cache subsystem: allocator invariants, paged-vs-contiguous
engine parity, and the EngineBackend churn lifecycle (dynamic admission)."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.api import (  # noqa: E402
    CellConfig,
    EngineBackend,
    MultiSpinCell,
    PagedKVCache,
    PagePoolExhausted,
    Request,
)
from repro.configs import get_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import SpecEngine  # noqa: E402


# ---------------------------------------------------------------------------
# Allocator property tests
# ---------------------------------------------------------------------------

def test_allocator_basic_lifecycle():
    mgr = PagedKVCache(num_pages=10, page_size=4, pages_per_stream=4)
    mgr.alloc_stream(0, 7)                     # 2 pages
    assert mgr.num_free_pages == 8
    mgr.extend(0, 13)                          # 4 pages total
    assert mgr.num_free_pages == 6
    assert mgr.length(0) == 13
    freed = mgr.truncate(0, 5)                 # back to 2 pages
    assert freed == 2 and mgr.num_free_pages == 8
    assert mgr.free_stream(0) == 2
    assert mgr.num_free_pages == 10
    mgr.check_invariants()


def test_allocator_rejects_over_capacity():
    mgr = PagedKVCache(num_pages=4, page_size=4, pages_per_stream=4)
    assert mgr.can_allocate(16)
    assert not mgr.can_allocate(17)            # > pages_per_stream
    mgr.alloc_stream(0, 12)                    # 3 of 4 pages
    assert mgr.can_allocate(4)
    assert not mgr.can_allocate(5)
    with pytest.raises(PagePoolExhausted):
        mgr.alloc_stream(1, 8)
    # failed allocation must not leak partial state
    mgr.check_invariants()
    assert mgr.num_free_pages == 1
    assert 1 not in mgr.streams()
    with pytest.raises(PagePoolExhausted):
        mgr.extend(0, 17)                      # past pages_per_stream
    mgr.check_invariants()


def test_allocator_double_ops_raise():
    mgr = PagedKVCache(num_pages=8, page_size=2, pages_per_stream=4)
    mgr.alloc_stream(3, 4)
    with pytest.raises(ValueError):
        mgr.alloc_stream(3, 2)                 # double alloc
    mgr.free_stream(3)
    with pytest.raises(KeyError):
        mgr.free_stream(3)                     # double free
    mgr.check_invariants()


def test_allocator_random_sequences_never_leak():
    """Random alloc/extend/truncate/free churn: after every operation the
    pool partitions exactly into free + mapped pages (no leak, no double
    mapping)."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        mgr = PagedKVCache(num_pages=int(rng.integers(4, 40)),
                           page_size=int(rng.integers(1, 8)),
                           pages_per_stream=int(rng.integers(2, 10)))
        live: dict[int, int] = {}
        next_sid = 0
        for _ in range(200):
            op = rng.integers(4)
            if op == 0:
                length = int(rng.integers(0, mgr.pages_per_stream
                                          * mgr.page_size + 2))
                try:
                    mgr.alloc_stream(next_sid, length)
                    live[next_sid] = length
                except PagePoolExhausted:
                    assert not mgr.can_allocate(length)
                next_sid += 1
            elif op == 1 and live:
                sid = int(rng.choice(list(live)))
                new_len = live[sid] + int(rng.integers(0, 12))
                try:
                    mgr.extend(sid, new_len)
                    live[sid] = new_len
                except PagePoolExhausted:
                    pass
            elif op == 2 and live:
                sid = int(rng.choice(list(live)))
                live[sid] = int(rng.integers(0, live[sid] + 1))
                mgr.truncate(sid, live[sid])
            elif op == 3 and live:
                sid = int(rng.choice(list(live)))
                mgr.free_stream(sid)
                del live[sid]
            mgr.check_invariants()
        used = sum(mgr.pages_for(length) for length in live.values())
        assert mgr.num_allocated_pages == used


# ---------------------------------------------------------------------------
# Paged model forward == contiguous model forward
# ---------------------------------------------------------------------------

def test_paged_forward_window_matches_contiguous():
    cfg = get_config("qwen2.5-3b").smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, M, L, max_len, ps = 3, 10, 4, 64, 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, M), 0,
                                 cfg.vocab_size)

    cache = m.init_cache(B, max_len, jnp.float32)
    lg_c, cache, _ = m.prefill(params, prompts[:, :-1], cache)

    mgr = PagedKVCache(num_pages=16, page_size=ps,
                       pages_per_stream=max_len // ps)
    pool = m.init_paged_cache(16, ps, jnp.float32)
    for b in range(B):
        mgr.alloc_stream(b, M - 1)
    pool = dict(pool, pages=jnp.asarray(mgr.page_table(range(B))))
    lg_p, pool, _ = m.prefill(params, prompts[:, :-1], pool)
    np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_p),
                               rtol=1e-5, atol=1e-5)

    # two windows at increasingly ragged offsets (accept/reject divergence)
    pos = jnp.full((B,), M - 1, jnp.int32)
    for step, deltas in enumerate([(2, 5, 1), (4, 1, 3)]):
        win = jax.random.randint(jax.random.PRNGKey(2 + step), (B, L + 1),
                                 0, cfg.vocab_size)
        for b in range(B):
            mgr.extend(b, int(pos[b]) + L + 1)
        pool["pages"] = jnp.asarray(mgr.page_table(range(B)))
        o_c, cache = m.forward_window(params, win, cache, pos)
        o_p, pool = m.forward_window(params, win, pool, pos)
        np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_p),
                                   rtol=1e-5, atol=1e-5)
        pos = pos + jnp.asarray(deltas)
        for b in range(B):
            mgr.truncate(b, int(pos[b]))         # rejected pages return
        mgr.check_invariants()


# ---------------------------------------------------------------------------
# Seeded engine parity: identical committed tokens + accept counts
# ---------------------------------------------------------------------------

def _engine_pair(max_len=96):
    tcfg = get_config("qwen2.5-3b").smoke()
    dcfg = tcfg.replace(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                        head_dim=16, d_ff=64, name="draft-smoke")
    return tcfg, dcfg


def test_paged_engine_matches_contiguous_engine():
    tcfg, dcfg = _engine_pair()
    lengths = np.array([3, 5, 2])
    results = {}
    for kind in ("contiguous", "paged"):
        eng = SpecEngine(tcfg, dcfg, max_len=96, cache_kind=kind)
        eng.init_params(jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 0,
                                     tcfg.vocab_size)
        state = eng.start(prompts)
        counts = []
        for r in range(3):
            state, res, _ = eng.spin_round(state, lengths,
                                           jax.random.PRNGKey(10 + r))
            counts.append(np.asarray(res.accept_counts))
        results[kind] = (state.committed, np.stack(counts),
                         np.asarray(state.target_pos))
    c_com, c_cnt, c_pos = results["contiguous"]
    p_com, p_cnt, p_pos = results["paged"]
    np.testing.assert_array_equal(c_cnt, p_cnt)
    np.testing.assert_array_equal(c_pos, p_pos)
    assert c_com == p_com


def test_paged_engine_incremental_consistency_after_churn():
    """After retire + rejoin + batch growth, every live stream's incremental
    logits must equal a from-scratch re-scoring of its committed text."""
    tcfg, dcfg = _engine_pair()
    eng = SpecEngine(tcfg, dcfg, max_len=96, cache_kind="paged")
    eng.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 0,
                                 tcfg.vocab_size)
    state = eng.start(prompts)
    for r in range(2):
        state, _, _ = eng.spin_round(state, np.array([3, 4, 2]),
                                     jax.random.PRNGKey(10 + r))
    eng.retire_stream(1)
    state, rows = eng.add_streams(
        state, jax.random.randint(jax.random.PRNGKey(7), (1, 8), 0,
                                  tcfg.vocab_size))
    assert rows == [1]                      # retired row recycled
    state, rows2 = eng.add_streams(
        state, jax.random.randint(jax.random.PRNGKey(8), (1, 8), 0,
                                  tcfg.vocab_size))
    assert rows2 == [3]                     # batch grows past start size
    for r in range(2):
        state, _, _ = eng.spin_round(state, np.array([2, 3, 2, 2]),
                                     jax.random.PRNGKey(50 + r))
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()

    B = state.pending.shape[0]
    # a real decode step extends the mapping before writing its token (the
    # pending position may start a fresh page)
    for b in range(B):
        eng.t_pages.extend(b, int(state.target_pos[b]) + 1)
    view = dict(eng.t_cache,
                pages=jnp.asarray(eng.t_pages.page_table(range(B))))
    inc, _ = eng.target.forward_window(eng.t_params, state.pending[:, None],
                                       view, state.target_pos)
    for b in range(B):
        assert state.committed[b][-1] == int(state.pending[b])
        seq = jnp.asarray(state.committed[b])[None, :]
        full, _ = eng.target.apply(eng.t_params, seq)
        np.testing.assert_allclose(np.asarray(inc[b, 0]),
                                   np.asarray(full[0, -1]),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# EngineBackend churn lifecycle through the cell
# ---------------------------------------------------------------------------

def test_engine_backend_churn_lifecycle():
    """The acceptance scenario: a request submitted AFTER engine.start() is
    admitted (no 'engine batch exhausted'), completes, departs return their
    pages, and a later request recycles the row."""
    tcfg, dcfg = _engine_pair()
    eng = SpecEngine(tcfg, dcfg, max_len=128, cache_kind="paged")
    eng.init_params(jax.random.PRNGKey(0))
    K, M = 2, 6
    prompts = jax.random.randint(jax.random.PRNGKey(1), (K, M), 0,
                                 tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts))
    cell = MultiSpinCell(CellConfig(scheme="fixed", L_fixed=3, max_batch=3,
                                    seed=0), backend=backend)
    for i in range(K):
        cell.submit(Request(rid=i, prompt_len=M, max_new_tokens=10,
                            alpha=0.8, T_S=0.01))
    cell.step()
    # join after start()
    cell.submit(Request(rid=99, prompt_len=6, max_new_tokens=6, alpha=0.8,
                        T_S=0.01))
    rec = cell.step()
    assert 99 in set(rec.rids.tolist())
    # leave mid-flight; the pages come back and the row is recyclable
    cell.leave(0)
    free_before = eng.t_pages.num_free_pages
    cell.submit(Request(rid=100, prompt_len=6, max_new_tokens=6, alpha=0.8,
                        T_S=0.01))
    rec = cell.step()
    assert set(rec.rids.tolist()) >= {1, 100}
    assert eng.t_pages.num_free_pages < free_before   # rejoin took pages
    cell.drain()
    assert cell.scheduler.stats.completed == 3        # 1, 99, 100 (0 left)
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()
    assert eng.t_pages.num_allocated_pages == 0       # all pages reclaimed


def test_engine_backend_admission_blocks_on_pool_oom():
    """With a pool sized for ~1 stream, the second request must WAIT in the
    queue (admission control) instead of crashing the engine, and be
    admitted once the first stream retires."""
    tcfg, dcfg = _engine_pair()
    # ps=16: the start stream holds 1 page; admitting rid=1 would need
    # pages_for(6 + 32 headroom) = 3 > the 2 left in the pool
    eng = SpecEngine(tcfg, dcfg, max_len=64, cache_kind="paged", num_pages=3)
    eng.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0,
                                 tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts), admit_headroom=32)
    cell = MultiSpinCell(CellConfig(scheme="fixed", L_fixed=2, max_batch=4,
                                    seed=0), backend=backend)
    cell.submit(Request(rid=0, prompt_len=6, max_new_tokens=6, alpha=0.8,
                        T_S=0.01))
    cell.submit(Request(rid=1, prompt_len=6, max_new_tokens=4, alpha=0.8,
                        T_S=0.01))
    rec = cell.step()
    assert rec.rids.tolist() == [0]          # rid=1 blocked by the pool
    assert len(cell.scheduler.queue) == 1
    cell.drain()                             # 0 retires -> 1 admitted
    assert cell.scheduler.stats.completed == 2
    assert eng.t_pages.num_allocated_pages == 0


def test_unservable_request_rejected_instead_of_wedging_queue():
    """A prompt that can NEVER fit a stream (> max_len) must be evicted with
    done=True — not silently block FIFO admission for everyone behind it."""
    tcfg, dcfg = _engine_pair()
    eng = SpecEngine(tcfg, dcfg, max_len=64, cache_kind="paged")
    eng.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0,
                                 tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts))
    cell = MultiSpinCell(CellConfig(scheme="fixed", L_fixed=2, max_batch=3,
                                    seed=0), backend=backend)
    cell.submit(Request(rid=0, prompt_len=6, max_new_tokens=4, alpha=0.8,
                        T_S=0.01))
    cell.submit(Request(rid=1, prompt_len=200, max_new_tokens=4, alpha=0.8,
                        T_S=0.01))                 # can never fit max_len=64
    cell.submit(Request(rid=2, prompt_len=6, max_new_tokens=4, alpha=0.8,
                        T_S=0.01))
    rec = cell.step()
    assert [r.rid for r in cell.rejected] == [1]
    assert cell.rejected[0].done
    assert set(rec.rids.tolist()) == {0, 2}        # rid=2 was not blocked
    cell.drain()
    assert cell.scheduler.stats.completed == 2
    assert cell.idle


def test_contiguous_backend_still_raises_on_exhaustion():
    tcfg, dcfg = _engine_pair()
    eng = SpecEngine(tcfg, dcfg, max_len=64)
    eng.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0,
                                 tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts))
    r0 = Request(rid=0, prompt_len=6, max_new_tokens=6, alpha=0.8, T_S=0.01)
    r1 = Request(rid=1, prompt_len=6, max_new_tokens=6, alpha=0.8, T_S=0.01)
    assert backend.can_admit(r0) and backend.servable(r0)
    backend.bind([r0])
    assert not backend.can_admit(r1)         # admission control says no...
    assert not backend.servable(r1)          # ...and it can never be served
    with pytest.raises(ValueError, match="batch exhausted"):
        backend.bind([r1])                   # force-binding still raises


def test_contiguous_overbatch_request_rejected_not_starved():
    """drain() must not return with requests silently parked forever: a
    request a contiguous engine can never serve is rejected explicitly."""
    tcfg, dcfg = _engine_pair()
    eng = SpecEngine(tcfg, dcfg, max_len=64)
    eng.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 tcfg.vocab_size)
    backend = EngineBackend(eng, eng.start(prompts))
    cell = MultiSpinCell(CellConfig(scheme="fixed", L_fixed=2, max_batch=3,
                                    seed=0), backend=backend)
    for i in range(3):                       # one more than the start batch
        cell.submit(Request(rid=i, prompt_len=6, max_new_tokens=4,
                            alpha=0.8, T_S=0.01))
    cell.drain()
    assert cell.scheduler.stats.completed == 2
    assert [r.rid for r in cell.rejected] == [2]
    assert cell.rejected[0].done
    assert cell.idle                         # nothing parked in the queue


# ---------------------------------------------------------------------------
# Paged-prefill prompt bucketing (bounded XLA trace count under churn)
# ---------------------------------------------------------------------------

def test_paged_prefill_buckets_prompt_shapes():
    """Heavy churn with many distinct prompt lengths must compile a bounded
    number of prefill traces: ``add_streams`` pads each prompt batch to its
    power-of-two bucket, and the compile-counting hook sees only bucket
    shapes.  Committed text, positions, and page accounting stay exact."""
    tcfg, dcfg = _engine_pair()
    eng = SpecEngine(tcfg, dcfg, max_len=96, cache_kind="paged",
                     num_pages=240)
    eng.init_params(jax.random.PRNGKey(0))
    traces = []
    eng.on_prefill_trace = traces.append
    state = eng.start(jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                         tcfg.vocab_size))
    prompt_lens = list(range(9, 21))            # 12 distinct lengths
    rows_of = {}
    for i, M in enumerate(prompt_lens):
        p = jax.random.randint(jax.random.PRNGKey(100 + i), (1, M), 0,
                               tcfg.vocab_size)
        state, rows = eng.add_streams(state, p)
        rows_of[rows[0]] = np.asarray(p[0])
    # one trace per BUCKET, not per distinct (n, M): lengths 9..16 -> 16,
    # 17..20 -> 32, plus the start batch's 8
    assert len(set(traces)) <= 3, traces
    assert set(traces) == set(eng.prefill_shapes)
    assert all(shape[1] in (8, 16, 32) for shape in traces)
    for row, p in rows_of.items():
        # true prompt preserved (no pad tokens leak into committed text)
        assert state.committed[row] == list(p)
        assert int(state.target_pos[row]) == len(p) - 1
        # bucket-padding pages were handed back right after the prefill
        assert eng.t_pages.length(row) == len(p) - 1
    eng.t_pages.check_invariants()
    eng.d_pages.check_invariants()
    # the padded prefill is never attended: a spin round over every stream
    # commits L+1-bounded tokens and keeps the allocator consistent
    B = state.pending.shape[0]
    state, res, _ = eng.spin_round(state, np.full(B, 3), jax.random.PRNGKey(9))
    assert np.all(np.asarray(res.output_len) <= 4)
    eng.t_pages.check_invariants()


def test_bucketed_prefill_numerics_match_exact_prefill():
    """A stream admitted through the bucketed prefill must score its
    committed text identically to the model's from-scratch forward (the pad
    K/V past the true prompt is never attended)."""
    tcfg, dcfg = _engine_pair()
    eng = SpecEngine(tcfg, dcfg, max_len=96, cache_kind="paged")
    eng.init_params(jax.random.PRNGKey(0))
    state = eng.start(jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                         tcfg.vocab_size))
    # length 11 -> bucketed to 16 (5 pad positions written, then truncated)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 11), 0,
                                tcfg.vocab_size)
    state, rows = eng.add_streams(state, prompt)
    assert (1, 16) in eng.prefill_shapes
    for r in range(2):
        state, _, _ = eng.spin_round(state, np.array([2, 3]),
                                     jax.random.PRNGKey(30 + r))
    b = rows[0]
    eng.t_pages.extend(b, int(state.target_pos[b]) + 1)
    view = dict(eng.t_cache,
                pages=jnp.asarray(eng.t_pages.page_table(range(2))))
    inc, _ = eng.target.forward_window(eng.t_params, state.pending[:, None],
                                       view, state.target_pos)
    seq = jnp.asarray(state.committed[b])[None, :]
    full, _ = eng.target.apply(eng.t_params, seq)
    np.testing.assert_allclose(np.asarray(inc[b, 0]), np.asarray(full[0, -1]),
                               rtol=2e-3, atol=2e-3)
