"""Beyond-paper extensions: packed verification + pipelined rounds."""

import numpy as np
import pytest

from repro.core.beyond import (
    TokenBudgetVerifier,
    pipelined_plan,
    solve_heterogeneous_packed,
    solve_heterogeneous_padded_tokenbudget,
)
from repro.core.channel import ChannelConfig, ChannelState
from repro.core.draft_control import solve_heterogeneous
from repro.core.schemes import CellObservation, build_scheme


def _system(K=12, seed=0, B=10e6):
    rng = np.random.default_rng(seed)
    alphas = rng.choice([0.71, 0.74, 0.86, 0.93], K)
    T_S = rng.uniform(0.85, 1.15, K) * 0.009
    cfg = ChannelConfig(total_bandwidth_hz=B)
    ch = ChannelState.sample(cfg, K, rng)
    return alphas, T_S, ch.rates, cfg.q_tok_bits, B


def _obs(alphas, T_S, rates, Q, B, t_fix=0.035, t_lin=0.0177, L_max=25):
    return CellObservation(alphas=np.asarray(alphas), T_S=np.asarray(T_S),
                           rates=np.asarray(rates), q_tok_bits=Q,
                           bandwidth_hz=B, t_ver_fix=t_fix, t_ver_lin=t_lin,
                           L_max=L_max)


def test_verifier_calibration_consistency():
    """At L == L_ref, the token-budget padded cost equals the affine model."""
    v = TokenBudgetVerifier.from_affine(t_fix=0.035, t_lin=0.0177, L_ref=8)
    K = 20
    affine = 0.035 + K * 0.0177
    assert v.padded(K, 8) == pytest.approx(affine, rel=1e-9)
    # packed with uniform lengths == padded
    assert v.packed(np.full(K, 8)) == pytest.approx(affine, rel=1e-9)


def test_packed_never_worse_than_padded():
    v = TokenBudgetVerifier.from_affine(0.035, 0.0177)
    for seed in range(4):
        alphas, T_S, r, Q, B = _system(seed=seed, B=2e6)
        pad = solve_heterogeneous_padded_tokenbudget(alphas, T_S, r, Q, B, v)
        pk = solve_heterogeneous_packed(alphas, T_S, r, Q, B, v)
        assert pk.goodput >= pad.goodput * (1 - 1e-9)


def test_packed_saves_with_heterogeneous_lengths():
    """When optimal lengths are heterogeneous, packing must strictly win."""
    v = TokenBudgetVerifier.from_affine(0.035, 0.0177, kv_fraction=0.3)
    alphas = np.array([0.6, 0.6, 0.95, 0.95])
    T_S = np.full(4, 0.005)
    rng = np.random.default_rng(0)
    cfg = ChannelConfig(total_bandwidth_hz=1e6)
    ch = ChannelState.sample(cfg, 4, rng)
    pad = solve_heterogeneous_padded_tokenbudget(
        alphas, T_S, ch.rates, cfg.q_tok_bits, 1e6, v, n_phi=60, n_lam=60)
    pk = solve_heterogeneous_packed(
        alphas, T_S, ch.rates, cfg.q_tok_bits, 1e6, v, n_phi=60, n_lam=60)
    assert len(set(pk.lengths.tolist())) > 1, pk.lengths  # heterogeneous
    assert pk.goodput > pad.goodput


def test_pipelined_beats_synchronous():
    """Overlap must win whenever T_ver is comparable to T_ma."""
    alphas, T_S, r, Q, B = _system(K=16, seed=1)
    sync = solve_heterogeneous(alphas, T_S, r, Q, B, 0.035 + 16 * 0.0177,
                               L_max=25)
    pipe = pipelined_plan(build_scheme("hete"), _obs(alphas, T_S, r, Q, B))
    assert pipe["goodput"] > sync.goodput
    assert len(pipe["halves"]) == 2


def test_pipelined_period_formula():
    alphas, T_S, r, Q, B = _system(K=8, seed=2)
    # verification-dominated: t_ver(K) ~ 0.2 for every half
    pipe = pipelined_plan(build_scheme("hete"),
                          _obs(alphas, T_S, r, Q, B, t_fix=0.2, t_lin=0.0))
    # with t_ver >> t_ma the period approaches 2 * t_ver (server saturated)
    assert pipe["period"] >= 0.4 - 1e-9


def test_pipelined_single_device_degenerates_to_serial():
    """K == 1 has nothing to overlap with: the period is t_ma + t_ver."""
    alphas, T_S, r, Q, B = _system(K=1, seed=3)
    pipe = pipelined_plan(build_scheme("hete"), _obs(alphas, T_S, r, Q, B))
    (plan,) = pipe["halves"]
    assert pipe["period"] == pytest.approx(
        plan.equalized_latency + 0.035 + 1 * 0.0177)


def test_cell_pipelined_and_packed_schemes():
    """Cell-level integration: the pipelined schedule and the hete-packed
    controller must both beat the synchronous paper baseline on realized
    (simulated) goodput."""
    from repro.api import CellConfig, MultiSpinCell, Request

    rng = np.random.default_rng(0)
    K = 12
    profiles = list(zip(rng.uniform(0.85, 1.15, K),
                        rng.choice([0.71, 0.74, 0.86, 0.93], K)))

    def cell(scheme, schedule="sync"):
        cfg = CellConfig(scheme=scheme, t_ver_fix=0.035, t_ver_lin=0.0177,
                         L_max=25, max_batch=K, schedule=schedule, seed=1)
        c = MultiSpinCell(cfg, rng=np.random.default_rng(1))
        for i, (f, a) in enumerate(profiles):
            c.submit(Request(rid=i, prompt_len=6, max_new_tokens=10 ** 12,
                             alpha=float(a), T_S=0.009 * float(f)))
        return c

    sync = cell("hete").run(40)["goodput"]
    packed = cell("hete-packed").run(40)["goodput"]
    piped = cell("hete", schedule="pipelined").run(80)["goodput"]
    assert packed >= sync * 0.95          # never materially worse
    assert piped > sync                   # overlap wins


def test_multidraft_expected_tokens():
    """E[max of J truncated geometrics]: J=1 == eq. 12; Monte-Carlo check."""
    from repro.core.beyond import expected_accepted_multidraft
    from repro.core.goodput import expected_accepted_tokens

    for alpha in (0.5, 0.8, 0.95):
        for L in (1, 4, 12):
            np.testing.assert_allclose(
                float(expected_accepted_multidraft(np.float64(alpha), L, 1)),
                float(expected_accepted_tokens(alpha, L)), rtol=1e-12)
    # Monte Carlo for J=3
    rng = np.random.default_rng(0)
    alpha, L, J, n = 0.8, 6, 3, 60000
    acc = rng.random((n, J, L)) < alpha
    n_j = np.cumprod(acc, axis=2).sum(axis=2)
    emp = np.mean(n_j.max(axis=1) + 1)
    theory = float(expected_accepted_multidraft(np.float64(alpha), L, J))
    assert abs(emp - theory) < 0.02 * theory


def test_multidraft_optimizer_beats_single_draft():
    """With cheap verification and a rich uplink, J > 1 must win; the
    optimizer never returns less than the J=1 optimum."""
    from repro.core.beyond import TokenBudgetVerifier, solve_uniform_multidraft

    K = 8
    T_S = np.full(K, 0.004)
    r = np.full(K, 6.0)
    v = TokenBudgetVerifier.from_affine(t_fix=0.3, t_lin=0.002)
    out = solve_uniform_multidraft(0.6, T_S, r, 31744.0, 40e6, v, K)
    assert out["best"]["goodput"] >= out["single_draft"]["goodput"] - 1e-9
    assert out["best"]["J"] > 1, out
    assert out["gain"] > 0.02
    # and in a bandwidth-starved cell J = 1 should remain optimal
    out2 = solve_uniform_multidraft(0.6, T_S, r, 31744.0, 0.3e6, v, K)
    assert out2["best"]["J"] == 1, out2["best"]
