"""Correctness of speculative verification (paper eq. 4-5).

The load-bearing property: the verified output is distributed EXACTLY as
target-model sampling, for any draft distribution — including the paper's
top-|V^hat| truncated uploads.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.drafting import generate_drafts
from repro.core.verification import (
    sparse_to_dense,
    truncate_renormalize,
    verify_drafts,
)


def _random_dists(key, B, L, V, concentration=1.0):
    k1, k2 = jax.random.split(key)
    p = jax.random.dirichlet(k1, jnp.full((V,), concentration), (B, L + 1))
    q = jax.random.dirichlet(k2, jnp.full((V,), concentration), (B, L))
    return p, q


def _draft_from_q(key, q):
    """Sample draft tokens from q rows: q (B, L, V) -> tokens, probs."""
    B, L, V = q.shape
    toks = jax.random.categorical(key, jnp.log(q), axis=-1)
    probs = jnp.take_along_axis(q, toks[..., None], axis=-1)[..., 0]
    return toks.astype(jnp.int32), probs


def _run_verify(key, p, q, toks, probs, **kw):
    """p: (B, L+1, V) target dists -> logits; q dense."""
    logits = jnp.log(jnp.maximum(p, 1e-30))
    return verify_drafts(key, toks, probs, logits, q_dense=q, **kw)


def test_verify_shapes_and_ranges():
    key = jax.random.PRNGKey(0)
    B, L, V = 8, 5, 13
    p, q = _random_dists(key, B, L, V)
    toks, probs = _draft_from_q(jax.random.PRNGKey(1), q)
    res = _run_verify(jax.random.PRNGKey(2), p, q, toks, probs)
    assert res.accept_counts.shape == (B,)
    assert res.output_tokens.shape == (B, L + 1)
    assert np.all(np.asarray(res.accept_counts) >= 0)
    assert np.all(np.asarray(res.accept_counts) <= L)
    assert np.all(np.asarray(res.output_len) == np.asarray(res.accept_counts) + 1)
    assert np.all(np.asarray(res.output_tokens) >= 0)
    assert np.all(np.asarray(res.output_tokens) < V)


def test_identical_dists_accept_everything():
    """q == p => acceptance probability 1 for every position."""
    key = jax.random.PRNGKey(0)
    B, L, V = 16, 6, 11
    p, _ = _random_dists(key, B, L, V)
    q = p[:, :L]
    toks, probs = _draft_from_q(jax.random.PRNGKey(1), q)
    res = _run_verify(jax.random.PRNGKey(2), p, q, toks, probs)
    assert np.all(np.asarray(res.accept_counts) == L)


def test_disjoint_dists_reject_first():
    """Draft mass disjoint from target support => immediate rejection and the
    calibrated token is exactly a target sample."""
    B, L, V = 4096, 3, 8
    # target on {0..3}, draft on {4..7}
    p_row = jnp.array([0.4, 0.3, 0.2, 0.1, 0, 0, 0, 0.0])
    q_row = jnp.array([0, 0, 0, 0, 0.25, 0.25, 0.25, 0.25])
    p = jnp.tile(p_row, (B, L + 1, 1))
    q = jnp.tile(q_row, (B, L, 1))
    toks, probs = _draft_from_q(jax.random.PRNGKey(1), q)
    res = _run_verify(jax.random.PRNGKey(2), p, q, toks, probs)
    assert np.all(np.asarray(res.accept_counts) == 0)
    first = np.asarray(res.output_tokens[:, 0])
    freq = np.bincount(first, minlength=V) / B
    np.testing.assert_allclose(freq[:4], np.asarray(p_row[:4]), atol=0.03)
    assert np.all(freq[4:] == 0)


@pytest.mark.parametrize("concentration", [0.5, 2.0])
def test_output_marginal_matches_target(concentration):
    """THE speculative-sampling theorem: the first output token's marginal
    must equal the target distribution regardless of the draft distribution.

    Monte-Carlo with a chi^2-style tolerance. Single (p, q) pair shared by
    all rows; randomness over rows gives the empirical marginal.
    """
    B, L, V = 20000, 4, 6
    kp, kq, kd, kv = jax.random.split(jax.random.PRNGKey(int(concentration * 10)), 4)
    p_row = jax.random.dirichlet(kp, jnp.full((V,), concentration))
    q_row = jax.random.dirichlet(kq, jnp.full((V,), concentration))
    p = jnp.tile(p_row, (B, L + 1, 1))
    q = jnp.tile(q_row, (B, L, 1))
    toks, probs = _draft_from_q(kd, q)
    res = _run_verify(kv, p, q, toks, probs)
    first = np.asarray(res.output_tokens[:, 0])
    freq = np.bincount(first, minlength=V) / B
    # 4-sigma multinomial tolerance per bin
    sigma = np.sqrt(np.asarray(p_row) * (1 - np.asarray(p_row)) / B)
    assert np.all(np.abs(freq - np.asarray(p_row)) < 4 * sigma + 1e-3), \
        (freq, np.asarray(p_row))


def test_output_marginal_with_truncated_upload():
    """Exactness must survive the paper's top-|V^hat| truncation, because the
    device samples from the SAME truncated+renormalized distribution that it
    uploads."""
    B, L, V, VHAT = 20000, 3, 8, 3
    kp, kq, kd, kv = jax.random.split(jax.random.PRNGKey(7), 4)
    p_row = jax.random.dirichlet(kp, jnp.ones((V,)))
    q_full = jax.random.dirichlet(kq, jnp.ones((V,)))
    idx, val = truncate_renormalize(jnp.tile(q_full, (B, L, 1)), VHAT)
    q_trunc = sparse_to_dense(idx, val, V)
    toks, probs = _draft_from_q(kd, q_trunc)
    logits = jnp.log(jnp.maximum(jnp.tile(p_row, (B, L + 1, 1)), 1e-30))
    res = verify_drafts(kv, toks, probs, logits, q_idx=idx, q_val=val)
    first = np.asarray(res.output_tokens[:, 0])
    freq = np.bincount(first, minlength=V) / B
    sigma = np.sqrt(np.asarray(p_row) * (1 - np.asarray(p_row)) / B)
    assert np.all(np.abs(freq - np.asarray(p_row)) < 4 * sigma + 1e-3)


def test_second_token_marginal():
    """Joint exactness: P(out_2 = v | out_1) must follow the target chain.

    With position-independent target dist p (iid chain), the SECOND output
    token marginal must also equal p."""
    B, L, V = 20000, 4, 6
    kp, kq, kd, kv = jax.random.split(jax.random.PRNGKey(3), 4)
    p_row = jax.random.dirichlet(kp, jnp.ones((V,)))
    q_row = jax.random.dirichlet(kq, jnp.ones((V,)))
    p = jnp.tile(p_row, (B, L + 1, 1))
    q = jnp.tile(q_row, (B, L, 1))
    toks, probs = _draft_from_q(kd, q)
    res = _run_verify(kv, p, q, toks, probs)
    out = np.asarray(res.output_tokens)
    n = np.asarray(res.output_len)
    second = out[n >= 2, 1]
    freq = np.bincount(second, minlength=V) / len(second)
    sigma = np.sqrt(np.asarray(p_row) * (1 - np.asarray(p_row)) / len(second))
    assert np.all(np.abs(freq - np.asarray(p_row)) < 4 * sigma + 2e-3)


def test_acceptance_rate_matches_theory():
    """E[A] must equal sum_x min(p(x), q(x)) (the eq.-10 alpha for iid rows)."""
    B, L, V = 40000, 1, 10
    kp, kq, kd, kv = jax.random.split(jax.random.PRNGKey(11), 4)
    p_row = jax.random.dirichlet(kp, jnp.ones((V,)))
    q_row = jax.random.dirichlet(kq, jnp.ones((V,)))
    alpha_theory = float(jnp.sum(jnp.minimum(p_row, q_row)))
    p = jnp.tile(p_row, (B, L + 1, 1))
    q = jnp.tile(q_row, (B, L, 1))
    toks, probs = _draft_from_q(kd, q)
    res = _run_verify(kv, p, q, toks, probs)
    alpha_emp = float(np.mean(np.asarray(res.accept_counts) == 1))
    assert abs(alpha_emp - alpha_theory) < 0.01


def test_heterogeneous_draft_lengths_zero_padding():
    """Paper Sec. V: shorter drafts zero-padded to L_max must behave exactly
    like unpadded verification of the true length."""
    B, L, V = 8192, 5, 6
    kp, kq, kd, kv = jax.random.split(jax.random.PRNGKey(5), 4)
    p_row = jax.random.dirichlet(kp, jnp.ones((V,)))
    q_row = jax.random.dirichlet(kq, jnp.ones((V,)))
    p = jnp.tile(p_row, (B, L + 1, 1))
    q = jnp.tile(q_row, (B, L, 1))
    toks, probs = _draft_from_q(kd, q)
    lens = jnp.concatenate([jnp.full((B // 2,), 2), jnp.full((B - B // 2,), L)])
    res = _run_verify(kv, p, q, toks, probs, draft_len=lens)
    n = np.asarray(res.accept_counts)
    assert np.all(n[:B // 2] <= 2)
    # acceptance stats of the short rows match an unpadded L=2 run
    alpha = float(jnp.sum(jnp.minimum(p_row, q_row)))
    expect = (1 - alpha ** 3) / (1 - alpha)  # eq. 12 with L=2
    got = np.mean(n[:B // 2] + 1)
    assert abs(got - expect) < 0.05 * expect
    # first-token marginal still exact on short rows
    freq = np.bincount(np.asarray(res.output_tokens[:B // 2, 0]), minlength=V) / (B // 2)
    sigma = np.sqrt(np.asarray(p_row) * (1 - np.asarray(p_row)) / (B // 2))
    assert np.all(np.abs(freq - np.asarray(p_row)) < 4 * sigma + 2e-3)


def test_expected_accepted_matches_eq12():
    """Realized E[N|L] must track the paper's geometric formula under the
    iid-acceptance approximation (exact here by construction)."""
    B, L, V = 30000, 6, 8
    kp, kq, kd, kv = jax.random.split(jax.random.PRNGKey(13), 4)
    p_row = jax.random.dirichlet(kp, jnp.ones((V,)))
    q_row = jax.random.dirichlet(kq, jnp.ones((V,)))
    alpha = float(jnp.sum(jnp.minimum(p_row, q_row)))
    p = jnp.tile(p_row, (B, L + 1, 1))
    q = jnp.tile(q_row, (B, L, 1))
    toks, probs = _draft_from_q(kd, q)
    res = _run_verify(kv, p, q, toks, probs)
    expect = (1 - alpha ** (L + 1)) / (1 - alpha)       # eq. 12
    got = float(np.mean(np.asarray(res.output_len)))
    assert abs(got - expect) / expect < 0.03


def test_drafting_probs_match_uploaded_dists():
    """generate_drafts: the sampled token's prob must equal its entry in the
    uploaded sparse distribution, and pos/cache bookkeeping must line up."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("qwen2.5-3b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, L, VHAT = 3, 8, 4, 16
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    cache = model.init_cache(B, 32, jnp.float32)
    _, cache, _ = model.prefill(params, prompt[:, :-1], cache)
    pending = prompt[:, -1]
    pos = jnp.full((B,), S - 1, jnp.int32)
    res = generate_drafts(model, params, cache, pending, pos, L,
                          jax.random.PRNGKey(2), vhat=VHAT)
    assert res.tokens.shape == (B, L)
    assert res.q_idx.shape == (B, L, VHAT)
    # every drafted token appears in its uploaded support with the right prob
    for b in range(B):
        for l in range(L):
            tok = int(res.tokens[b, l])
            row_idx = np.asarray(res.q_idx[b, l])
            row_val = np.asarray(res.q_val[b, l])
            assert tok in row_idx
            j = int(np.where(row_idx == tok)[0][0])
            np.testing.assert_allclose(float(res.probs[b, l]), row_val[j],
                                       rtol=1e-5)
            np.testing.assert_allclose(row_val.sum(), 1.0, rtol=1e-5)
