"""Import hypothesis, or degrade so that ONLY the property tests skip.

A module-level ``pytest.importorskip("hypothesis")`` would skip every test
in the importing file; this shim instead turns each ``@given`` test into an
individual skip while the plain tests still run.  ``st`` resolves any
strategy expression evaluated at decoration time to a dummy.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    class _MissingStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _MissingStrategies()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed "
                                       "(pip install '.[test]')")

    def settings(*args, **kwargs):
        return lambda fn: fn
